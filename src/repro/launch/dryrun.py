import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count on first init).  512 placeholder host devices back both the
single-pod 16×16 mesh (first 256) and the 2×16×16 multi-pod mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Per cell it records: compile success, memory_analysis, cost_analysis,
and the parsed collective wire bytes — the roofline table reads these
JSON artifacts (single-pod only; the multi-pod pass proves the "pod"
axis shards).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, all_arch_ids  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import shardings  # noqa: E402
from repro.launch.steps import cell, skip_reason  # noqa: E402
from repro.roofline.analysis import analyze_compiled, model_flops, roofline_terms  # noqa: E402

DEFAULT_OUT = Path("results/dryrun")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             overrides: dict | None = None, tag: str = "") -> dict:
    t0 = time.time()
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "status": "unknown",
    }
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return _save(rec, out_dir)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        c = cell(arch, shape_name, mesh, **(overrides or {}))
        in_sh = shardings(c.in_shardings, mesh)
        out_sh = shardings(c.out_shardings, mesh)
        with mesh:
            lowered = jax.jit(c.fn, in_shardings=in_sh, out_shardings=out_sh).lower(*c.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        analysis = analyze_compiled(compiled, n_devices=n_dev)
        mf = model_flops(c.cfg, c.shape)
        terms = roofline_terms(analysis, n_devices=n_dev)
        rec.update(
            status="ok",
            kind=c.kind,
            n_devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            model_flops=mf,
            # hlo_flops are per-device; useful-compute ratio compares the
            # whole-job model FLOPs against chips × per-device HLO FLOPs
            useful_ratio=(mf / (analysis["hlo_flops"] * n_dev)) if analysis["hlo_flops"] else None,
            **analysis,
            **terms,
        )
        try:
            print(compiled.memory_analysis())
        except Exception:
            pass
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    return _save(rec, out_dir)


def _probe_pattern(cfg):
    """Two shallow probe configs (k1, k2 layers) such that the full cost
    is linear: F(L) = F(k2) + (L-k2)/(k2-k1) · (F(k2)-F(k1)).

    Periodic patterns probe 1 and 2 periods; prefix+tail patterns (e.g.
    deepseek 'D'+'E'*26) probe prefix+1 and prefix+2 tail units.
    """
    pat = cfg.pattern
    L = len(pat)
    for p in range(1, L + 1):
        if L % p == 0 and pat == pat[:p] * (L // p):
            break
    if L // p > 1:
        k1, k2 = p, 2 * p
    else:
        # prefix of runs + homogeneous tail: unit = one tail layer
        tail = pat[-1]
        t0 = L
        while t0 > 0 and pat[t0 - 1] == tail:
            t0 -= 1
        k1, k2 = t0 + 1, t0 + 2
    assert (L - k2) % (k2 - k1) == 0, (pat, k1, k2)
    return k1, k2


def run_cost_probe(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
                   overrides: dict | None = None, tag: str = "cost") -> dict:
    """Extrapolated true-cost record (tag='cost').  XLA counts while-loop
    bodies once, so the scanned main pass under-reports FLOPs; here two
    SHALLOW fully-unrolled probes are compiled and costs extrapolated
    linearly in depth — every number still comes from compiled artifacts.
    """
    import time as _t

    t0 = _t.time()
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "status": "unknown"}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return _save(rec, out_dir)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        base_cfg = cell(arch, shape_name, mesh).cfg  # for L and pattern
        k1, k2 = _probe_pattern(base_cfg)
        L = base_cfg.n_layers
        probes = []
        for k in (k1, k2):
            ov = dict(overrides or {})
            ov.update(
                n_layers=k, layer_pattern=base_cfg.pattern[:k],
                n_enc_layers=(max(1, base_cfg.n_enc_layers * k // L)
                              if base_cfg.enc_dec else 0),
                unroll_scans=True, scan_layers=False, microbatches=1,
            )
            c = cell(arch, shape_name, mesh, **ov)
            in_sh = shardings(c.in_shardings, mesh)
            out_sh = shardings(c.out_shardings, mesh)
            with mesh:
                compiled = jax.jit(c.fn, in_shardings=in_sh,
                                   out_shardings=out_sh).lower(*c.args).compile()
            probes.append(analyze_compiled(compiled, n_devices=n_dev))
        a1, a2 = probes
        scale = (L - k2) / (k2 - k1)

        def extrap(key):
            return a2[key] + scale * (a2[key] - a1[key])

        analysis = {
            "hlo_flops": extrap("hlo_flops"),
            "hlo_bytes": extrap("hlo_bytes"),
            "coll_ici_bytes": extrap("coll_ici_bytes"),
            "coll_dci_bytes": extrap("coll_dci_bytes"),
            "coll_by_kind": {
                kk: a2["coll_by_kind"].get(kk, 0.0)
                + scale * (a2["coll_by_kind"].get(kk, 0.0) - a1["coll_by_kind"].get(kk, 0.0))
                for kk in set(a1["coll_by_kind"]) | set(a2["coll_by_kind"])
            },
            "coll_ops": int(extrap("coll_ops")),
            "memory": a2["memory"],
            "probe_layers": [k1, k2],
        }
        c_full = cell(arch, shape_name, mesh, **(overrides or {}))
        mf = model_flops(c_full.cfg, c_full.shape)
        terms = roofline_terms(analysis, n_devices=n_dev)
        rec.update(
            status="ok", kind=c_full.kind, n_devices=n_dev,
            model_flops=mf,
            useful_ratio=mf / (analysis["hlo_flops"] * n_dev) if analysis["hlo_flops"] else None,
            **analysis, **terms,
        )
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(_t.time() - t0, 2)
    return _save(rec, out_dir)


def _save(rec: dict, out_dir: Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1, default=str))
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" dom={rec['dominant']} frac={rec['roofline_fraction']:.3f}"
                 f" wall={rec.get('compile_s', rec.get('wall_s', 0)):.0f}s")
    elif status == "fail":
        extra = " " + rec["error"][:140]
    print(f"[dryrun] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:10s} {status}{extra}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (int/float/str)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--cost-pass", action="store_true",
                    help="unroll every scan so cost_analysis counts true "
                         "FLOPs (XLA counts while bodies once); tags the "
                         "record 'cost'")
    args = ap.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        try:
            overrides[k] = int(v)
        except ValueError:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = {"true": True, "false": False, "none": None}.get(v.lower(), v)

    out_dir = Path(args.out)
    archs = all_arch_ids() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                if args.cost_pass:
                    rec = run_cost_probe(arch, shape, multi_pod=mp,
                                         out_dir=out_dir, overrides=overrides,
                                         tag=args.tag or "cost")
                else:
                    rec = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                                   overrides=overrides, tag=args.tag)
                n_fail += rec["status"] == "fail"
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
