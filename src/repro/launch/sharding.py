"""Sharding rules: param/batch/state pytrees → PartitionSpecs.

The rule system is name-based (leaf key paths) with **divisibility
fallback**: a dim is sharded over an axis group only if its size divides
the group's total size; otherwise that dim's spec entry degrades to
``None``.  This keeps every (arch × shape × mesh) cell compilable even
where published head/expert counts don't divide the mesh (yi-34b's 56
heads, grok's 8 experts vs a 16-way model axis) — the baseline is then
conservatively replicated on that dim, and the §Perf pass improves the
interesting cells.

Scheme (mesh axes ``pod``/``data``/``model``):

* FSDP: every ≥2-D parameter shards its *largest eligible* dim over
  ``("pod","data")`` — ZeRO-3 semantics; GSPMD inserts the per-layer
  all-gathers which the scheduler overlaps with compute (paper §5.4).
* TP over ``model``: attention heads / FFN hidden / MoE experts / vocab
  (unembed) — the matching contractions reduce-scatter/psum.
* Batch over ``("pod","data")``; ``long_500k`` (batch=1) shards the
  sequence dim instead (SP).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes, fsdp_axes

__all__ = [
    "param_specs",
    "batch_specs",
    "state_specs",
    "shardings",
    "axis_size",
]


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0 and dim >= axis_size(mesh, axes)


def _clean(spec: list, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis assignments that don't divide; dedupe axis reuse."""
    used: set[str] = set()
    out = []
    for d, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a not in used)
        if not axes or not _fits(shape[d], mesh, axes):
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# (regex on the leaf path, spec-builder(shape, fsdp) -> list spec)
# Leaf paths look like: "['segs'][0]['0A']['attn']['wq']".
def _param_rule(path: str, shape: tuple[int, ...], fsdp, mesh) -> P:
    nd = len(shape)
    F, M = fsdp, "model"

    def match(*pats):
        return any(re.search(p, path) for p in pats)

    if nd == 0 or all(s == 1 for s in shape):
        return P()

    # --- embeddings -----------------------------------------------------
    # Megatron vocab-parallel: V over model, D UNSHARDED.  Sharding D over
    # the data axis makes the logits matmul contract a data-sharded dim →
    # GSPMD emits a full-vocab f32 all-reduce over "data" (12.9 GB/device
    # on granite train_4k — §Perf iteration 3).
    if match(r"\['embed'\]$"):
        return _clean([M, None], shape, mesh)
    if match(r"\['unembed'\]$"):
        return _clean([None, M], shape, mesh)

    # --- attention -------------------------------------------------------
    if match(r"\['attn'\]\['wq'\]", r"\['attn'\]\['wk'\]", r"\['attn'\]\['wv'\]",
             r"\['xattn'\]\['wq'\]", r"\['xattn'\]\['wk'\]", r"\['xattn'\]\['wv'\]"):
        return _clean([F, M], shape[-2:], mesh) if nd == 2 else _stacked([F, M], shape, mesh)
    if match(r"\['attn'\]\['wo'\]", r"\['xattn'\]\['wo'\]"):
        return _clean([M, F], shape[-2:], mesh) if nd == 2 else _stacked([M, F], shape, mesh)
    # MLA
    if match(r"\['attn'\]\['wdkv'\]"):
        return _stacked([F, None], shape, mesh)
    if match(r"\['attn'\]\['wuk'\]", r"\['attn'\]\['wuv'\]"):
        return _stacked([F, M], shape, mesh)

    # --- MLP --------------------------------------------------------------
    if match(r"\['mlp'\]\['w_in'\]", r"\['mlp'\]\['w_gate'\]",
             r"\['shared'\]\['w_in'\]", r"\['shared'\]\['w_gate'\]"):
        return _stacked([F, M], shape, mesh)
    if match(r"\['mlp'\]\['w_out'\]", r"\['shared'\]\['w_out'\]"):
        return _stacked([M, F], shape, mesh)

    # --- MoE ----------------------------------------------------------------
    if match(r"\['moe'\]\['router'\]"):
        return _stacked([F, None], shape, mesh)
    if match(r"\['moe'\]\['w_gate'\]", r"\['moe'\]\['w_in'\]"):
        # experts over model when divisible (EP), else TP inside experts
        E = shape[-3]
        if _fits(E, mesh, M):
            return _stacked([M, F, None], shape, mesh)
        return _stacked([None, F, M], shape, mesh)
    if match(r"\['moe'\]\['w_out'\]"):
        E = shape[-3]
        if _fits(E, mesh, M):
            return _stacked([M, None, F], shape, mesh)
        return _stacked([None, M, F], shape, mesh)

    # --- mamba2 ----------------------------------------------------------
    if match(r"\['mamba'\]\['w_z'\]", r"\['mamba'\]\['w_x'\]"):
        return _stacked([F, M], shape, mesh)  # heads (d_in) over model
    if match(r"\['mamba'\]\['w_out'\]"):
        return _stacked([M, F], shape, mesh)
    if match(r"\['mamba'\]\['conv_x'\]$"):
        return _stacked([None, M], shape, mesh)
    if match(r"\['mamba'\]\['w_B'\]", r"\['mamba'\]\['w_C'\]", r"\['mamba'\]\['w_dt'\]"):
        return _stacked([F, None], shape, mesh)
    if match(r"\['mamba'\]"):  # biases, A_log, D, dt_bias, norm_g, conv_B/C
        if shape[-1] > 1024:  # norm_g / conv_x_b over d_in
            return _stacked([M], shape, mesh, from_end=1)
        return _stacked([None], shape, mesh, from_end=1)

    # --- rwkv6 ----------------------------------------------------------
    if match(r"\['Wr'\]", r"\['Wk'\]", r"\['Wv'\]", r"\['Wg'\]", r"\['Wck'\]"):
        return _stacked([F, M], shape, mesh)
    if match(r"\['Wo'\]", r"\['Wcv'\]"):
        return _stacked([M, F], shape, mesh)
    if match(r"\['Wcr'\]"):
        return _stacked([F, None], shape, mesh)
    if match(r"\['lora_A'\]", r"\['lora_B'\]", r"\['wA'\]", r"\['wB'\]"):
        return _stacked([None, None], shape, mesh)
    if match(r"\['u'\]"):
        return _stacked([M, None], shape, mesh)  # heads over model

    # --- norms / small vectors -------------------------------------------
    if nd >= 2 and shape[-1] * shape[-2] >= 1 << 20:
        return _stacked([F, M], shape, mesh)  # generic big matrix
    return P(*([None] * nd))


def _stacked(tail_spec: list, shape, mesh, from_end: Optional[int] = None) -> P:
    """Apply ``tail_spec`` to the trailing dims (leading dims = scan
    stacking, unsharded)."""
    k = len(tail_spec) if from_end is None else from_end
    lead = [None] * (len(shape) - k)
    return _clean(lead + list(tail_spec), shape, mesh)


def param_specs(params_shape, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a (shape-)param tree."""
    F = fsdp_axes(mesh)
    flat, tdef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        specs.append(_param_rule(path, tuple(leaf.shape), F, mesh))
    return jax.tree_util.tree_unflatten(tdef, specs)


# --------------------------------------------------------------------------
# batch / state rules
# --------------------------------------------------------------------------


def batch_specs(batch_shape, mesh: Mesh, *, seq_sharded: bool = False) -> Any:
    """tokens/labels [B, S] over dp; modality embeddings [B, T, D] over dp.
    ``seq_sharded`` (long_500k, batch=1): shard S over "data" instead."""
    dp = dp_axes(mesh)

    def rule(kp, leaf):
        nd = len(leaf.shape)
        if seq_sharded and nd >= 2:
            return _clean([None, "data"] + [None] * (nd - 2), leaf.shape, mesh)
        if nd == 0:
            return P()
        return _clean([dp] + [None] * (nd - 1), leaf.shape, mesh)

    flat, tdef = jax.tree_util.tree_flatten_with_path(batch_shape)
    return jax.tree_util.tree_unflatten(tdef, [rule(k, l) for k, l in flat])


def state_specs(state_shape, mesh: Mesh, *, seq_axis_candidates=(524288, 32768)) -> Any:
    """Decode-state sharding: batch dim over dp; KV-cache length dim over
    "data" when the batch can't use it (B==1); head-ish dims over model
    when divisible."""
    dp = dp_axes(mesh)

    def rule(kp, leaf):
        path = jax.tree_util.keystr(kp)
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd == 0:
            return P()
        spec: list = [None] * nd
        # find the leading batch dim: "pos" is [B]; seg states have
        # [reps?, B, ...] — reps come from stacking, batch is the first
        # dim that matches the decode batch. Heuristic: shard the first
        # dim that divides dp; if it's 1 (B==1 long-ctx) shard the
        # largest dim over "data" instead (sequence/cache sharding).
        b_dim = None
        for d, s in enumerate(shape):
            if s > 1 and s % axis_size(mesh, dp) == 0:
                b_dim = d
                break
        if b_dim is not None:
            spec[b_dim] = dp
        elif nd >= 2:
            big = int(np.argmax(shape))
            if shape[big] % mesh.shape["data"] == 0 and shape[big] > 1:
                spec[big] = "data"
        # model axis on a trailing head/hidden dim
        for d in range(nd - 1, max(nd - 3, (b_dim if b_dim is not None else -1)), -1):
            if spec[d] is None and shape[d] % mesh.shape["model"] == 0 and shape[d] >= mesh.shape["model"]:
                if d != b_dim:
                    spec[d] = "model"
                    break
        return _clean(spec, shape, mesh)

    flat, tdef = jax.tree_util.tree_flatten_with_path(state_shape)
    return jax.tree_util.tree_unflatten(tdef, [rule(k, l) for k, l in flat])


def shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
