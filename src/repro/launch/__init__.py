"""repro.launch — meshes, sharding rules, train/serve steps, dry-run."""
