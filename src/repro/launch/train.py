"""Training driver: data pipeline → train_step → checkpoints, under the
fault-tolerance supervisor.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --reduced --steps 200 --ckpt-dir /tmp/ckpt

On this CPU container use ``--reduced`` (the full configs are exercised
via the dry-run).  On a pod the same driver runs per host with
``jax.distributed.initialize()`` and the production mesh.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import init_params
from repro.optim import AdamW, linear_warmup_cosine

from .steps import make_train_step


def train(
    arch: str,
    *,
    reduced: bool = True,
    steps: int = 200,
    seq_len: int = 128,
    global_batch: int = 8,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    lr: float = 1e-3,
    log_every: int = 10,
    resume: bool = False,
):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    cfg = cfg.replace(microbatches=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {arch} reduced={reduced} params={n_params/1e6:.1f}M")

    opt = AdamW(
        lr=linear_warmup_cosine(lr, warmup=max(1, steps // 20), total_steps=steps),
        moment_dtype=cfg.opt_state_dtype,
    )
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    pipe = TokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=global_batch)
    )
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), start = mgr.restore((params, opt_state))
        print(f"[train] resumed from step {start}")

    t0 = time.time()
    losses = []
    extra = {}
    for step in range(start, steps):
        b = pipe.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.enc_dec:
            batch["enc_frames"] = jnp.zeros((global_batch, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        if cfg.n_img_tokens:
            batch["img_emb"] = jnp.zeros((global_batch, cfg.n_img_tokens, cfg.d_model), cfg.jdtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            tps = (step - start + 1) * global_batch * seq_len / max(dt, 1e-9)
            print(f"  step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} tok/s {tps:,.0f}")
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state))  # async
    if mgr:
        mgr.save(steps, (params, opt_state), blocking=True)
    print(f"[train] done: loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"({time.time()-t0:.0f}s)")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--resume", action="store_true")
    a = ap.parse_args()
    train(a.arch, reduced=a.reduced, steps=a.steps, seq_len=a.seq_len,
          global_batch=a.global_batch, ckpt_dir=a.ckpt_dir,
          ckpt_every=a.ckpt_every, lr=a.lr, resume=a.resume)


if __name__ == "__main__":
    main()
