"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single-pod: 16×16 = 256 chips, axes
("data", "model").  Multi-pod: 2×16×16 = 512 chips, axes
("pod", "data", "model") — the "pod" axis crosses DCI.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "fsdp_axes", "dp_axes", "MESH_AXES"]

MESH_AXES = {
    False: (("data", "model"), (16, 16)),
    True: (("pod", "data", "model"), (2, 16, 16)),
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Axes parameters are FSDP-sharded over (pod+data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes the batch dimension is sharded over."""
    return fsdp_axes(mesh)
