"""Step builders: train_step / prefill_step / serve_step per (arch × shape).

``cell()`` returns everything the dry-run and the real drivers need:
the step function, ShapeDtypeStruct arguments, and in/out shardings.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data.pipeline import make_batch_specs
from repro.models import (
    decode_step,
    forward,
    init_params,
    loss_fn,
    make_decode_state,
    prefill,
)
from repro.optim import AdamW

from .sharding import batch_specs, param_specs, shardings, state_specs

__all__ = ["cell", "Cell", "make_train_step", "make_serve_step", "make_prefill_step",
           "cell_config", "skip_reason"]

# archs whose attention is quadratic-full → long_500k is skipped
_FULL_ATTN_SKIP = {
    "whisper-small",
    "yi-34b",
    "mistral-large-123b",
    "granite-3-8b",
    "internvl2-2b",
    "grok-1-314b",
    "deepseek-v2-lite-16b",
}


def skip_reason(arch_id: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch_id in _FULL_ATTN_SKIP:
        return "full quadratic attention — 524k decode is not sub-quadratic (DESIGN.md §Arch-applicability)"
    return None


def cell_config(arch_id: str, shape_name: str, **overrides) -> ModelConfig:
    """Shape-specialized config (e.g. zamba2 long-context window)."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    kw: dict[str, Any] = {}
    if shape_name == "long_500k" and cfg.family == "hybrid":
        # zamba2's shared attention runs a sliding window at 500k
        kw["swa_window"] = 4096
    if shape.kind != "train":
        kw["remat"] = False
        kw["microbatches"] = 1
    kw.update(overrides)
    return cfg.replace(**kw) if kw else cfg


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_optimizer(cfg) -> AdamW:
    return AdamW(lr=3e-4, moment_dtype=cfg.opt_state_dtype)


def make_train_step(cfg, optimizer: Optional[AdamW] = None) -> Callable:
    opt = optimizer or make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        mb = cfg.microbatches
        if mb <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True
            )(params)
        else:
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

            mbatches = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mbatch), has_aux=True
                )(params)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            (grads, loss_sum), _ = jax.lax.scan(acc, (zero, 0.0), mbatches)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss_sum / mb
            metrics = {}
        new_params, new_opt, opt_metrics = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **opt_metrics}

    return train_step


def make_prefill_step(cfg, shape: ShapeSpec) -> Callable:
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, max_len=shape.seq_len)

    return prefill_step


def make_serve_step(cfg) -> Callable:
    def serve_step(params, state, tokens):
        logits, new_state = decode_step(cfg, params, tokens, state)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_state

    return serve_step


# ---------------------------------------------------------------------------
# cell assembly (fn + arg specs + shardings)
# ---------------------------------------------------------------------------


@dataclass
class Cell:
    arch_id: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Callable
    args: tuple  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    kind: str


def _param_shapes(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def cell(arch_id: str, shape_name: str, mesh: Mesh, **cfg_overrides) -> Cell:
    """Build the lowering cell for (arch × shape) on ``mesh``."""
    cfg = cell_config(arch_id, shape_name, **cfg_overrides)
    shape = SHAPES[shape_name]
    p_shapes = _param_shapes(cfg)
    p_spec = param_specs(p_shapes, mesh)
    seq_sharded = shape.global_batch == 1

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_spec = param_specs_like(o_shapes, p_spec)
        b_shapes = make_batch_specs(cfg, shape)
        b_spec = batch_specs(b_shapes, mesh, seq_sharded=seq_sharded)
        fn = make_train_step(cfg, opt)
        return Cell(
            arch_id, shape, cfg, fn,
            (p_shapes, o_shapes, b_shapes),
            (p_spec, o_spec, b_spec),
            (p_spec, o_spec, P()),
            "train",
        )

    if shape.kind == "prefill":
        b_shapes = make_batch_specs(cfg, shape)
        b_spec = batch_specs(b_shapes, mesh, seq_sharded=seq_sharded)
        st_shapes = jax.eval_shape(
            lambda: make_decode_state(cfg, shape.global_batch, shape.seq_len)
        )
        st_spec = state_specs(st_shapes, mesh)
        fn = make_prefill_step(cfg, shape)
        return Cell(
            arch_id, shape, cfg, fn,
            (p_shapes, b_shapes),
            (p_spec, b_spec),
            (P(), st_spec),
            "prefill",
        )

    # decode: one token against a seq_len-deep cache
    st_shapes = jax.eval_shape(
        lambda: make_decode_state(
            cfg, shape.global_batch, shape.seq_len,
            start_pos=jnp.full((shape.global_batch,), shape.seq_len - 1, jnp.int32),
        )
    )
    st_spec = state_specs(st_shapes, mesh)
    t_shapes = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    t_spec = batch_specs(t_shapes, mesh)
    fn = make_serve_step(cfg)
    return Cell(
        arch_id, shape, cfg, fn,
        (p_shapes, st_shapes, t_shapes),
        (p_spec, st_spec, t_spec),
        (t_spec, st_spec),
        "decode",
    )


def param_specs_like(opt_shapes, p_spec):
    """Optimizer state inherits each param's spec (moments are
    shape-congruent); the step scalar is replicated."""
    return type(opt_shapes)(P(), p_spec, p_spec)
