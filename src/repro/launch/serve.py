"""Serving driver: synthetic tenants against one shared runtime.

    PYTHONPATH=src python -m repro.launch.serve --tenants 2 --requests 8

Each tenant is a client thread submitting halo-exchange stencil
requests to a :class:`repro.serve.Server`.  All tenants share one
runtime and one work-stealing worker pool; their request cones are
disjoint, so they drain concurrently — the demo prints each tenant's
measured wait%, request quantiles (p50/p95/p99), and the admission
counters via :func:`repro.format_stats`.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def tenant_workload(seed: int, n: int):
    """One tenant's request: a 5-point stencil step over a private
    array, plus its NumPy closed form for verification."""
    import repro

    host = np.random.default_rng(seed).standard_normal((n, n))

    def fn():
        a = repro.array(host)
        b = (np.roll(a, 1, axis=0) + np.roll(a, -1, axis=0)
             + np.roll(a, 1, axis=1) + np.roll(a, -1, axis=1)) * 0.25
        return b - a * 0.5

    expect = (np.roll(host, 1, axis=0) + np.roll(host, -1, axis=0)
              + np.roll(host, 1, axis=1) + np.roll(host, -1, axis=1)) * 0.25 \
        - host * 0.5
    return fn, expect


def serve(
    tenants: int = 2,
    requests: int = 8,
    *,
    nprocs: int = 4,
    block: int = 16,
    n: int = 32,
    latency: float = 5e-3,
    max_inflight: int = 8,
    seed: int = 0,
):
    """Run ``tenants`` concurrent client threads, ``requests`` stencil
    requests each, against one shared Server; verifies every result and
    returns ``{tenant: TenantStats}``."""
    import repro

    srv = repro.Server(
        nprocs=nprocs,
        block_size=block,
        latency=latency,
        max_inflight=max_inflight,
        max_queue=max(tenants, 8),
    )
    mismatches = []

    def client(name: str, widx: int):
        fn, expect = tenant_workload(seed + widx, n)
        sess = srv.session(name)
        for _ in range(requests):
            got = sess.request(fn).result()
            if not np.array_equal(got, expect):
                mismatches.append(name)

    t0 = time.perf_counter()
    with srv:
        threads = [
            threading.Thread(target=client, args=(f"tenant-{i}", i))
            for i in range(tenants)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        assert not mismatches, f"result mismatch for {sorted(set(mismatches))}"
        print(srv.format_stats())
        adm = srv.admission
        print(f"[serve] {tenants} tenants x {requests} requests in "
              f"{elapsed * 1e3:.0f} ms "
              f"({tenants * requests / elapsed:.1f} req/s); admission: "
              f"{adm.n_admitted} admitted, {adm.n_rejected} rejected, "
              f"peak inflight {adm.peak_inflight}")
        return srv.stats()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per tenant")
    ap.add_argument("--nprocs", type=int, default=4)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--latency", type=float, default=5e-3)
    ap.add_argument("--max-inflight", type=int, default=8)
    a = ap.parse_args()
    serve(a.tenants, a.requests, nprocs=a.nprocs, block=a.block, n=a.n,
          latency=a.latency, max_inflight=a.max_inflight)


if __name__ == "__main__":
    main()
