"""Serving driver: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.models import decode_step, init_params, prefill

from .steps import make_serve_step


def serve(
    arch: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 32,
    seed: int = 0,
):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    req = {"tokens": jax.random.randint(ks[0], (batch, prompt_len), 0, cfg.vocab_size)}
    if cfg.enc_dec:
        req["enc_frames"] = jax.random.normal(ks[1], (batch, cfg.enc_seq, cfg.d_model))
    if cfg.n_img_tokens:
        req["img_emb"] = jax.random.normal(ks[2], (batch, cfg.n_img_tokens, cfg.d_model))

    t0 = time.time()
    max_len = prompt_len + gen + (cfg.n_img_tokens or 0)
    last, state = prefill(cfg, params, req, max_len=max_len)
    t_prefill = time.time() - t0
    toks = jnp.argmax(last, axis=-1).astype(jnp.int32)

    step = jax.jit(make_serve_step(cfg))
    out = [toks]
    t0 = time.time()
    for _ in range(gen - 1):
        toks, state = step(params, state, toks)
        out.append(toks)
    seq = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] {arch}: prefill {batch}x{prompt_len} in {t_prefill*1e3:.0f}ms; "
          f"decoded {batch}x{gen} in {dt*1e3:.0f}ms "
          f"({batch * (gen-1) / max(dt, 1e-9):.1f} tok/s)")
    assert bool(jnp.isfinite(last).all())
    return seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    a = ap.parse_args()
    serve(a.arch, reduced=a.reduced, batch=a.batch,
          prompt_len=a.prompt_len, gen=a.gen)


if __name__ == "__main__":
    main()
