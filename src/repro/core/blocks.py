"""Block decomposition of distributed arrays (paper §5.2).

Implements the paper's three-level block hierarchy:

* **base-block** — a tile of an array-base, owned by exactly one process,
  assigned by an N-D block-cyclic distribution (paper follows HPF).
* **view-block** — a tile of an array-view (user-visible coordinates).
* **sub-view-block** — the intersection of a view-block with one base-block
  of every operand; the unit of scheduling.

The fragmentation routine is generalized to an *iteration space*: an
operation iterates over an N-D index space; every operand maps a subset of
the iteration dims onto its own view dims.  The common refinement of all
operands' base-block grids then yields fragments such that every fragment
touches exactly one base-block of every operand — the paper's
sub-view-block decomposition.  Elementwise ufuncs, axis reductions,
broadcasts and blocked matmul (SUMMA) all fragment through this one
mechanism.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "Layout",
    "ViewSpec",
    "Region",
    "Fragment",
    "OperandSpec",
    "fragment_iteration_space",
    "default_process_grid",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def default_process_grid(nprocs: int, ndim: int) -> tuple[int, ...]:
    """Factor ``nprocs`` into an ``ndim``-dimensional near-square grid."""
    if ndim == 0:
        return ()
    grid = [1] * ndim
    n = nprocs
    f = 2
    factors = []
    while f * f <= n:
        while n % f == 0:
            factors.append(f)
            n //= f
        f += 1
    if n > 1:
        factors.append(n)
    for f in sorted(factors, reverse=True):
        i = int(np.argmin(grid))
        grid[i] *= f
    return tuple(grid)


@dataclass(frozen=True)
class Layout:
    """N-D block-cyclic distribution of an array-base (paper §5.2)."""

    shape: tuple[int, ...]
    block_shape: tuple[int, ...]
    pgrid: tuple[int, ...]  # process grid, same ndim as shape

    def __post_init__(self):
        if len(self.shape) != len(self.block_shape):
            raise ValueError("shape/block_shape ndim mismatch")
        if len(self.pgrid) != len(self.shape):
            raise ValueError("pgrid ndim mismatch")
        if any(b <= 0 for b in self.block_shape):
            raise ValueError("non-positive block size")

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def grid(self) -> tuple[int, ...]:
        """Number of base-blocks per dimension."""
        return tuple(
            _ceil_div(s, b) if s else 0 for s, b in zip(self.shape, self.block_shape)
        )

    @property
    def nblocks(self) -> int:
        return int(np.prod(self.grid)) if self.ndim else 1

    def owner(self, coord: tuple[int, ...]) -> int:
        """Block-cyclic owner rank of base-block ``coord`` (round-robin
        per-dimension over the process grid, HPF style)."""
        if not coord:
            return 0
        rank = 0
        for c, p in zip(coord, self.pgrid):
            rank = rank * p + (c % p)
        return rank

    def block_slices(self, coord: tuple[int, ...]) -> tuple[slice, ...]:
        return tuple(
            slice(c * b, min((c + 1) * b, s))
            for c, b, s in zip(coord, self.block_shape, self.shape)
        )

    def block_shape_at(self, coord: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(
            min((c + 1) * b, s) - c * b
            for c, b, s in zip(coord, self.block_shape, self.shape)
        )

    def blocks(self) -> Iterator[tuple[tuple[int, ...], tuple[slice, ...]]]:
        for coord in np.ndindex(*self.grid):
            yield coord, self.block_slices(coord)


@dataclass(frozen=True)
class ViewSpec:
    """Strided view of an array-base: per-dim ``(offset, step, length)``.

    A view maps view-index ``i`` (0 <= i < length) to base index
    ``offset + i*step``.  This is the paper's array-view (§5.1): the
    hierarchy is flat — views refer directly to a base, never to another
    view.
    """

    offset: tuple[int, ...]
    step: tuple[int, ...]
    vshape: tuple[int, ...]

    @staticmethod
    def full(shape: Sequence[int]) -> "ViewSpec":
        n = len(shape)
        return ViewSpec((0,) * n, (1,) * n, tuple(shape))

    @property
    def ndim(self) -> int:
        return len(self.vshape)

    @property
    def size(self) -> int:
        return int(np.prod(self.vshape)) if self.vshape else 1

    def compose_slice(self, key: tuple[slice, ...]) -> "ViewSpec":
        """Compose this view with a basic slice (positive steps only)."""
        off, st, sh = [], [], []
        for o, s, L, sl in zip(self.offset, self.step, self.vshape, key):
            start, stop, stride = sl.indices(L)
            if stride <= 0:
                raise NotImplementedError("negative slice steps not supported")
            n = max(0, _ceil_div(stop - start, stride))
            off.append(o + start * s)
            st.append(s * stride)
            sh.append(n)
        return ViewSpec(tuple(off), tuple(st), tuple(sh))

    def base_range(self, dim: int, lo: int, hi: int) -> tuple[int, int]:
        """Base-index interval [first, last] covered by view interval
        [lo, hi) on ``dim``; requires hi > lo."""
        first = self.offset[dim] + lo * self.step[dim]
        last = self.offset[dim] + (hi - 1) * self.step[dim]
        return first, last


# A Region is a per-dim (start, stop) interval tuple in base-block-local
# coordinates; used for fine-grained conflict detection inside one block.
Region = tuple[tuple[int, int], ...]


@dataclass(frozen=True)
class Fragment:
    """One sub-view-block: the part of one operand touched by one fragment
    of the iteration space.  ``local`` is per-operand-dim (start, stop,
    step) inside base-block ``block``."""

    block: tuple[int, ...]
    local: tuple[tuple[int, int, int], ...]
    owner: int

    @property
    def region(self) -> Region:
        return tuple((s, e) for s, e, _ in self.local)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(_ceil_div(e - s, st) for s, e, st in self.local)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.local else 1

    @property
    def slices(self) -> tuple[slice, ...]:
        return tuple(slice(s, e, st) for s, e, st in self.local)


@dataclass(frozen=True)
class OperandSpec:
    """An operand of a fragmented operation.

    ``dims[d]`` gives, for operand dim ``d``, the iteration-space dim it is
    indexed by.  Elementwise ops use ``dims = (0, 1, ..., n-1)`` for every
    operand; a matmul ``C[m,n] += A[m,k] B[k,n]`` uses iteration space
    ``(M, N, K)`` with dims ``(0, 2)``, ``(2, 1)`` and ``(0, 1)``.
    """

    view: ViewSpec
    layout: Layout
    dims: tuple[int, ...]


def _dim_cuts(view: ViewSpec, layout: Layout, dim: int) -> np.ndarray:
    """View-coordinate cut points on ``dim`` where the base-block index of
    ``view`` changes (sorted, interior only)."""
    L = view.vshape[dim]
    if L <= 1:
        return np.empty(0, dtype=np.int64)
    o, s = view.offset[dim], view.step[dim]
    bs = layout.block_shape[dim]
    idx = o + np.arange(L, dtype=np.int64) * s
    bid = idx // bs
    return (np.nonzero(np.diff(bid))[0] + 1).astype(np.int64)


@functools.lru_cache(maxsize=8192)
def _fragment_cached(
    iter_shape: tuple[int, ...],
    operands: tuple[OperandSpec, ...],
) -> tuple[tuple[tuple[tuple[int, int], ...], tuple[Fragment, ...]], ...]:
    nd = len(iter_shape)
    cuts: list[list[np.ndarray]] = [
        [np.array([0, iter_shape[d]], dtype=np.int64)] for d in range(nd)
    ]
    for op in operands:
        for od, idim in enumerate(op.dims):
            cuts[idim].append(_dim_cuts(op.view, op.layout, od))
    per_dim = [np.unique(np.concatenate(c)) for c in cuts]
    intervals = [
        [(int(c[i]), int(c[i + 1])) for i in range(len(c) - 1)] for c in per_dim
    ]
    out = []
    for combo in np.ndindex(*[len(iv) for iv in intervals]):
        vint = tuple(intervals[d][combo[d]] for d in range(nd))
        frags = []
        for op in operands:
            block, local = [], []
            for od, idim in enumerate(op.dims):
                lo, hi = vint[idim]
                if op.view.vshape[od] == 1 and iter_shape[idim] > 1:
                    lo, hi = 0, 1  # broadcast dim: single element read by all
                first, last = op.view.base_range(od, lo, hi)
                bs = op.layout.block_shape[od]
                b0 = first // bs
                assert last // bs == b0, "fragment spans base blocks"
                block.append(int(b0))
                start = first - b0 * bs
                stop = last - b0 * bs + 1
                local.append((int(start), int(stop), int(op.view.step[od])))
            block_t = tuple(block)
            frags.append(Fragment(block_t, tuple(local), op.layout.owner(block_t)))
        out.append((vint, tuple(frags)))
    return tuple(out)


def fragment_iteration_space(
    iter_shape: Sequence[int],
    operands: Sequence[OperandSpec],
):
    """Decompose an operation's iteration space into sub-view-block
    fragments (cached on (iter_shape, operand specs))."""
    if any(s == 0 for s in iter_shape):
        return ()
    return _fragment_cached(tuple(iter_shape), tuple(operands))
