"""Ufunc fusion (paper §7 "future work", implemented — beyond-paper).

When ``Runtime(fusion=True)``, elementwise operator applications build
:class:`~repro.core.darray.Expr` trees instead of materializing a
temporary per ufunc; at materialization the whole tree is recorded as ONE
joint operation.  Benefits, measured in ``benchmarks/paper_apps.py``:

* fewer operation-nodes → lower dependency-system overhead (the paper's
  dominating cost for the full-DAG variant);
* no intermediate temporaries → less memory traffic (on TPU: the analogue
  of keeping the chain in VMEM instead of HBM round-trips per ufunc);
* higher per-fragment arithmetic intensity → more computation available to
  hide each transfer behind (directly improves the §5.4 overlap window).
"""
from .darray import Expr  # noqa: F401

__all__ = ["Expr"]
