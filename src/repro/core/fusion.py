"""Fusion: record-time elementwise trees and the plan-stage cross-kind
fusion pass.

Two fusion layers live here:

* **Record-time elementwise fusion** (paper §7 "future work",
  implemented beyond-paper): with ``Runtime(fusion=True)``, operator
  applications build :class:`~repro.core.darray.Expr` trees instead of
  materializing a temporary per ufunc; the whole tree is recorded as
  ONE joint operation.  Fewer operation-nodes → lower
  dependency-system overhead; no intermediate temporaries → less
  memory traffic; higher per-fragment arithmetic intensity → more
  computation to hide each transfer behind (§5.4 overlap window).

* **Plan-stage cross-kind fusion** (the ``"fuse"`` pass,
  :func:`fuse_cross_kind`): record-time fusion only merges elementwise
  ufuncs.  This pass runs over the *recorded* graph and fuses across
  operation kinds:

  - **map → reduce-partial**: a map whose output fragment is consumed
    only by a partial reduction of the exact same fragment — and whose
    output base is dead (the user dropped the temporary, e.g.
    ``(x * x).sum()``) — becomes one
    :class:`~repro.core.engine.FusedMapReducePayload`, skipping the
    block-storage round trip entirely;
  - **fill → map**: a map operand whose fragment was last written by a
    contiguous fill covering it constant-folds the fill value into the
    argument list, deleting the dependency edge;
  - **dead-store elimination**: fills and maps writing regions of dead
    bases that no remaining operation reads are dropped.

  All rewrites preserve the relative program order of the conflicting
  accesses they keep (the fused node sits at the producer's position),
  so planned graphs stay bit-identical to the unfused simulator — the
  property-based test in ``tests/test_plan.py`` checks exactly this on
  random programs.
"""
from __future__ import annotations

from collections import defaultdict

from repro.api.registry import register_pass

from .darray import Expr  # noqa: F401  (re-export: the record-time layer)
from .engine import (
    FillPayload,
    FusedMapReducePayload,
    MapPayload,
    ReducePartialPayload,
)
from .graph import COMPUTE, AccessNode, OperationNode
from .plan import PlanContext, op_reads, region_covers, regions_overlap

__all__ = ["Expr", "fuse_cross_kind"]


def _rebuild_map_accesses(op: OperationNode, p: MapPayload) -> None:
    """Re-derive the access list of a map whose args changed (mirrors
    ``Runtime._insert_compute``'s construction)."""
    writes = [a for a in op.accesses if a.write]
    op.accesses = []
    for a in writes:
        op.add_access(AccessNode(a.key, a.region, write=True))
    for ref in p.args:
        if ref[0] == "b":
            _, bid, frag = ref
            op.add_access(
                AccessNode((bid, frag.block), frag.region, write=False)
            )
        elif ref[0] == "s":
            op.add_access(AccessNode(("s", ref[1]), None, write=False))


def _const_fold_fills(ctx: PlanContext) -> None:
    """fill → map: replace map operands whose fragment was last written
    by a covering contiguous fill with the fill value (cast to the
    block dtype, so the ufunc sees exactly what a block read would have
    produced)."""
    writes_at: dict = defaultdict(list)  # key -> [(pos, region, op)]
    folded = 0
    for i, op in enumerate(ctx.ops):
        p = op.payload
        if isinstance(p, MapPayload):
            new_args = list(p.args)
            changed = False
            for k, ref in enumerate(p.args):
                if ref[0] != "b":
                    continue
                _, bid, frag = ref
                last = None
                for pos, region, wop in reversed(
                    writes_at.get((bid, frag.block), ())
                ):
                    if regions_overlap(region, frag.region):
                        last = wop
                        break
                if last is None or not isinstance(last.payload, FillPayload):
                    continue
                fp = last.payload
                if any(st != 1 for _, _, st in fp.out_frag.local):
                    continue  # strided fill: does not cover contiguously
                if not region_covers(fp.out_frag.region, frag.region):
                    continue
                dtype = ctx.dtype_of(bid, frag.block)
                if dtype is None:
                    continue
                new_args[k] = ("c", dtype.type(fp.value))
                changed = True
                folded += 1
            if changed:
                p.args = tuple(new_args)
                _rebuild_map_accesses(op, p)
                ctx.dirty = True
        for acc in op.accesses:
            if acc.write:
                writes_at[acc.key].append((i, acc.region, op))
    ctx.stats.n_const_folded += folded


def _fuse_map_reduce(ctx: PlanContext) -> None:
    """map → reduce-partial fusion on dead temporaries."""
    ops = ctx.ops
    reads_by_key: dict = defaultdict(list)  # key -> [(pos, region)]
    writes_by_key: dict = defaultdict(list)  # key -> [(pos, region, op)]
    for i, op in enumerate(ops):
        for key, region in op_reads(op):
            reads_by_key[key].append((i, region))
        for a in op.accesses:
            if a.write:
                writes_by_key[a.key].append((i, a.region, op))
    fused: dict[int, OperationNode] = {}  # map position -> fused node
    dropped: set[int] = set()  # reduce positions folded away
    for i, op in enumerate(ops):
        p = op.payload
        if not isinstance(p, ReducePartialPayload) or p.src[0] != "b":
            continue
        _, bid, frag = p.src
        if bid not in ctx.dead_bases:
            continue
        key = (bid, frag.block)
        # the latest writer overlapping the reduced fragment before us
        last = None
        for pos, region, wop in reversed(writes_by_key.get(key, ())):
            if pos < i and regions_overlap(region, frag.region):
                last = (pos, wop)
                break
        if last is None:
            continue
        mpos, mop = last
        mp = mop.payload
        if (
            mpos in fused
            or not isinstance(mp, MapPayload)
            or mp.out_frag.block != frag.block
            or mp.out_frag.local != frag.local
        ):
            continue
        # sole reader: nothing after the map reads its output region
        # except this reduction (earlier readers saw the pre-map value
        # and are unaffected by skipping the write)
        sole = all(
            pos <= mpos or pos == i or not regions_overlap(region, mp.out_frag.region)
            for pos, region in reads_by_key.get(key, ())
        )
        if not sole:
            continue
        node = OperationNode(
            COMPUTE,
            FusedMapReducePayload(mp, p.ufunc_name, p.axes, p.dst_scratch, p.keepdims),
            procs=mop.procs,
            cost=mop.cost + op.cost,
            label=f"map+reduce:{p.ufunc_name}",
        )
        for a in mop.accesses:
            if not a.write:
                node.add_access(AccessNode(a.key, a.region, write=False))
        node.add_access(AccessNode(("s", p.dst_scratch), None, write=True))
        ctx.note_rewrite(node, (mop, op))
        fused[mpos] = node
        dropped.add(i)
    if fused:
        ctx.ops = [
            fused.get(i, op) for i, op in enumerate(ops) if i not in dropped
        ]
        ctx.dirty = True
        ctx.stats.n_fused += len(fused)


def _drop_dead_stores(ctx: PlanContext) -> None:
    """Eliminate fills/maps writing dead-base regions never read by any
    remaining operation (the base was garbage-collected, so the blocks
    can never be gathered either)."""
    ops = ctx.ops
    reads_by_key: dict = defaultdict(list)
    for i, op in enumerate(ops):
        for key, region in op_reads(op):
            reads_by_key[key].append((i, region))
    drop: set[int] = set()
    for i, op in enumerate(ops):
        p = op.payload
        if not isinstance(p, (FillPayload, MapPayload)):
            continue
        if p.out_base not in ctx.dead_bases:
            continue
        frag = p.out_frag
        if any(
            pos > i and regions_overlap(region, frag.region)
            for pos, region in reads_by_key.get((p.out_base, frag.block), ())
        ):
            continue
        drop.add(i)
    if drop:
        for i in drop:
            ctx.note_drop(ops[i])
        ctx.ops = [op for i, op in enumerate(ops) if i not in drop]
        ctx.dirty = True
        ctx.stats.n_dropped += len(drop)


def fuse_cross_kind(ctx: PlanContext) -> None:
    """The ``"fuse"`` plan pass: fill→map constant folding, then
    map→reduce-partial fusion, then dead-store elimination (each stage
    re-indexes, so later stages see earlier rewrites)."""
    _const_fold_fills(ctx)
    _fuse_map_reduce(ctx)
    _drop_dead_stores(ctx)


register_pass("fuse", fuse_cross_kind)
