"""User-facing distributed arrays — the DistNumPy API surface (paper §5).

``array(..., dist=True)`` etc. mirror the paper's only API difference from
NumPy.  All operations on :class:`DistArray` are recorded lazily into the
active :class:`~repro.core.engine.Runtime`; reading data back (``__array__``,
``item``, comparisons) triggers an operation flush (§5.6) — under
``sync="demand"`` a *partial* one, draining only the reader's dependency
cone, with :meth:`DistArray.evaluate` / :meth:`DistArray.block_until_ready`
as the explicit JAX-style spellings.

The paper's central promise — *no user-visible change to the NumPy
programming model* — is carried by the NumPy array protocols:
:class:`DistArray` (and :class:`Expr`) implement ``__array_ufunc__``,
``__array_function__`` and ``__array_priority__``, so plain
``np.add(a, b)``, ``np.exp(a)``, ``np.sum(a, axis=0)``, ``np.matmul``,
``np.where`` and ``np.roll`` record lazily into the active runtime.  The
ufunc registry in :mod:`repro.core.ufunc` is the single dispatch table
(NumPy ufunc → :class:`UFunc` → backend impl); the module-level
functions here (``add``, ``exp``, …) are generated from it.

When the runtime is created with ``fusion=True``, elementwise expressions
build :class:`Expr` trees that are merged into a single joint operation at
materialization — the paper's §7 "merge calls to ufuncs" future work,
implemented here as a beyond-paper optimization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from . import ufunc as uf
from .blocks import ViewSpec
from .engine import ArrayBase, Runtime, current_runtime
from .ufunc import UFunc

Scalar = (int, float, complex, bool, np.integer, np.floating, np.complexfloating, np.bool_)


def _coerce_operand(x):
    """Normalize one user-supplied operand: DistArray/Expr/scalar pass
    through, host ndarrays are scattered into a DistArray, 0-d arrays
    become scalars.  Returns None for unsupported types."""
    if isinstance(x, (DistArray, Expr)) or isinstance(x, Scalar):
        return x
    if isinstance(x, np.ndarray):
        if x.ndim == 0:
            return x[()]
        return array(x)
    if isinstance(x, (list, tuple)):
        return array(np.asarray(x))
    return None


def _as_operand(x):
    """DistArray -> (base, view); Expr -> materialized temp; scalar -> tag."""
    if isinstance(x, DistArray):
        return (x._base, x._view)
    if isinstance(x, Expr):
        return _as_operand(x.materialize())
    if isinstance(x, Scalar):
        return ("c", x)
    raise TypeError(f"unsupported operand {type(x)}")


def _result_meta(ufn: Optional[UFunc], args) -> tuple[tuple[int, ...], np.dtype]:
    """(broadcast shape, result dtype) of applying ``ufn`` to ``args``;
    the ufunc's fixed ``out_dtype`` (comparisons -> bool) overrides NumPy
    promotion."""
    shapes, dtypes = [], []
    for a in args:
        if isinstance(a, (DistArray, Expr)):
            shapes.append(a.shape)
            dtypes.append(a.dtype)
        else:
            dtypes.append(np.dtype(type(a)) if not isinstance(a, complex) else np.dtype(complex))
    shape = np.broadcast_shapes(*shapes) if shapes else ()
    if ufn is not None and ufn.out_dtype is not None:
        dtype = np.dtype(ufn.out_dtype)
    else:
        dtype = np.result_type(*dtypes)
    return tuple(shape), dtype


# ---------------------------------------------------------------------------
# NumPy protocol dispatch (shared by DistArray and Expr)
# ---------------------------------------------------------------------------

# np functions that are not np.ufuncs dispatch through
# ``__array_function__``; handlers registered below with @_implements
_HANDLED_FUNCTIONS: dict = {}


def _implements(*np_funcs):
    def deco(fn):
        for f in np_funcs:
            _HANDLED_FUNCTIONS[f] = fn
        return fn

    return deco


# ufunc.reduce method -> the engine's reduceable ufunc name
_REDUCE_UFUNCS = {np.add: "add", np.minimum: "minimum", np.maximum: "maximum"}


def _array_ufunc(self, ufunc, method, *inputs, **kwargs):
    """Shared ``__array_ufunc__``: resolve the NumPy ufunc through the
    registry (ufunc.py is the single dispatch table) and record lazily."""
    out = kwargs.pop("out", None)
    if method == "__call__":
        if ufunc is np.matmul:
            if kwargs or out is not None:
                return NotImplemented
            a, b = (_coerce_operand(x) for x in inputs)
            if a is None or b is None:
                return NotImplemented
            return matmul(a, b)
        u = uf.NP_TO_UFUNC.get(ufunc)
        if u is None or kwargs:
            return NotImplemented
        args = [_coerce_operand(x) for x in inputs]
        if any(a is None for a in args):
            return NotImplemented
        if out is not None:
            target = out[0] if isinstance(out, tuple) else out
            if not isinstance(target, DistArray) or (
                isinstance(out, tuple) and len(out) != 1
            ):
                return NotImplemented
            rt = current_runtime()
            if rt.fusion:
                Expr(u, tuple(args)).materialize(out=target)
            else:
                rt.record_map(
                    u, (target._base, target._view), [_as_operand(a) for a in args]
                )
            return target
        return _apply(u, *args)
    if method == "reduce":
        name = _REDUCE_UFUNCS.get(ufunc)
        axis = kwargs.pop("axis", 0)
        keepdims = kwargs.pop("keepdims", False)
        if name is None or out is not None or kwargs.pop("dtype", None) is not None:
            return NotImplemented
        if kwargs:
            return NotImplemented
        (a,) = inputs
        a = a.materialize() if isinstance(a, Expr) else a
        return a._reduce(name, axis, keepdims)
    return NotImplemented


def _array_function(self, func, types, args, kwargs):
    impl = _HANDLED_FUNCTIONS.get(func)
    if impl is None:
        return NotImplemented
    return impl(*args, **kwargs)


class Expr:
    """Unevaluated elementwise expression (fusion mode)."""

    __slots__ = ("ufunc", "args", "shape", "dtype")

    __array_priority__ = 1000.0
    __array_ufunc__ = _array_ufunc
    __array_function__ = _array_function

    def __init__(self, ufunc: UFunc, args: tuple):
        self.ufunc = ufunc
        self.args = args
        self.shape, self.dtype = _result_meta(ufunc, args)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    # -- fusion ---------------------------------------------------------
    def _collect(self, leaves: list) -> object:
        """Return a spec tree of ('leaf', idx) / ('const', v) / (ufunc, specs)."""
        specs = []
        for a in self.args:
            if isinstance(a, Expr):
                specs.append(a._collect(leaves))
            elif isinstance(a, DistArray):
                leaves.append(a)
                specs.append(("leaf", len(leaves) - 1))
            else:
                specs.append(("const", a))
        return (self.ufunc, tuple(specs))

    def _cost_parts(self) -> tuple[int, float]:
        """(#ops, heavy-compute surplus) of the tree."""
        n, heavy = 1, max(0.0, self.ufunc.cost - 1.0)
        for a in self.args:
            if isinstance(a, Expr):
                sn, sh = a._cost_parts()
                n += sn
                heavy += sh
        return n, heavy

    def fused_cost(self, n_leaves: int) -> float:
        """Per-element cost of the fused op.  Plain ufunc chains are
        memory-bound: a chain of k binary ufuncs moves ~3k·N bytes
        (2 reads + 1 write each), the fused version (L+1)·N — that ratio is
        the fusion win (HBM round-trip avoidance on TPU).  Heavy
        (transcendental) compute stays additive."""
        _, heavy = self._cost_parts()
        return max(1.0, (n_leaves + 1) / 3.0) + heavy

    def materialize(self, out: Optional["DistArray"] = None) -> "DistArray":
        """Record ONE joint operation for the whole tree (§7 fusion)."""
        rt = current_runtime()
        leaves: list[DistArray] = []
        spec = self._collect(leaves)
        if out is not None and any(l._base is out._base for l in leaves):
            # output aliases an input base: a single joint operation would
            # let one fragment's write race another fragment's read.  Go
            # through a fresh temporary (same rule NumPy's ufuncs need).
            tmp = self.materialize(None)
            rt.record_map(
                uf.identity, (out._base, out._view), [(tmp._base, tmp._view)]
            )
            return out

        def run(*arrays):
            return uf.eval_tree(spec, arrays, lambda u: u.fn)

        fused = UFunc(
            name=f"fused[{self.ufunc.name}x{len(leaves)}]",
            fn=run,
            nin=len(leaves),
            cost=self.fused_cost(len(leaves)),
            tree=spec,
        )
        if out is None:
            out = empty(self.shape, dtype=self.dtype)
        rt.record_map(fused, (out._base, out._view), [(l._base, l._view) for l in leaves])
        return out

    # -- readback (materialize + gather) ----------------------------------
    def __array__(self, dtype=None, copy=None):
        return self.materialize().__array__(dtype)

    def evaluate(self):
        """Materialize the tree and start draining its cone without
        blocking (see :meth:`DistArray.evaluate`)."""
        from repro.api.futures import evaluate as _evaluate

        return _evaluate(self)

    # -- reductions (np.sum(expr) etc. land here via the protocols) --------
    def _reduce(self, name: str, axis, keepdims: bool) -> "DistArray":
        return self.materialize()._reduce(name, axis, keepdims)

    def sum(self, axis=None, keepdims=False):
        return self._reduce("add", axis, keepdims)

    def min(self, axis=None, keepdims=False):
        return self._reduce("minimum", axis, keepdims)

    def max(self, axis=None, keepdims=False):
        return self._reduce("maximum", axis, keepdims)

    # -- operator sugar (mirrors DistArray) -------------------------------
    def __add__(self, o):
        return _apply(uf.add, self, o)

    def __radd__(self, o):
        return _apply(uf.add, o, self)

    def __sub__(self, o):
        return _apply(uf.subtract, self, o)

    def __rsub__(self, o):
        return _apply(uf.subtract, o, self)

    def __mul__(self, o):
        return _apply(uf.multiply, self, o)

    def __rmul__(self, o):
        return _apply(uf.multiply, o, self)

    def __truediv__(self, o):
        return _apply(uf.divide, self, o)

    def __rtruediv__(self, o):
        return _apply(uf.divide, o, self)

    def __neg__(self):
        return _apply(uf.negative, self)

    def __pow__(self, o):
        return _apply(uf.power, self, o)


def _apply(ufn: UFunc, *args) -> Union["DistArray", Expr]:
    """Apply a ufunc: build an Expr in fusion mode, else record immediately
    into a fresh temporary (DistNumPy behaviour)."""
    coerced = []
    for a in args:
        c = _coerce_operand(a)
        if c is None:
            raise TypeError(f"unsupported operand {type(a)} for {ufn.name}")
        coerced.append(c)
    args = tuple(coerced)
    rt = current_runtime()
    if rt.fusion:
        return Expr(ufn, args)
    shape, dtype = _result_meta(ufn, args)
    out = empty(shape, dtype=dtype)
    rt.record_map(ufn, (out._base, out._view), [_as_operand(a) for a in args])
    return out


class DistArray:
    """An array-view over an array-base (paper §5.1)."""

    __slots__ = ("_base", "_view", "_rt")

    # NumPy defers to us for mixed ndarray/DistArray expressions, and
    # np.<ufunc>/np.<function> calls dispatch through the protocols.
    __array_priority__ = 1000.0
    __array_ufunc__ = _array_ufunc
    __array_function__ = _array_function

    def __init__(self, base: ArrayBase, view: ViewSpec, rt: Runtime):
        self._base = base
        self._view = view
        self._rt = rt

    # -- metadata ----------------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return self._view.vshape

    @property
    def ndim(self) -> int:
        return self._view.ndim

    @property
    def dtype(self) -> np.dtype:
        return self._base.dtype

    @property
    def size(self) -> int:
        return self._view.size

    def __repr__(self):
        return f"DistArray(shape={self.shape}, dtype={self.dtype}, base={self._base.id})"

    # -- views (§5.1: flat two-level hierarchy) ------------------------------
    def _normalize_key(self, key) -> tuple[slice, ...]:
        if not isinstance(key, tuple):
            key = (key,)
        out = []
        it = iter(key)
        for k in it:
            if k is Ellipsis:
                n_rest = sum(1 for x in key if x is not Ellipsis and x is not None)
                out.extend([slice(None)] * (self.ndim - n_rest - len(out)))
                continue
            if isinstance(k, int):
                L = self._view.vshape[len(out)]
                if k < 0:
                    k += L
                out.append(slice(k, k + 1))
            elif isinstance(k, slice):
                out.append(k)
            else:
                raise TypeError(f"unsupported index {k!r}")
        while len(out) < self.ndim:
            out.append(slice(None))
        return tuple(out)

    def __getitem__(self, key) -> "DistArray":
        view = self._view.compose_slice(self._normalize_key(key))
        return DistArray(self._base, view, self._rt)

    def __setitem__(self, key, value) -> None:
        target = self[key]
        tgt = (target._base, target._view)
        if isinstance(value, Expr):
            value.materialize(out=target)
        elif isinstance(value, DistArray):
            if value._base is target._base and value._view != target._view:
                value = value.copy()  # overlapping self-assignment: snapshot
            self._rt.record_map(uf.identity, tgt, [(value._base, value._view)])
        elif isinstance(value, Scalar):
            self._rt.record_fill(tgt, value)
        elif isinstance(value, np.ndarray):
            tmp = array(value)
            self._rt.record_map(uf.identity, tgt, [(tmp._base, tmp._view)])
        else:
            raise TypeError(f"unsupported assignment {type(value)}")

    def copy(self) -> "DistArray":
        out = empty(self.shape, dtype=self.dtype)
        self._rt.record_map(uf.identity, (out._base, out._view), [_as_operand(self)])
        return out

    # -- arithmetic -----------------------------------------------------------
    def __add__(self, o):
        return _apply(uf.add, self, o)

    def __radd__(self, o):
        return _apply(uf.add, o, self)

    def __sub__(self, o):
        return _apply(uf.subtract, self, o)

    def __rsub__(self, o):
        return _apply(uf.subtract, o, self)

    def __mul__(self, o):
        return _apply(uf.multiply, self, o)

    def __rmul__(self, o):
        return _apply(uf.multiply, o, self)

    def __truediv__(self, o):
        return _apply(uf.divide, self, o)

    def __rtruediv__(self, o):
        return _apply(uf.divide, o, self)

    def __pow__(self, o):
        return _apply(uf.power, self, o)

    def __neg__(self):
        return _apply(uf.negative, self)

    def __matmul__(self, o):
        return matmul(self, o)

    def __iadd__(self, o):
        self._rt.record_map(
            uf.add, (self._base, self._view), [_as_operand(self), _as_operand(o)]
        )
        return self

    def __isub__(self, o):
        self._rt.record_map(
            uf.subtract, (self._base, self._view), [_as_operand(self), _as_operand(o)]
        )
        return self

    def __imul__(self, o):
        self._rt.record_map(
            uf.multiply, (self._base, self._view), [_as_operand(self), _as_operand(o)]
        )
        return self

    # -- reductions --------------------------------------------------------
    def _reduce(self, name: str, axis, keepdims: bool) -> "DistArray":
        nd = self.ndim
        if axis is None:
            axes = tuple(range(nd))
        elif isinstance(axis, int):
            axes = (axis % nd,)
        else:
            axes = tuple(a % nd for a in axis)
        if keepdims:
            oshape = tuple(1 if d in axes else s for d, s in enumerate(self.shape))
        else:
            oshape = tuple(s for d, s in enumerate(self.shape) if d not in axes)
        # NumPy promotes bool sums to integer counts (np.sum(a > x) is the
        # counting idiom); min/max of bools stay bool
        rdtype = self.dtype
        if rdtype == np.bool_ and name == "add":
            rdtype = np.dtype(np.int64)
        out = empty(oshape, dtype=rdtype)
        self._rt.record_reduce(
            name, (out._base, out._view), (self._base, self._view), axes, keepdims
        )
        return out

    def sum(self, axis=None, keepdims=False):
        return self._reduce("add", axis, keepdims)

    def min(self, axis=None, keepdims=False):
        return self._reduce("minimum", axis, keepdims)

    def max(self, axis=None, keepdims=False):
        return self._reduce("maximum", axis, keepdims)

    # -- demand-driven evaluation (futures surface) ---------------------------
    def evaluate(self) -> "object":
        """Start draining this array's dependency cone without blocking;
        returns a :class:`repro.api.futures.ArrayFuture` (JAX-style
        async dispatch — recording continues while workers drain)."""
        from repro.api.futures import evaluate as _evaluate

        return _evaluate(self)

    def block_until_ready(self) -> "DistArray":
        """Block until every pending operation this array depends on has
        executed (its dependency cone under ``sync="demand"``, the whole
        graph under ``sync="barrier"``); returns self, JAX-style."""
        return self.evaluate().block_until_ready()

    # -- readback (flush triggers, §5.6) -------------------------------------
    def __array__(self, dtype=None, copy=None):
        arr = self._rt.gather(self._base, self._view)
        return arr.astype(dtype) if dtype is not None else arr

    def to_numpy(self) -> np.ndarray:
        return self.__array__()

    def item(self) -> float:
        return self.__array__().reshape(-1)[0].item()

    def __float__(self):
        return float(self.item())

    def __bool__(self):
        return bool(self.__array__().all())

    def _cmp_scalar(self, other, op):
        return op(float(self), float(other))

    def __lt__(self, other):
        if self.size == 1 and isinstance(other, Scalar + (DistArray,)):
            return self._cmp_scalar(other, lambda a, b: a < b)
        return _apply(uf.less, self, other)

    def __gt__(self, other):
        if self.size == 1 and isinstance(other, Scalar + (DistArray,)):
            return self._cmp_scalar(other, lambda a, b: a > b)
        return _apply(uf.greater, self, other)


# ---------------------------------------------------------------------------
# creation routines (the paper's only API delta: ``dist=`` flag)
# ---------------------------------------------------------------------------

def array(data, dtype=None, dist: bool = True, block_shape=None) -> DistArray:
    rt = current_runtime()
    np_data = np.asarray(data, dtype=dtype)
    base = rt.new_base(np_data.shape, np_data.dtype, block_shape)
    rt.scatter(base, np_data)
    return DistArray(base, ViewSpec.full(np_data.shape), rt)


def empty(shape, dtype=np.float64, dist: bool = True, block_shape=None) -> DistArray:
    rt = current_runtime()
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    base = rt.new_base(shape, dtype, block_shape)
    rt.fill_base(base, 0)  # deterministic contents; blocks must exist
    return DistArray(base, ViewSpec.full(shape), rt)


def zeros(shape, dtype=np.float64, dist: bool = True, block_shape=None) -> DistArray:
    return full(shape, 0, dtype, dist, block_shape)


def ones(shape, dtype=np.float64, dist: bool = True, block_shape=None) -> DistArray:
    return full(shape, 1, dtype, dist, block_shape)


def full(shape, value, dtype=np.float64, dist=True, block_shape=None) -> DistArray:
    rt = current_runtime()
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    base = rt.new_base(shape, dtype, block_shape)
    rt.fill_base(base, value)
    return DistArray(base, ViewSpec.full(shape), rt)


def arange(n, dtype=np.float64, block_shape=None) -> DistArray:
    return array(np.arange(n, dtype=dtype), block_shape=block_shape)


def random(shape, seed=0, dtype=np.float64, block_shape=None) -> DistArray:
    rng = np.random.default_rng(seed)
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    return array(rng.random(shape).astype(dtype), block_shape=block_shape)


# ---------------------------------------------------------------------------
# module-level ufuncs — generated from the registry (single dispatch
# table: adding a primitive to ufunc.py adds it here and to np.<ufunc>
# dispatch in one step)
# ---------------------------------------------------------------------------

def _module_ufunc(u: UFunc):
    def f(*args):
        if len(args) != u.nin:
            raise TypeError(f"{u.name} expects {u.nin} operand(s), got {len(args)}")
        return _apply(u, *args)

    f.__name__ = u.name
    f.__qualname__ = u.name
    f.__doc__ = (
        f"Record ``{u.name}`` lazily on DistArrays (generated from the "
        f"ufunc registry; ``np.{u.name}`` on DistArray operands is the "
        f"canonical spelling)."
    )
    return f


_GENERATED_UFUNCS = [n for n in uf.UFUNCS if n != "identity"]
for _name in _GENERATED_UFUNCS:
    globals()[_name] = _module_ufunc(uf.UFUNCS[_name])


# ---------------------------------------------------------------------------
# linalg / data movement
# ---------------------------------------------------------------------------

def matmul(a, b, trans_a=False, trans_b=False) -> DistArray:
    rt = current_runtime()
    a, b = _coerce_operand(a), _coerce_operand(b)
    a = a.materialize() if isinstance(a, Expr) else a
    b = b.materialize() if isinstance(b, Expr) else b
    M = a.shape[1] if trans_a else a.shape[0]
    Ka = a.shape[0] if trans_a else a.shape[1]
    Kb = b.shape[1] if trans_b else b.shape[0]
    N = b.shape[0] if trans_b else b.shape[1]
    if Ka != Kb:
        raise ValueError(f"matmul shape mismatch {a.shape} @ {b.shape}")
    out = empty((M, N), dtype=np.result_type(a.dtype, b.dtype))
    rt.record_matmul(
        (out._base, out._view),
        (a._base, a._view),
        (b._base, b._view),
        trans_a,
        trans_b,
    )
    return out


def roll(a, shift: int, axis: int = 0) -> DistArray:
    """np.roll equivalent: two strided copies (used by the LBM streaming
    step).  C[..., s:, ...] = A[..., :-s, ...]; C[..., :s, ...] = A[..., n-s:, ...]."""
    a = _coerce_operand(a)
    a = a.materialize() if isinstance(a, Expr) else a
    n = a.shape[axis]
    s = shift % n
    out = empty(a.shape, dtype=a.dtype)
    if s == 0:
        out[...] = a
        return out

    def sl(lo, hi):
        key = [slice(None)] * a.ndim
        key[axis] = slice(lo, hi)
        return tuple(key)

    out[sl(s, n)] = a[sl(0, n - s)]
    out[sl(0, s)] = a[sl(n - s, n)]
    return out


# ---------------------------------------------------------------------------
# __array_function__ handlers: the np-namespace spellings of the
# reductions / data movement above
# ---------------------------------------------------------------------------

def _as_lazy(x):
    c = _coerce_operand(x)
    if c is None:
        raise TypeError(f"unsupported operand {type(x)}")
    return c.materialize() if isinstance(c, Expr) else c


@_implements(np.sum)
def _np_sum(a, axis=None, dtype=None, out=None, keepdims=False, **kw):
    if dtype is not None or out is not None or kw:
        raise TypeError("np.sum on DistArray supports only axis= and keepdims=")
    return _as_lazy(a)._reduce("add", axis, keepdims)


@_implements(np.min, np.amin)
def _np_min(a, axis=None, out=None, keepdims=False, **kw):
    if out is not None or kw:
        raise TypeError("np.min on DistArray supports only axis= and keepdims=")
    return _as_lazy(a)._reduce("minimum", axis, keepdims)


@_implements(np.max, np.amax)
def _np_max(a, axis=None, out=None, keepdims=False, **kw):
    if out is not None or kw:
        raise TypeError("np.max on DistArray supports only axis= and keepdims=")
    return _as_lazy(a)._reduce("maximum", axis, keepdims)


@_implements(np.where)
def _np_where(condition, x=None, y=None):
    if x is None or y is None:
        raise TypeError("np.where(cond) without x/y is eager; unsupported on DistArray")
    return _apply(uf.where, condition, x, y)


@_implements(np.roll)
def _np_roll(a, shift, axis=None):
    if axis is None:
        raise TypeError("np.roll on DistArray requires an explicit axis")
    return roll(a, shift, axis)


@_implements(np.matmul)
def _np_matmul(a, b, **kw):
    if kw:
        raise TypeError("np.matmul on DistArray supports no keyword arguments")
    return matmul(a, b)


__all__ = [
    "DistArray",
    "array",
    "empty",
    "zeros",
    "ones",
    "full",
    "arange",
    "random",
    "matmul",
    "roll",
    *_GENERATED_UFUNCS,
]
