"""User-facing distributed arrays — the DistNumPy API surface (paper §5).

``array(..., dist=True)`` etc. mirror the paper's only API difference from
NumPy.  All operations on :class:`DistArray` are recorded lazily into the
active :class:`~repro.core.engine.Runtime`; reading data back (``__array__``,
``item``, comparisons) triggers an operation flush (§5.6).

When the runtime is created with ``fusion=True``, elementwise expressions
build :class:`Expr` trees that are merged into a single joint operation at
materialization — the paper's §7 "merge calls to ufuncs" future work,
implemented here as a beyond-paper optimization.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from . import ufunc as uf
from .blocks import ViewSpec
from .engine import ArrayBase, Runtime, current_runtime
from .ufunc import UFunc

__all__ = [
    "DistArray",
    "array",
    "empty",
    "zeros",
    "ones",
    "full",
    "arange",
    "random",
    "add",
    "subtract",
    "multiply",
    "divide",
    "exp",
    "log",
    "sqrt",
    "square",
    "absolute",
    "maximum",
    "minimum",
    "greater",
    "less",
    "where",
    "matmul",
    "dsum",
    "dmin",
    "dmax",
    "roll",
]

Scalar = (int, float, complex, np.integer, np.floating, np.complexfloating)


def _as_operand(x):
    """DistArray -> (base, view); Expr -> materialized temp; scalar -> tag."""
    if isinstance(x, DistArray):
        return (x._base, x._view)
    if isinstance(x, Expr):
        return _as_operand(x.materialize())
    if isinstance(x, Scalar):
        return ("c", x)
    raise TypeError(f"unsupported operand {type(x)}")


def _result_meta(args) -> tuple[tuple[int, ...], np.dtype]:
    shapes, dtypes = [], []
    for a in args:
        if isinstance(a, (DistArray, Expr)):
            shapes.append(a.shape)
            dtypes.append(a.dtype)
        else:
            dtypes.append(np.dtype(type(a)) if not isinstance(a, complex) else np.dtype(complex))
    shape = np.broadcast_shapes(*shapes) if shapes else ()
    dtype = np.result_type(*dtypes)
    return tuple(shape), dtype


class Expr:
    """Unevaluated elementwise expression (fusion mode)."""

    __slots__ = ("ufunc", "args", "shape", "dtype")

    def __init__(self, ufunc: UFunc, args: tuple):
        self.ufunc = ufunc
        self.args = args
        self.shape, self.dtype = _result_meta(args)

    # -- fusion ---------------------------------------------------------
    def _collect(self, leaves: list) -> object:
        """Return a spec tree of ('leaf', idx) / ('const', v) / (ufunc, specs)."""
        specs = []
        for a in self.args:
            if isinstance(a, Expr):
                specs.append(a._collect(leaves))
            elif isinstance(a, DistArray):
                leaves.append(a)
                specs.append(("leaf", len(leaves) - 1))
            else:
                specs.append(("const", a))
        return (self.ufunc, tuple(specs))

    def _cost_parts(self) -> tuple[int, float]:
        """(#ops, heavy-compute surplus) of the tree."""
        n, heavy = 1, max(0.0, self.ufunc.cost - 1.0)
        for a in self.args:
            if isinstance(a, Expr):
                sn, sh = a._cost_parts()
                n += sn
                heavy += sh
        return n, heavy

    def fused_cost(self, n_leaves: int) -> float:
        """Per-element cost of the fused op.  Plain ufunc chains are
        memory-bound: a chain of k binary ufuncs moves ~3k·N bytes
        (2 reads + 1 write each), the fused version (L+1)·N — that ratio is
        the fusion win (HBM round-trip avoidance on TPU).  Heavy
        (transcendental) compute stays additive."""
        _, heavy = self._cost_parts()
        return max(1.0, (n_leaves + 1) / 3.0) + heavy

    def materialize(self, out: Optional["DistArray"] = None) -> "DistArray":
        """Record ONE joint operation for the whole tree (§7 fusion)."""
        rt = current_runtime()
        leaves: list[DistArray] = []
        spec = self._collect(leaves)
        if out is not None and any(l._base is out._base for l in leaves):
            # output aliases an input base: a single joint operation would
            # let one fragment's write race another fragment's read.  Go
            # through a fresh temporary (same rule NumPy's ufuncs need).
            tmp = self.materialize(None)
            rt.record_map(
                uf.identity, (out._base, out._view), [(tmp._base, tmp._view)]
            )
            return out

        def run(*arrays):
            return uf.eval_tree(spec, arrays, lambda u: u.fn)

        fused = UFunc(
            name=f"fused[{self.ufunc.name}x{len(leaves)}]",
            fn=run,
            nin=len(leaves),
            cost=self.fused_cost(len(leaves)),
            tree=spec,
        )
        if out is None:
            out = empty(self.shape, dtype=self.dtype)
        rt.record_map(fused, (out._base, out._view), [(l._base, l._view) for l in leaves])
        return out

    # -- readback (materialize + gather) ----------------------------------
    def __array__(self, dtype=None, copy=None):
        return self.materialize().__array__(dtype)

    # -- operator sugar (mirrors DistArray) -------------------------------
    def __add__(self, o):
        return _apply(uf.add, self, o)

    def __radd__(self, o):
        return _apply(uf.add, o, self)

    def __sub__(self, o):
        return _apply(uf.subtract, self, o)

    def __rsub__(self, o):
        return _apply(uf.subtract, o, self)

    def __mul__(self, o):
        return _apply(uf.multiply, self, o)

    def __rmul__(self, o):
        return _apply(uf.multiply, o, self)

    def __truediv__(self, o):
        return _apply(uf.divide, self, o)

    def __rtruediv__(self, o):
        return _apply(uf.divide, o, self)

    def __neg__(self):
        return _apply(uf.negative, self)

    def __pow__(self, o):
        return _apply(uf.power, self, o)


def _apply(ufn: UFunc, *args) -> Union["DistArray", Expr]:
    """Apply a ufunc: build an Expr in fusion mode, else record immediately
    into a fresh temporary (DistNumPy behaviour)."""
    rt = current_runtime()
    if rt.fusion:
        return Expr(ufn, args)
    shape, dtype = _result_meta(args)
    out = empty(shape, dtype=dtype)
    rt.record_map(ufn, (out._base, out._view), [_as_operand(a) for a in args])
    return out


class DistArray:
    """An array-view over an array-base (paper §5.1)."""

    __slots__ = ("_base", "_view", "_rt")

    def __init__(self, base: ArrayBase, view: ViewSpec, rt: Runtime):
        self._base = base
        self._view = view
        self._rt = rt

    # -- metadata ----------------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return self._view.vshape

    @property
    def ndim(self) -> int:
        return self._view.ndim

    @property
    def dtype(self) -> np.dtype:
        return self._base.dtype

    @property
    def size(self) -> int:
        return self._view.size

    def __repr__(self):
        return f"DistArray(shape={self.shape}, dtype={self.dtype}, base={self._base.id})"

    # -- views (§5.1: flat two-level hierarchy) ------------------------------
    def _normalize_key(self, key) -> tuple[slice, ...]:
        if not isinstance(key, tuple):
            key = (key,)
        out = []
        it = iter(key)
        for k in it:
            if k is Ellipsis:
                n_rest = sum(1 for x in key if x is not Ellipsis and x is not None)
                out.extend([slice(None)] * (self.ndim - n_rest - len(out)))
                continue
            if isinstance(k, int):
                L = self._view.vshape[len(out)]
                if k < 0:
                    k += L
                out.append(slice(k, k + 1))
            elif isinstance(k, slice):
                out.append(k)
            else:
                raise TypeError(f"unsupported index {k!r}")
        while len(out) < self.ndim:
            out.append(slice(None))
        return tuple(out)

    def __getitem__(self, key) -> "DistArray":
        view = self._view.compose_slice(self._normalize_key(key))
        return DistArray(self._base, view, self._rt)

    def __setitem__(self, key, value) -> None:
        target = self[key]
        tgt = (target._base, target._view)
        if isinstance(value, Expr):
            value.materialize(out=target)
        elif isinstance(value, DistArray):
            if value._base is target._base and value._view != target._view:
                value = value.copy()  # overlapping self-assignment: snapshot
            self._rt.record_map(uf.identity, tgt, [(value._base, value._view)])
        elif isinstance(value, Scalar):
            self._rt.record_fill(tgt, value)
        elif isinstance(value, np.ndarray):
            tmp = array(value)
            self._rt.record_map(uf.identity, tgt, [(tmp._base, tmp._view)])
        else:
            raise TypeError(f"unsupported assignment {type(value)}")

    def copy(self) -> "DistArray":
        out = empty(self.shape, dtype=self.dtype)
        self._rt.record_map(uf.identity, (out._base, out._view), [_as_operand(self)])
        return out

    # -- arithmetic -----------------------------------------------------------
    def __add__(self, o):
        return _apply(uf.add, self, o)

    def __radd__(self, o):
        return _apply(uf.add, o, self)

    def __sub__(self, o):
        return _apply(uf.subtract, self, o)

    def __rsub__(self, o):
        return _apply(uf.subtract, o, self)

    def __mul__(self, o):
        return _apply(uf.multiply, self, o)

    def __rmul__(self, o):
        return _apply(uf.multiply, o, self)

    def __truediv__(self, o):
        return _apply(uf.divide, self, o)

    def __rtruediv__(self, o):
        return _apply(uf.divide, o, self)

    def __pow__(self, o):
        return _apply(uf.power, self, o)

    def __neg__(self):
        return _apply(uf.negative, self)

    def __iadd__(self, o):
        self._rt.record_map(
            uf.add, (self._base, self._view), [_as_operand(self), _as_operand(o)]
        )
        return self

    def __isub__(self, o):
        self._rt.record_map(
            uf.subtract, (self._base, self._view), [_as_operand(self), _as_operand(o)]
        )
        return self

    def __imul__(self, o):
        self._rt.record_map(
            uf.multiply, (self._base, self._view), [_as_operand(self), _as_operand(o)]
        )
        return self

    # -- reductions --------------------------------------------------------
    def _reduce(self, name: str, axis, keepdims: bool) -> "DistArray":
        nd = self.ndim
        if axis is None:
            axes = tuple(range(nd))
        elif isinstance(axis, int):
            axes = (axis % nd,)
        else:
            axes = tuple(a % nd for a in axis)
        if keepdims:
            oshape = tuple(1 if d in axes else s for d, s in enumerate(self.shape))
        else:
            oshape = tuple(s for d, s in enumerate(self.shape) if d not in axes)
        out = empty(oshape, dtype=self.dtype)
        self._rt.record_reduce(
            name, (out._base, out._view), (self._base, self._view), axes, keepdims
        )
        return out

    def sum(self, axis=None, keepdims=False):
        return self._reduce("add", axis, keepdims)

    def min(self, axis=None, keepdims=False):
        return self._reduce("minimum", axis, keepdims)

    def max(self, axis=None, keepdims=False):
        return self._reduce("maximum", axis, keepdims)

    # -- readback (flush triggers, §5.6) -------------------------------------
    def __array__(self, dtype=None, copy=None):
        arr = self._rt.gather(self._base, self._view)
        return arr.astype(dtype) if dtype is not None else arr

    def to_numpy(self) -> np.ndarray:
        return self.__array__()

    def item(self) -> float:
        return self.__array__().reshape(-1)[0].item()

    def __float__(self):
        return float(self.item())

    def __bool__(self):
        return bool(self.__array__().all())

    def _cmp_scalar(self, other, op):
        return op(float(self), float(other))

    def __lt__(self, other):
        if self.size == 1 and isinstance(other, Scalar + (DistArray,)):
            return self._cmp_scalar(other, lambda a, b: a < b)
        return _apply(uf.less, self, other)

    def __gt__(self, other):
        if self.size == 1 and isinstance(other, Scalar + (DistArray,)):
            return self._cmp_scalar(other, lambda a, b: a > b)
        return _apply(uf.greater, self, other)


# ---------------------------------------------------------------------------
# creation routines (the paper's only API delta: ``dist=`` flag)
# ---------------------------------------------------------------------------

def array(data, dtype=None, dist: bool = True, block_shape=None) -> DistArray:
    rt = current_runtime()
    np_data = np.asarray(data, dtype=dtype)
    base = rt.new_base(np_data.shape, np_data.dtype, block_shape)
    rt.scatter(base, np_data)
    return DistArray(base, ViewSpec.full(np_data.shape), rt)


def empty(shape, dtype=np.float64, dist: bool = True, block_shape=None) -> DistArray:
    rt = current_runtime()
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    base = rt.new_base(shape, dtype, block_shape)
    rt.fill_base(base, 0)  # deterministic contents; blocks must exist
    return DistArray(base, ViewSpec.full(shape), rt)


def zeros(shape, dtype=np.float64, dist: bool = True, block_shape=None) -> DistArray:
    return full(shape, 0, dtype, dist, block_shape)


def ones(shape, dtype=np.float64, dist: bool = True, block_shape=None) -> DistArray:
    return full(shape, 1, dtype, dist, block_shape)


def full(shape, value, dtype=np.float64, dist=True, block_shape=None) -> DistArray:
    rt = current_runtime()
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    base = rt.new_base(shape, dtype, block_shape)
    rt.fill_base(base, value)
    return DistArray(base, ViewSpec.full(shape), rt)


def arange(n, dtype=np.float64, block_shape=None) -> DistArray:
    return array(np.arange(n, dtype=dtype), block_shape=block_shape)


def random(shape, seed=0, dtype=np.float64, block_shape=None) -> DistArray:
    rng = np.random.default_rng(seed)
    shape = (shape,) if isinstance(shape, int) else tuple(shape)
    return array(rng.random(shape).astype(dtype), block_shape=block_shape)


# ---------------------------------------------------------------------------
# module-level ufuncs / linalg / reductions
# ---------------------------------------------------------------------------

def add(a, b):
    return _apply(uf.add, a, b)


def subtract(a, b):
    return _apply(uf.subtract, a, b)


def multiply(a, b):
    return _apply(uf.multiply, a, b)


def divide(a, b):
    return _apply(uf.divide, a, b)


def exp(a):
    return _apply(uf.exp, a)


def log(a):
    return _apply(uf.log, a)


def sqrt(a):
    return _apply(uf.sqrt, a)


def square(a):
    return _apply(uf.square, a)


def absolute(a):
    return _apply(uf.absolute, a)


def maximum(a, b):
    return _apply(uf.maximum, a, b)


def minimum(a, b):
    return _apply(uf.minimum, a, b)


def greater(a, b):
    return _apply(uf.greater, a, b)


def less(a, b):
    return _apply(uf.less, a, b)


def where(c, a, b):
    return _apply(uf.where, c, a, b)


def dsum(a, axis=None, keepdims=False):
    a = a.materialize() if isinstance(a, Expr) else a
    return a.sum(axis, keepdims)


def dmin(a, axis=None, keepdims=False):
    a = a.materialize() if isinstance(a, Expr) else a
    return a.min(axis, keepdims)


def dmax(a, axis=None, keepdims=False):
    a = a.materialize() if isinstance(a, Expr) else a
    return a.max(axis, keepdims)


def matmul(a, b, trans_a=False, trans_b=False) -> DistArray:
    rt = current_runtime()
    a = a.materialize() if isinstance(a, Expr) else a
    b = b.materialize() if isinstance(b, Expr) else b
    M = a.shape[1] if trans_a else a.shape[0]
    Ka = a.shape[0] if trans_a else a.shape[1]
    Kb = b.shape[1] if trans_b else b.shape[0]
    N = b.shape[0] if trans_b else b.shape[1]
    if Ka != Kb:
        raise ValueError(f"matmul shape mismatch {a.shape} @ {b.shape}")
    out = empty((M, N), dtype=np.result_type(a.dtype, b.dtype))
    rt.record_matmul(
        (out._base, out._view),
        (a._base, a._view),
        (b._base, b._view),
        trans_a,
        trans_b,
    )
    return out


def roll(a: DistArray, shift: int, axis: int = 0) -> DistArray:
    """np.roll equivalent: two strided copies (used by the LBM streaming
    step).  C[..., s:, ...] = A[..., :-s, ...]; C[..., :s, ...] = A[..., n-s:, ...]."""
    a = a.materialize() if isinstance(a, Expr) else a
    n = a.shape[axis]
    s = shift % n
    out = empty(a.shape, dtype=a.dtype)
    if s == 0:
        out[...] = a
        return out

    def sl(lo, hi):
        key = [slice(None)] * a.ndim
        key[axis] = slice(lo, hi)
        return tuple(key)

    out[sl(s, n)] = a[sl(0, n - s)]
    out[sl(0, s)] = a[sl(n - s, n)]
    return out
