"""Universal-function registry (paper §5.3) — the single dispatch table.

A ufunc is a vectorized scalar function applied independently to every
element of the involved array-views; the engine translates a ufunc
application into per-sub-view-block operations.  ``cost`` is the relative
per-element compute weight used by the timeline model (memory-bound ufuncs
≈ 1, transcendentals higher — calibrated against NumPy throughput ratios).

Every primitive is registered once here and every consumer derives from
this table:

* the NumPy array protocol on :class:`~repro.core.darray.DistArray`
  resolves ``np.add`` → :data:`NP_TO_UFUNC` → :class:`UFunc`;
* ``repro.core.darray`` generates its module-level functions from
  :data:`UFUNCS`;
* alternative compute backends retarget by name (or re-trace fused
  expression trees via :func:`eval_tree`).

``out_dtype`` carries a fixed result dtype for primitives whose output
dtype is not the promoted input dtype — the comparisons return
``bool``, exactly as NumPy's do.  The timeline cost model is untouched
by dtype routing (costs stay per-element).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

__all__ = [
    "UFunc",
    "UFUNCS",
    "NP_TO_UFUNC",
    "get_ufunc",
    "result_dtype",
    "eval_tree",
]


@dataclass(frozen=True)
class UFunc:
    name: str
    fn: Callable
    nin: int
    cost: float = 1.0  # relative per-element cost vs. a copy
    reduceable: bool = False
    # fused ufuncs carry their expression tree (see eval_tree) so that
    # alternative compute backends (repro.exec JaxBackend) can re-trace the
    # expression with their own primitive implementations instead of
    # calling the opaque NumPy closure.
    tree: object = None
    # fixed result dtype (e.g. bool for comparisons); None means NumPy
    # promotion of the input dtypes
    out_dtype: object = None

    def __call__(self, *args):
        return self.fn(*args)


def result_dtype(ufunc: "UFunc", dtypes) -> np.dtype:
    """Result dtype of applying ``ufunc`` to operands of ``dtypes`` —
    the ufunc's fixed ``out_dtype`` if it has one, NumPy promotion
    otherwise."""
    if ufunc.out_dtype is not None:
        return np.dtype(ufunc.out_dtype)
    return np.result_type(*dtypes)


def eval_tree(spec, arrays, impl: Callable[["UFunc"], Callable]):
    """Evaluate a fused-expression spec tree.

    ``spec`` nodes are ``("leaf", i)`` (the i-th input array),
    ``("const", v)`` (a scalar), or ``(UFunc, (subspec, ...))``.  ``impl``
    maps each primitive :class:`UFunc` to a callable — ``lambda u: u.fn``
    reproduces the NumPy semantics; a jnp table retargets the same tree to
    XLA."""
    tag = spec[0]
    if tag == "leaf":
        return arrays[spec[1]]
    if tag == "const":
        return spec[1]
    f, subs = spec
    return impl(f)(*[eval_tree(s, arrays, impl) for s in subs])


UFUNCS: dict[str, UFunc] = {}

# NumPy ufunc object -> our UFunc: the table behind DistArray's
# ``__array_ufunc__`` (np.add(a, b) records uf.add lazily)
NP_TO_UFUNC: dict[np.ufunc, UFunc] = {}


def _reg(
    name,
    fn,
    nin,
    cost=1.0,
    reduceable=False,
    np_ufunc: Optional[np.ufunc] = None,
    out_dtype=None,
):
    uf = UFunc(name, fn, nin, cost, reduceable, out_dtype=out_dtype)
    UFUNCS[name] = uf
    if np_ufunc is not None:
        NP_TO_UFUNC[np_ufunc] = uf
    return uf


identity = _reg("identity", lambda x: x, 1, 1.0)
add = _reg("add", np.add, 2, 1.0, reduceable=True, np_ufunc=np.add)
subtract = _reg("subtract", np.subtract, 2, 1.0, np_ufunc=np.subtract)
multiply = _reg("multiply", np.multiply, 2, 1.0, reduceable=True, np_ufunc=np.multiply)
divide = _reg("divide", np.divide, 2, 2.0, np_ufunc=np.divide)
power = _reg("power", np.power, 2, 8.0, np_ufunc=np.power)
negative = _reg("negative", np.negative, 1, 1.0, np_ufunc=np.negative)
absolute = _reg("absolute", np.absolute, 1, 1.0, np_ufunc=np.absolute)
exp = _reg("exp", np.exp, 1, 4.0, np_ufunc=np.exp)
log = _reg("log", np.log, 1, 4.0, np_ufunc=np.log)
sqrt = _reg("sqrt", np.sqrt, 1, 2.0, np_ufunc=np.sqrt)
square = _reg("square", np.square, 1, 1.0, np_ufunc=np.square)
maximum = _reg("maximum", np.maximum, 2, 1.0, reduceable=True, np_ufunc=np.maximum)
minimum = _reg("minimum", np.minimum, 2, 1.0, reduceable=True, np_ufunc=np.minimum)
greater = _reg("greater", np.greater, 2, 1.0, np_ufunc=np.greater, out_dtype=np.bool_)
less = _reg("less", np.less, 2, 1.0, np_ufunc=np.less, out_dtype=np.bool_)
where = _reg("where", np.where, 3, 1.0)  # np.where is not a np.ufunc

_REDUCE_INIT = {"add": 0.0, "multiply": 1.0, "maximum": -np.inf, "minimum": np.inf}
_REDUCE_NP = {
    "add": np.add.reduce,
    "multiply": np.multiply.reduce,
    "maximum": np.maximum.reduce,
    "minimum": np.minimum.reduce,
}


def get_ufunc(name: str) -> UFunc:
    return UFUNCS[name]


def reduce_fn(name: str):
    return _REDUCE_NP[name]
