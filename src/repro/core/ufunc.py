"""Universal-function registry (paper §5.3).

A ufunc is a vectorized scalar function applied independently to every
element of the involved array-views; the engine translates a ufunc
application into per-sub-view-block operations.  ``cost`` is the relative
per-element compute weight used by the timeline model (memory-bound ufuncs
≈ 1, transcendentals higher — calibrated against NumPy throughput ratios).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

__all__ = ["UFunc", "UFUNCS", "get_ufunc", "eval_tree"]


@dataclass(frozen=True)
class UFunc:
    name: str
    fn: Callable
    nin: int
    cost: float = 1.0  # relative per-element cost vs. a copy
    reduceable: bool = False
    # fused ufuncs carry their expression tree (see eval_tree) so that
    # alternative compute backends (repro.exec JaxBackend) can re-trace the
    # expression with their own primitive implementations instead of
    # calling the opaque NumPy closure.
    tree: object = None

    def __call__(self, *args):
        return self.fn(*args)


def eval_tree(spec, arrays, impl: Callable[["UFunc"], Callable]):
    """Evaluate a fused-expression spec tree.

    ``spec`` nodes are ``("leaf", i)`` (the i-th input array),
    ``("const", v)`` (a scalar), or ``(UFunc, (subspec, ...))``.  ``impl``
    maps each primitive :class:`UFunc` to a callable — ``lambda u: u.fn``
    reproduces the NumPy semantics; a jnp table retargets the same tree to
    XLA."""
    tag = spec[0]
    if tag == "leaf":
        return arrays[spec[1]]
    if tag == "const":
        return spec[1]
    f, subs = spec
    return impl(f)(*[eval_tree(s, arrays, impl) for s in subs])


UFUNCS: dict[str, UFunc] = {}


def _reg(name, fn, nin, cost=1.0, reduceable=False):
    uf = UFunc(name, fn, nin, cost, reduceable)
    UFUNCS[name] = uf
    return uf


identity = _reg("identity", lambda x: x, 1, 1.0)
add = _reg("add", np.add, 2, 1.0, reduceable=True)
subtract = _reg("subtract", np.subtract, 2, 1.0)
multiply = _reg("multiply", np.multiply, 2, 1.0, reduceable=True)
divide = _reg("divide", np.divide, 2, 2.0)
power = _reg("power", np.power, 2, 8.0)
negative = _reg("negative", np.negative, 1, 1.0)
absolute = _reg("absolute", np.absolute, 1, 1.0)
exp = _reg("exp", np.exp, 1, 4.0)
log = _reg("log", np.log, 1, 4.0)
sqrt = _reg("sqrt", np.sqrt, 1, 2.0)
square = _reg("square", np.square, 1, 1.0)
maximum = _reg("maximum", np.maximum, 2, 1.0, reduceable=True)
minimum = _reg("minimum", np.minimum, 2, 1.0, reduceable=True)
greater = _reg("greater", lambda a, b: np.greater(a, b).astype(np.float64), 2, 1.0)
less = _reg("less", lambda a, b: np.less(a, b).astype(np.float64), 2, 1.0)
where = _reg("where", np.where, 3, 1.0)

_REDUCE_INIT = {"add": 0.0, "multiply": 1.0, "maximum": -np.inf, "minimum": np.inf}
_REDUCE_NP = {
    "add": np.add.reduce,
    "multiply": np.multiply.reduce,
    "maximum": np.maximum.reduce,
    "minimum": np.minimum.reduce,
}


def get_ufunc(name: str) -> UFunc:
    return UFUNCS[name]


def reduce_fn(name: str):
    return _REDUCE_NP[name]
