"""Dependency system (paper §5.7).

Two interchangeable implementations:

* :class:`DependencySystem` — the paper's §5.7.2 heuristic: one ordered
  *dependency-list* of access-nodes per base-block, a reference counter per
  operation-node, and an O(1) ready queue.  Insertion of an operation only
  scans the lists of the blocks it touches.
* :class:`FullDAG` — the §5.7 straw-man that compares every new node against
  every node in the graph (O(n) insert, O(n²) build).  Kept as a reference
  oracle for tests and for the overhead benchmark that motivates the
  heuristic.

Conflict rule: two access-nodes conflict iff they touch the same base-block,
at least one is a write, and their per-dimension index regions intersect.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Hashable, Iterable, Optional

from repro.obs import collector as _obs

from .blocks import Region

__all__ = [
    "AccessNode",
    "OperationNode",
    "DependencySystem",
    "FullDAG",
    "regions_overlap",
    "producer_cone",
    "cone_access_keys",
    "cone_base_ids",
    "cones_conflict",
    "cone_region_footprint",
    "region_footprints_conflict",
]

_op_counter = itertools.count()

# Operation kinds.  COMM nodes are prioritized by the scheduler (§5.7
# invariant 2/3); COMPUTE nodes are everything else.
COMM = "comm"
COMPUTE = "compute"


def regions_overlap(a: Optional[Region], b: Optional[Region]) -> bool:
    """Per-dimension interval intersection — THE conflict geometry, shared
    by :meth:`AccessNode.conflicts` and the plan-stage passes.  ``None``
    means the whole block (always overlaps)."""
    if a is None or b is None:
        return True
    for (a0, a1), (b0, b1) in zip(a, b):
        if a1 <= b0 or b1 <= a0:
            return False
    return True


@dataclass
class AccessNode:
    """Memory access to one sub-view-block (paper fig. 7)."""

    key: Hashable  # (base_id, block_coord) — identifies the dependency list
    region: Optional[Region]  # None = whole block
    write: bool
    op: "OperationNode" = field(repr=False, default=None)
    # access-nodes that were inserted *later* and conflict with this one;
    # their ops get a refcount decrement when this access is removed.
    dependents: list["AccessNode"] = field(default_factory=list, repr=False)
    removed: bool = False

    def conflicts(self, other: "AccessNode") -> bool:
        if not (self.write or other.write):
            return False
        return regions_overlap(self.region, other.region)


@dataclass
class OperationNode:
    """A schedulable operation over a set of sub-view-blocks (paper fig. 7).

    ``kind`` is COMM for data transfers and COMPUTE for local work; the
    scheduler's priority rule keys on it.  ``payload`` carries whatever the
    execution backend needs (ufunc + fragments, transfer descriptor, ...).
    ``procs`` is the set of participating process ranks; ``cost`` a model
    duration in seconds for the timeline simulator; ``bytes`` the transfer
    size for comm nodes.
    """

    kind: str
    payload: object
    procs: tuple[int, ...]
    cost: float = 0.0
    nbytes: int = 0
    label: str = ""
    uid: int = field(default_factory=lambda: next(_op_counter))
    accesses: list[AccessNode] = field(default_factory=list, repr=False)
    refcount: int = 0
    executed: bool = False
    # insertion sequence within the owning dependency system — the
    # program-order key (uid is creation order, which diverges for
    # plan-stage merged nodes inserted mid-list on rebuild)
    seq: int = 0

    def add_access(self, acc: AccessNode) -> None:
        acc.op = self
        self.accesses.append(acc)


def producer_cone(
    ops: list[OperationNode], targets: set
) -> tuple[list[OperationNode], list[OperationNode]]:
    """Split a program-ordered pending-operation list into the
    *dependency cone* of ``targets`` and the untouched remainder.

    ``targets`` holds base ids (ints — every block of that base) and/or
    exact ``(base_id, block)`` access keys (a sub-view readback forces
    only the blocks it touches).

    The cone is the transitive predecessor closure — under the §5.7
    conflict rule, at access-key granularity — of every pending **write**
    to a targeted block: exactly the operations that must execute
    before those blocks are readable.  The closure is computed by one
    reverse walk that propagates two key sets:

    * ``need_any``  — keys *written* by a marked operation: any earlier
      access (read or write) to such a key conflicts, so its operation
      joins the cone.  This also captures anti-dependencies: a pending
      read of a target base recorded *before* a later write to it is
      pulled in, so it observes the program-order value, not the
      post-cone one.
    * ``need_write`` — keys *read* by a marked operation: an earlier
      write to such a key is the producer of the value read.

    Both returned lists preserve program order, so draining the cone
    first and the remainder later respects the total order of every
    conflicting access pair: any conflict between a cone operation and a
    remainder operation necessarily has the cone operation earlier —
    otherwise the closure would have marked the remainder operation too.
    Key granularity (regions ignored) over-approximates, which is sound:
    at worst a few extra operations drain early.
    """
    marked = [False] * len(ops)
    need_any: set[Hashable] = set()
    need_write: set[Hashable] = set()
    for i in range(len(ops) - 1, -1, -1):
        op = ops[i]
        hit = any(
            acc.write and (acc.key[0] in targets or acc.key in targets)
            for acc in op.accesses
        )
        if not hit:
            for acc in op.accesses:
                if acc.key in need_any or (acc.write and acc.key in need_write):
                    hit = True
                    break
        if not hit:
            continue
        marked[i] = True
        for acc in op.accesses:
            if acc.write:
                need_any.add(acc.key)
            else:
                need_write.add(acc.key)
    cone = [op for i, op in enumerate(ops) if marked[i]]
    rest = [op for i, op in enumerate(ops) if not marked[i]]
    return cone, rest


def cone_access_keys(ops: list[OperationNode]) -> tuple[set, set]:
    """The access footprint of a cone: ``(reads, writes)`` key sets at
    the §5.7 access-key granularity (regions ignored — the same sound
    over-approximation ``producer_cone`` uses).  Scratch keys
    (``("s", sid)``) are included: two cones sharing a scratch buffer
    must not drain concurrently."""
    reads: set = set()
    writes: set = set()
    for op in ops:
        for acc in op.accesses:
            (writes if acc.write else reads).add(acc.key)
    return reads, writes


def cone_base_ids(ops: list[OperationNode]) -> set:
    """The array-base ids a cone touches (scratch keys excluded).  The
    plan-shape cache keys on this to restrict the flush's dead-base set
    to the bases the pass pipeline can actually see — a dead base no
    cone operation touches cannot change what the passes do, so it must
    not fragment the cache."""
    out: set = set()
    for op in ops:
        for acc in op.accesses:
            k = acc.key
            if isinstance(k, tuple) and k and k[0] != "s":
                out.add(k[0])
    return out


def cones_conflict(a: tuple[set, set], b: tuple[set, set]) -> bool:
    """True when two cone footprints (from :func:`cone_access_keys`)
    order-depend: one's writes touch the other's reads or writes.
    Disjoint (non-conflicting) cones may drain concurrently in any
    interleaving and still produce bit-identical block contents —
    there is no access pair the dependency systems would have ordered."""
    ar, aw = a
    br, bw = b
    return bool(aw & (br | bw)) or bool(bw & ar)


def cone_region_footprint(ops: list[OperationNode]) -> dict:
    """The *region-precise* access footprint of a cone: ``key -> ([read
    regions], [write regions])``.  Unlike :func:`cone_access_keys` this
    keeps the per-dimension index regions, so two cones sharing a block
    key but touching disjoint slices can be told apart — the precision
    the key-granular conflict check gives up.  A whole-block access
    (region ``None``) collapses its list to ``[None]``."""
    fp: dict = {}
    for op in ops:
        for acc in op.accesses:
            entry = fp.get(acc.key)
            if entry is None:
                entry = fp[acc.key] = ([], [])
            lst = entry[1] if acc.write else entry[0]
            if lst and lst[0] is None:
                continue  # already whole-block
            if acc.region is None:
                lst[:] = [None]
            else:
                lst.append(acc.region)
    return fp


def _any_overlap(regions_a: list, regions_b: list) -> bool:
    for ra in regions_a:
        for rb in regions_b:
            if regions_overlap(ra, rb):
                return True
    return False


def region_footprints_conflict(a: dict, b: dict):
    """§5.7 conflict between two :func:`cone_region_footprint` maps:
    returns the first key where one side's writes overlap the other
    side's reads or writes at region granularity, or ``None`` when the
    footprints may drain concurrently."""
    keys = a.keys() & b.keys() if len(a) < len(b) else b.keys() & a.keys()
    for key in keys:
        ar, aw = a[key]
        br, bw = b[key]
        if (
            _any_overlap(aw, br)
            or _any_overlap(aw, bw)
            or _any_overlap(bw, ar)
        ):
            return key
    return None


def _reset_for_reinsert(op: OperationNode) -> None:
    """Clear the link state a previous insertion left on ``op`` so it can
    be re-inserted into a fresh graph (plan-stage rebuild)."""
    op.refcount = 0
    op.executed = False
    for acc in op.accesses:
        acc.dependents = []
        acc.removed = False


class DependencySystem:
    """Paper §5.7.2: per-base-block dependency lists + ready queue."""

    # True while rebuild() re-inserts already-recorded ops (plan stage /
    # cone extraction): re-insertion is replay, not recording, so the
    # tracer must not see a second "recorded" event per op
    _replay = False

    def __init__(self) -> None:
        # key -> list of live access-nodes, in insertion (program) order.
        self._lists: dict[Hashable, list[AccessNode]] = {}
        self.ready: deque[OperationNode] = deque()
        self.n_ops = 0
        self.n_pending = 0
        # instrumentation for the overhead benchmark
        self.scan_steps = 0
        # when set, newly-ready operations are handed to this callback
        # instead of the ready deque (used by the async executor so worker
        # dispatch happens directly on completion callbacks)
        self.on_ready: Optional[Callable[[OperationNode], None]] = None

    def _make_ready(self, op: OperationNode) -> None:
        if self.on_ready is not None:
            self.on_ready(op)
        else:
            self.ready.append(op)

    # -- recording -------------------------------------------------------
    @classmethod
    def rebuild(cls, ops: Iterable[OperationNode]) -> "DependencySystem":
        """Fresh dependency system from operation-nodes in the given
        (program) order — the re-insertion step of the plan stage
        (``repro.core.plan``).  Access-node link state from a previous
        insertion is reset; because insertion order encodes the total
        order of conflicting accesses, a pass that preserves the
        relative order of the ops it keeps yields an equivalent
        schedule constraint set."""
        deps = cls()
        deps._replay = True
        try:
            for op in ops:
                _reset_for_reinsert(op)
                deps.insert(op)
        finally:
            deps._replay = False
        return deps

    def insert(self, op: OperationNode) -> None:
        """Record ``op``: insert each access into its block's dependency
        list, accumulating the refcount from conflicting earlier accesses."""
        op.seq = self.n_ops  # program order within THIS system
        refs = 0
        for acc in op.accesses:
            lst = self._lists.setdefault(acc.key, [])
            for prev in lst:
                self.scan_steps += 1
                if not prev.removed and prev.op is not op and prev.conflicts(acc):
                    prev.dependents.append(acc)
                    refs += 1
            lst.append(acc)
        op.refcount = refs
        self.n_ops += 1
        self.n_pending += 1
        col = _obs.CURRENT
        if col is not None and not self._replay:
            col.op_recorded(op)
        if refs == 0:
            self._make_ready(op)

    # -- execution bookkeeping -------------------------------------------
    def complete(self, op: OperationNode) -> list[OperationNode]:
        """Remove ``op``'s access-nodes (paper: only on execution are
        access-nodes removed) and return newly-ready operations."""
        assert not op.executed
        op.executed = True
        self.n_pending -= 1
        newly = []
        for acc in op.accesses:
            acc.removed = True
            for dep in acc.dependents:
                dep.op.refcount -= 1
                if dep.op.refcount == 0:
                    newly.append(dep.op)
                    self._make_ready(dep.op)
            acc.dependents.clear()
        # lazy compaction of dependency lists
        for acc in op.accesses:
            lst = self._lists.get(acc.key)
            if lst is not None and len(lst) > 32 and sum(a.removed for a in lst) > len(lst) // 2:
                self._lists[acc.key] = [a for a in lst if not a.removed]
        return newly

    def pop_ready(self, kind: Optional[str] = None) -> Optional[OperationNode]:
        """Pop a ready op, optionally restricted to ``kind`` (comm-first
        priority is implemented by asking for COMM first)."""
        if kind is None:
            return self.ready.popleft() if self.ready else None
        for i, op in enumerate(self.ready):
            if op.kind == kind:
                del self.ready[i]
                return op
        return None

    def ready_of_kind(self, kind: str) -> list[OperationNode]:
        return [op for op in self.ready if op.kind == kind]

    def pending_ops(self) -> list[OperationNode]:
        """All recorded-but-unexecuted operations, in *program* (insertion)
        order — the plan stage's input and the diagnostic payload for
        deadlock reports.  Keyed on ``seq``, not ``uid``: a plan-stage
        merged node sits mid-list with a larger uid, and re-planning a
        partially drained graph must not reorder it past its consumers."""
        seen: dict[int, OperationNode] = {}
        for lst in self._lists.values():
            for acc in lst:
                if not acc.removed and acc.op is not None and not acc.op.executed:
                    seen[acc.op.seq] = acc.op
        return [seen[s] for s in sorted(seen)]

    @property
    def done(self) -> bool:
        return self.n_pending == 0


class FullDAG:
    """Paper §5.7 baseline: O(n) insertion against every live node."""

    def __init__(self) -> None:
        self.nodes: list[OperationNode] = []
        self.edges: dict[int, list[OperationNode]] = {}
        self.ready: deque[OperationNode] = deque()
        self.n_pending = 0
        self.scan_steps = 0

    @classmethod
    def rebuild(cls, ops: Iterable[OperationNode]) -> "FullDAG":
        """Same contract as :meth:`DependencySystem.rebuild` for the
        O(n²) baseline graph."""
        dag = cls()
        for op in ops:
            _reset_for_reinsert(op)
            dag.insert(op)
        return dag

    def insert(self, op: OperationNode) -> None:
        op.seq = len(self.nodes)
        refs = 0
        for prev in self.nodes:
            if prev.executed:
                continue
            dep = False
            for pa in prev.accesses:
                for na in op.accesses:
                    self.scan_steps += 1
                    if pa.key == na.key and pa.conflicts(na):
                        dep = True
                        break
                if dep:
                    break
            if dep:
                self.edges.setdefault(prev.uid, []).append(op)
                refs += 1
        op.refcount = refs
        self.nodes.append(op)
        self.n_pending += 1
        if refs == 0:
            self.ready.append(op)

    def complete(self, op: OperationNode) -> list[OperationNode]:
        op.executed = True
        self.n_pending -= 1
        newly = []
        for succ in self.edges.pop(op.uid, []):
            succ.refcount -= 1
            if succ.refcount == 0:
                newly.append(succ)
                self.ready.append(succ)
        return newly

    def pop_ready(self, kind: Optional[str] = None) -> Optional[OperationNode]:
        if kind is None:
            return self.ready.popleft() if self.ready else None
        for i, op in enumerate(self.ready):
            if op.kind == kind:
                del self.ready[i]
                return op
        return None

    def ready_of_kind(self, kind: str) -> list[OperationNode]:
        return [op for op in self.ready if op.kind == kind]

    def pending_ops(self) -> list[OperationNode]:
        return [op for op in self.nodes if not op.executed]

    @property
    def done(self) -> bool:
        return self.n_pending == 0
