"""Plan stage of the record → plan → execute flush pipeline.

The paper's runtime records operations lazily and drains them through a
scheduler; this module inserts an explicit *plan* stage in between: a
pipeline of registered graph passes rewrites the recorded operation list
before any scheduling happens.  Passes attack the dispatch-overhead wall
(ROADMAP "Dispatch overhead": ~0.1 ms/op of Python thread handoff caps
single-machine scaling near 10k ops per flush) the way arXiv:1811.05077
rewrites task graphs for latency tolerance and arXiv:1810.07591
aggregates tasks to amortize per-task Python overhead:

* ``"coalesce"`` (:func:`coalesce_transfers`, here) — merge chains of
  same-(src, dst) transfers into one wire message, so the channel
  progress engine posts fewer, larger sends;
* ``"fuse"`` (:func:`repro.core.fusion.fuse_cross_kind`) — cross-kind
  producer/consumer fusion beyond elementwise trees: map→reduce-partial
  pairs become joint payloads, fill values constant-fold into consuming
  maps, dead stores to collected bases are eliminated;
* ``"batch"`` (:func:`batch_dispatch`, here) — an executor hint: ready
  compute ops move between the completion sweep and the workers as
  per-worker *lists*, amortizing one lock+event round trip over many
  operations.

Passes are string-keyed plugins (``repro.register_pass``) resolved
through :mod:`repro.api.registry`, ordered by the pipeline on
:class:`~repro.api.config.ExecutionPolicy` — they compose exactly like
backends and channels do.  Under demand-driven sync the pipeline runs
on each extracted dependency cone, not the whole recorded graph: the
runtime hands ``plan()`` the cone's dependency system and a
``dead_bases`` set already restricted to bases no *remainder* operation
still touches (a dead temp whose consumer stays pending is not dead for
this flush).

**Correctness contract** — a pass must preserve the relative program
order of every pair of conflicting accesses it keeps.  The rewritten
list is re-inserted into a fresh dependency system
(:meth:`~repro.core.graph.DependencySystem.rebuild`), and because
insertion order *is* the total order of conflicting accesses (§5.7),
any executor draining the planned graph produces block contents
bit-identical to the unplanned one.  The built-in passes guarantee this
by construction: a merged operation is placed at its earliest
constituent's position, and a constituent may only be hoisted there if
no conflicting write intervenes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence, Union

from repro.api.registry import get_pass, register_pass
from repro.obs import collector as _obs

from .engine import CoalescedTransferPayload, TransferPayload
from .graph import (
    COMM,
    AccessNode,
    DependencySystem,
    OperationNode,
    regions_overlap,  # noqa: F401  (re-export for pass authors)
)

__all__ = [
    "PlanStats",
    "PlanContext",
    "PlanResult",
    "plan",
    "resolve_pipeline",
    "coalesce_transfers",
    "batch_dispatch",
    "DEFAULT_ASYNC_PIPELINE",
    "MAX_COALESCE",
]

# default pipeline for the measured (async) flush backend; the simulator
# keeps the unrewritten graphs so the paper-reproduction numbers stay
# exactly the paper's
DEFAULT_ASYNC_PIPELINE = ("coalesce", "fuse", "batch")

# cap on transfers per coalesced message (bounds the latency cost of one
# oversized send and keeps per-message work balanced across progress
# threads)
MAX_COALESCE = 16


@dataclass
class PlanStats:
    """Counters accumulated across the plan stages of a runtime's
    flushes — the observable effect of the pass pipeline."""

    n_ops_in: int = 0
    n_ops_out: int = 0
    n_transfers_coalesced: int = 0  # transfer ops merged away
    n_fused: int = 0  # map→reduce pairs fused into joint payloads
    n_const_folded: int = 0  # fill values propagated into map args
    n_dropped: int = 0  # dead stores eliminated

    def merge(self, other: "PlanStats") -> "PlanStats":
        self.n_ops_in += other.n_ops_in
        self.n_ops_out += other.n_ops_out
        self.n_transfers_coalesced += other.n_transfers_coalesced
        self.n_fused += other.n_fused
        self.n_const_folded += other.n_const_folded
        self.n_dropped += other.n_dropped
        return self


@dataclass
class PlanContext:
    """Mutable state handed through the pass pipeline.

    ``ops`` is the recorded operation list in program order — list
    order, not uid order, is authoritative (passes may append
    newly-built merged nodes whose uids are larger than their
    position).  ``dead_bases`` are array-base ids whose user-facing
    arrays have been garbage-collected before this flush: their block
    contents can never be read back, which licenses dead-store
    elimination and write-skipping fusion.  ``storage`` is the
    runtime's block storage, used read-only for dtype lookups.
    ``hints`` are handed to the execution stage (e.g.
    ``batch_dispatch``).
    """

    ops: list[OperationNode]
    dead_bases: set = field(default_factory=set)
    storage: dict = field(default_factory=dict)
    hints: dict = field(default_factory=dict)
    stats: PlanStats = field(default_factory=PlanStats)
    max_coalesce: int = MAX_COALESCE
    dirty: bool = False
    # rewrite provenance, fed to obs tracing and the static plan
    # verifier (repro.analysis): new uid -> (pass, source uids), and
    # dropped uid -> pass
    provenance: dict = field(default_factory=dict)
    dropped: dict = field(default_factory=dict)
    _active_pass: Optional[str] = None

    def dtype_of(self, base_id: int, block: tuple):
        blk = self.storage.get((base_id, block))
        return None if blk is None else blk.dtype

    def note_rewrite(self, op: OperationNode, sources) -> None:
        """Record that the active pass built ``op`` out of ``sources``
        (operation-nodes or uids).  Every pass that replaces nodes MUST
        call this: it is both the obs ``rewritten`` trace event and the
        provenance the plan verifier uses to follow a constituent to
        its merged node (and to blame the right pass in diagnostics)."""
        name = self._active_pass or "<pass>"
        srcs = tuple(getattr(s, "uid", s) for s in sources)
        self.provenance[op.uid] = (name, srcs)
        col = _obs.CURRENT
        if col is not None:
            col.op_rewritten(name, op, srcs)

    def note_drop(self, op: OperationNode) -> None:
        """Record that the active pass eliminated ``op`` outright
        (dead-store elimination).  Emits the obs ``dropped`` event and
        feeds the verifier's drop provenance."""
        name = self._active_pass or "<pass>"
        self.dropped[op.uid] = name
        col = _obs.CURRENT
        if col is not None:
            col.op_dropped(name, op)


@dataclass
class PlanResult:
    deps: DependencySystem
    hints: dict
    stats: PlanStats
    # rewrite/drop provenance accumulated by the pipeline (see
    # PlanContext.note_rewrite / note_drop) — the plan verifier's input
    provenance: dict = field(default_factory=dict)
    dropped: dict = field(default_factory=dict)
    # the final planned operation list in program order (``ctx.ops``) —
    # what the plan-shape cache walks to record a replayable recipe
    # (positions in this tuple, joined with ``provenance``/``dropped``,
    # say which pass produced every node)
    ops: tuple = ()


def resolve_pipeline(
    spec: Union[None, str, Sequence[str]], flush_backend: str = "sim"
) -> tuple[str, ...]:
    """Normalize a pass-pipeline spec to a tuple of registered names.

    ``"auto"`` resolves per flush backend (the measured executor gets
    :data:`DEFAULT_ASYNC_PIPELINE`, the simulator no passes); a string
    is split on commas; every name is validated against the pass
    registry so unknown passes fail at construction time.
    """
    if spec is None:
        return ()
    if isinstance(spec, str):
        if spec == "auto":
            return DEFAULT_ASYNC_PIPELINE if flush_backend == "async" else ()
        spec = tuple(s for s in (x.strip() for x in spec.split(",")) if s)
    pipeline = tuple(spec)
    from repro.api.registry import PASSES

    for name in pipeline:
        if name not in PASSES:
            raise ValueError(
                f"unknown pass {name!r} "
                f"(registered: {', '.join(PASSES.available()) or 'none'})"
            )
    return pipeline


def plan(
    deps: DependencySystem,
    pipeline: Sequence[str],
    *,
    dead_bases: Optional[set] = None,
    storage: Optional[dict] = None,
    max_coalesce: int = MAX_COALESCE,
) -> PlanResult:
    """Run the pass ``pipeline`` over the recorded graph.

    Returns the (possibly rebuilt) dependency system, the executor
    hints, and the pass statistics.  When no pass rewrites the graph
    the original system is returned untouched — the plan stage costs
    one ``pending_ops`` walk and nothing else.
    """
    stats = PlanStats(n_ops_in=deps.n_pending, n_ops_out=deps.n_pending)
    if not pipeline or deps.n_pending == 0:
        return PlanResult(deps, {}, stats)
    ctx = PlanContext(
        ops=deps.pending_ops(),
        dead_bases=set(dead_bases or ()),
        storage=storage if storage is not None else {},
        stats=stats,
        max_coalesce=max_coalesce,
    )
    col = _obs.CURRENT
    for name in pipeline:
        n_before = len(ctx.ops)
        ctx._active_pass = name
        try:
            get_pass(name)(ctx)
        finally:
            ctx._active_pass = None
        if col is not None:
            col.plan_pass(name, n_before, len(ctx.ops))
    stats.n_ops_out = len(ctx.ops)
    new_deps = type(deps).rebuild(ctx.ops) if ctx.dirty else deps
    return PlanResult(
        new_deps, ctx.hints, stats, ctx.provenance, ctx.dropped, tuple(ctx.ops)
    )


# ---------------------------------------------------------------------------
# built-in pass: transfer coalescing
# ---------------------------------------------------------------------------


def _is_simple_transfer(op: OperationNode) -> bool:
    return (
        op.kind == COMM
        and isinstance(op.payload, TransferPayload)
        and len(op.procs) == 2
    )


def coalesce_transfers(ctx: PlanContext) -> None:
    """Merge chains of transfers with the same (src, dst) process pair
    into one :class:`~repro.core.engine.CoalescedTransferPayload`.

    The merged node sits at the position of its *first* constituent; a
    transfer may only join an open group if none of its read keys has
    been written since the group opened (hoisting its read to the group
    position must not skip a conflicting write).  Scratch destinations
    are untouched, so consumers are oblivious to the merge — they just
    see their scratch buffer delivered by a bigger message.
    """
    ops = ctx.ops
    last_write: dict = {}  # access key -> last position with a write
    open_groups: dict[tuple, dict] = {}  # (src, dst) -> group record
    member_of: dict[int, dict] = {}  # op position -> its group
    for i, op in enumerate(ops):
        if _is_simple_transfer(op):
            key = op.procs
            g = open_groups.get(key)
            joinable = g is not None and len(g["idx"]) < ctx.max_coalesce
            if joinable:
                for acc in op.accesses:
                    if not acc.write and last_write.get(acc.key, -1) >= g["pos"]:
                        joinable = False
                        break
            if not joinable:
                g = {"pos": i, "idx": []}
                open_groups[key] = g
            g["idx"].append(i)
            member_of[i] = g
        for acc in op.accesses:
            if acc.write:
                last_write[acc.key] = i
    if not any(len(g["idx"]) > 1 for g in member_of.values()):
        return
    new_ops: list[OperationNode] = []
    merged_away = 0
    for i, op in enumerate(ops):
        g = member_of.get(i)
        if g is None or len(g["idx"]) < 2:
            new_ops.append(op)
            continue
        if i != g["idx"][0]:
            continue  # folded into the group leader's position
        members = [ops[j] for j in g["idx"]]
        merged = OperationNode(
            COMM,
            CoalescedTransferPayload(tuple(m.payload for m in members)),
            procs=op.procs,
            nbytes=sum(m.nbytes for m in members),
            label=f"xfer-coalesced[{len(members)}]",
        )
        for m in members:
            for acc in m.accesses:
                merged.add_access(AccessNode(acc.key, acc.region, acc.write))
        ctx.note_rewrite(merged, members)
        new_ops.append(merged)
        merged_away += len(members) - 1
    ctx.ops = new_ops
    ctx.dirty = True
    ctx.stats.n_transfers_coalesced += merged_away


# ---------------------------------------------------------------------------
# built-in pass: batched dispatch (executor hint)
# ---------------------------------------------------------------------------


def batch_dispatch(ctx: PlanContext) -> None:
    """Executor hint: the completion sweep groups newly-ready compute
    ops per worker and hands each worker a *list* per wakeup
    (``Worker.push_batch``), and workers drain their whole queue per
    wakeup and complete the batch through a single ``on_ready`` sweep —
    amortizing the ~0.1 ms/op lock+event handoff that caps
    single-machine scaling (ROADMAP "Dispatch overhead")."""
    ctx.hints["batch_dispatch"] = True


# shared region helpers for pass authors (``regions_overlap`` — the
# conflict geometry itself — is re-exported from repro.core.graph) -----------


def region_covers(outer, inner) -> bool:
    """True iff ``outer`` contains every index of ``inner``."""
    if outer is None:
        return True
    if inner is None:
        return False
    return all(
        o0 <= i0 and i1 <= o1 for (o0, o1), (i0, i1) in zip(outer, inner)
    )


def op_reads(op: OperationNode) -> Iterable[tuple]:
    """(key, region) pairs the op reads — including the *implicit*
    read-modify-write of non-initializing combines and matmuls, whose
    access lists only carry the write."""
    from .engine import CombinePayload, MatmulPayload

    out = [(a.key, a.region) for a in op.accesses if not a.write]
    p = op.payload
    if isinstance(p, (CombinePayload, MatmulPayload)) and not p.init:
        out.extend((a.key, a.region) for a in op.accesses if a.write)
    return out


# registration last: registering triggers the registry's default-module
# load, which imports repro.core.fusion — and that module imports the
# helpers above, so this module must be fully defined first
register_pass("coalesce", coalesce_transfers)
register_pass("batch", batch_dispatch)
