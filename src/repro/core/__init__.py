"""repro.core — the paper's runtime latency-hiding model.

Public surface:

* :class:`Runtime` — lazy-evaluation engine + comm-first flush scheduler.
* :mod:`repro.core.darray` — the DistNumPy-style array API (``array(...,
  dist=True)``, views, ufuncs, reductions, matmul).
* :class:`DependencySystem` — the paper's per-base-block dependency-list
  heuristic (§5.7.2); :class:`FullDAG` — the O(n²) baseline it replaces.
* :mod:`repro.core.plan` — the plan stage of the record → plan →
  execute flush pipeline: registered graph passes (transfer coalescing,
  cross-kind fusion, batched dispatch) rewrite the recorded graph
  before scheduling.
* :func:`run_schedule` — the flush algorithm (§5.7), latency-hiding and
  blocking modes; timeline accounting on an α–β cluster model.
"""
from .blocks import Fragment, Layout, OperandSpec, ViewSpec, fragment_iteration_space
from .darray import DistArray
from .engine import ArrayBase, Runtime, current_runtime
from .graph import COMM, COMPUTE, AccessNode, DependencySystem, FullDAG, OperationNode
from .plan import DEFAULT_ASYNC_PIPELINE, PlanStats, plan, resolve_pipeline
from .scheduler import DeadlockError, run_rendezvous_bsp, run_schedule
from .timeline import GIGE_2012, TPU_V5E_ICI, ClusterSpec, TimelineResult

__all__ = [
    "Runtime",
    "DistArray",
    "current_runtime",
    "ArrayBase",
    "Layout",
    "ViewSpec",
    "Fragment",
    "OperandSpec",
    "fragment_iteration_space",
    "DependencySystem",
    "FullDAG",
    "OperationNode",
    "AccessNode",
    "COMM",
    "COMPUTE",
    "plan",
    "PlanStats",
    "resolve_pipeline",
    "DEFAULT_ASYNC_PIPELINE",
    "run_schedule",
    "run_rendezvous_bsp",
    "DeadlockError",
    "ClusterSpec",
    "TimelineResult",
    "GIGE_2012",
    "TPU_V5E_ICI",
]
