"""Operation-flush schedulers (paper §5.7).

``run_schedule`` is an event-driven simulation of the paper's flush
algorithm over a recorded dependency system:

* ``mode="latency_hiding"`` — the paper's algorithm: every ready
  communication is initiated immediately (non-blocking), computation is
  evaluated lazily while transfers are in flight, and a process only waits
  when it has no ready computation (§5.7 invariants 1–3).
* ``mode="blocking"`` — the paper's baseline setup: communication is
  synchronous; a transfer occupies both end-point CPUs for its duration.

The simulation maintains per-process CPU clocks and per-process NIC
clocks; transfers serialize on the NICs of both end points, compute ops on
the owner's CPU.  If an ``executor`` is supplied, each operation's payload
is executed (real NumPy block work) at the moment it is scheduled, so the
numerical result is produced by exactly the schedule being measured —
mirroring the paper, where the measured run *is* the computation.

``run_rendezvous_bsp`` demonstrates the paper's fig. 6 deadlock: the naive
bulk-synchronous evaluation with two-sided rendezvous messaging deadlocks
on schedules that the flush algorithm executes fine.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional

from repro.api.registry import register_scheduler

from .graph import COMM, COMPUTE, DependencySystem, OperationNode
from .timeline import ClusterSpec, TimelineResult

__all__ = ["run_schedule", "run_rendezvous_bsp", "DeadlockError", "format_stuck_ops"]


class DeadlockError(RuntimeError):
    pass


def format_stuck_ops(ops: list[OperationNode], limit: int = 20) -> str:
    """Render pending operation-nodes for deadlock diagnostics (shared by
    the simulated scheduler and the repro.exec async executor)."""
    lines = [
        f"  op#{o.uid} [{o.kind}] refcount={o.refcount} procs={o.procs} "
        f"{o.label or type(o.payload).__name__}"
        for o in ops[:limit]
    ]
    if len(ops) > limit:
        lines.append(f"  ... and {len(ops) - limit} more")
    return "\n".join(lines)


def _drain_ready(deps: DependencySystem, schedule, t: float) -> None:
    """Comm-first drain of the ready queue (invariants 2 & 3): every
    ready communication is initiated before any ready computation."""
    for kind in (COMM, COMPUTE):
        while True:
            op = deps.pop_ready(kind)
            if op is None:
                break
            schedule(op, t)


def run_schedule(
    deps: DependencySystem,
    cluster: ClusterSpec,
    mode: str = "latency_hiding",
    executor: Optional[Callable[[OperationNode], None]] = None,
) -> TimelineResult:
    """Drain ``deps`` under the chosen scheduling mode; return the timeline.

    Event-driven list scheduling: when an operation's refcount reaches zero
    it is placed on its resources at the earliest feasible time.  The
    comm-first invariant is structural: communication never competes with
    computation for the CPU in latency-hiding mode (initiation is
    non-blocking), so every ready transfer is in flight before any ready
    compute is allowed to make the process busy.

    ``deps`` may be the recorded system or a plan-stage rewrite of it
    (:mod:`repro.core.plan`): coalesced transfer nodes carry their summed
    byte count, so one merged message pays a single α under the cluster
    model, and fused compute nodes carry their summed cost.
    """
    if mode not in ("latency_hiding", "blocking"):
        raise ValueError(f"unknown mode {mode!r}")
    res = TimelineResult(mode=mode, cluster=cluster)
    cpu_free = [0.0] * cluster.nprocs
    nic_free = [0.0] * cluster.nprocs
    # (end_time, seq, op) completion events
    events: list[tuple[float, int, OperationNode]] = []
    seq = itertools.count()

    def schedule(op: OperationNode, ready_t: float) -> None:
        if executor is not None:
            executor(op)
        if op.kind == COMM:
            src, dst = op.procs
            dur = cluster.comm_time(op.nbytes)
            occ = cluster.occupancy(op.nbytes)
            res.comm_bytes += op.nbytes
            res.n_comm_ops += 1
            if mode == "latency_hiding":
                # non-blocking: the NICs serialize injection/drain, the wire
                # latency is pipelined; CPUs stay free (MPI_Testsome progress)
                start = max(ready_t, nic_free[src], nic_free[dst])
                end = start + dur
                nic_free[src] = nic_free[dst] = start + occ
                res.procs[src].nic_busy += occ
                res.procs[dst].nic_busy += occ
            else:  # blocking: synchronous send/recv occupies both CPUs
                start = max(ready_t, cpu_free[src], cpu_free[dst])
                end = start + dur
                cpu_free[src] = cpu_free[dst] = end
                nic_free[src] = nic_free[dst] = end
                for p in (src, dst):
                    res.procs[p].comm_busy += dur
                    res.procs[p].n_comm += 1
                    res.procs[p].last_end = max(res.procs[p].last_end, end)
        else:
            (p,) = op.procs
            start = max(ready_t, cpu_free[p])
            end = start + op.cost
            cpu_free[p] = end
            st = res.procs[p]
            st.compute_busy += op.cost
            st.n_compute += 1
            st.last_end = max(st.last_end, end)
            res.n_compute_ops += 1
            res.seq_time += op.cost
        heapq.heappush(events, (end, next(seq), op))

    # comm-first initial drain of the ready queue (invariant 2)
    _drain_ready(deps, schedule, 0.0)

    while events:
        t, _, op = heapq.heappop(events)
        res.makespan = max(res.makespan, t)
        for newly in deps.complete(op):
            pass  # ready queue already holds them
        _drain_ready(deps, schedule, t)

    if not deps.done:
        stuck = deps.pending_ops() if hasattr(deps, "pending_ops") else []
        raise DeadlockError(
            f"{deps.n_pending} operations never became ready — dependency "
            "cycle.\nstuck operation-nodes:\n" + format_stuck_ops(stuck)
        )
    return res


# The two paper modes are the built-in entries of the scheduler
# registry; Runtime.flush resolves ``mode`` through it, so alternative
# flush disciplines plug in with one register_scheduler call.
def _registered_mode(mode: str):
    def scheduler(deps, cluster, executor=None):
        return run_schedule(deps, cluster, mode=mode, executor=executor)

    scheduler.__name__ = f"run_schedule[{mode}]"
    return scheduler


register_scheduler("latency_hiding", _registered_mode("latency_hiding"))
register_scheduler("blocking", _registered_mode("blocking"))


# ---------------------------------------------------------------------------
# Fig. 6 demonstration: naive BSP + two-sided rendezvous messaging
# ---------------------------------------------------------------------------

def run_rendezvous_bsp(
    per_proc_programs: list[list[dict]],
) -> tuple[bool, int]:
    """Simulate the paper's *naive* evaluation (fig. 6): each process walks
    its own operation list **in order**, and a two-sided rendezvous message
    blocks until the partner reaches the matching call.

    ``per_proc_programs[p]`` is a list of ops, each
    ``{"kind": "send"|"recv"|"compute", "tag": hashable, "peer": int}``.

    Returns ``(deadlocked, steps_completed)``.  The flush algorithm of
    :func:`run_schedule` cannot deadlock on the equivalent one-sided graph
    (§5.7.1); this runner shows the naive schedule can.
    """
    pc = [0] * len(per_proc_programs)
    done = lambda p: pc[p] >= len(per_proc_programs[p])
    steps = 0
    while not all(done(p) for p in range(len(pc))):
        progressed = False
        for p in range(len(pc)):
            if done(p):
                continue
            op = per_proc_programs[p][pc[p]]
            if op["kind"] == "compute":
                pc[p] += 1
                steps += 1
                progressed = True
            else:
                q = op["peer"]
                if done(q):
                    continue
                partner = per_proc_programs[q][pc[q]]
                want = "recv" if op["kind"] == "send" else "send"
                if (
                    partner["kind"] == want
                    and partner["peer"] == p
                    and partner["tag"] == op["tag"]
                ):
                    pc[p] += 1
                    pc[q] += 1
                    steps += 2
                    progressed = True
        if not progressed:
            return True, steps
    return False, steps
