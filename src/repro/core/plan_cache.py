"""Plan-shape cache: skip re-planning (and re-verifying) cones whose
*shape* was planned before.

Serving workloads are repetitive — the same request function records the
same operation graph over and over, differing only in which array bases
(and scratch ids) the fresh cone happens to use.  Planning is pure
structure: every decision the pass pipeline makes (which transfers
coalesce, which map→reduce pairs fuse, which fill values fold, which
dead stores drop) depends only on the cone's *canonical* shape — the
operation list modulo a consistent renaming of base ids and scratch ids
— plus the dead-base set, the pass pipeline, and the block dtypes.  Two
cones with equal canonical signatures therefore plan identically.

The cache exploits that in two steps:

* :meth:`PlanCache.signature` canonicalizes a cone into a hashable
  structural key (first-occurrence renaming ``base→c0,c1,…`` /
  ``scratch→s0,s1,…``; every pass-relevant datum — ufunc trees, fragment
  geometry, fill/constant values, block dtypes, proc placements, access
  footprints, the dead set — is part of the key, so a signature hit is a
  *proof* of identical planning, not a heuristic);
* on a cold plan, :meth:`PlanCache.insert` diffs the planned operation
  list (``PlanResult.ops`` + rewrite provenance) against the pre-plan
  list into a replayable **recipe** — keep/patch, coalesce(positions),
  fuse(map, reduce) steps; on a hit, :meth:`PlanCache.replay` applies
  the recipe to the *fresh* cone's operation nodes, constructing merged
  nodes exactly as the passes would (same payloads, same access lists,
  same program order).

Because the insert-time plan went through the static plan verifier (or
is at least verifiable — the entry retains the pre/post footprint
snapshots, provenance, and drop records), a replay needs no
re-verification: it is the same rewrite, re-targeted.
:meth:`Runtime.verify_cached_plans` re-checks every resident entry on
demand (the ``graph-lint`` story for cached plans).

Unknown payload kinds, unregistered passes, or rewrites the recipe
language cannot express make a cone *uncacheable* — the cold path
simply runs every time, counted in :attr:`PlanCache.n_uncacheable`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Optional

from .engine import (
    CombinePayload,
    FillPayload,
    FusedMapReducePayload,
    MapPayload,
    MatmulPayload,
    ReducePartialPayload,
    TransferPayload,
)
from .graph import COMM, COMPUTE, AccessNode, OperationNode

__all__ = ["PlanCache", "PlanCacheEntry"]

# passes whose rewrites the recipe language can express; any other name
# in the pipeline makes every cone uncacheable (correct, just cold)
_REPLAYABLE_PASSES = frozenset({"coalesce", "fuse", "batch"})

_DEFAULT_MAXSIZE = 256


class _Canon:
    """First-occurrence canonical renaming of base ids and scratch ids:
    the cone recorded by request N and the one recorded by request N+1
    use different global counters, but walk their operations in program
    order and both collapse to ``c0, c1, …`` / ``s0, s1, …``."""

    __slots__ = ("bases", "scratch")

    def __init__(self):
        self.bases: dict = {}
        self.scratch: dict = {}

    def base(self, bid) -> int:
        out = self.bases.get(bid)
        if out is None:
            out = self.bases[bid] = len(self.bases)
        return out

    def scr(self, sid) -> int:
        out = self.scratch.get(sid)
        if out is None:
            out = self.scratch[sid] = len(self.scratch)
        return out


def _const_sig(v):
    """Value signature for a scalar constant: dtype identity + exact
    value (``.item()`` for numpy scalars, so hashing never sees a 0-d
    array)."""
    dt = getattr(v, "dtype", None)
    name = str(dt) if dt is not None else type(v).__name__
    return (name, v.item() if hasattr(v, "item") else v)


def _tree_sig(spec):
    """Signature of a fused-ufunc expression tree (mirrors
    ``JaxBackend._tree_key``, but resolves const *values* so two trees
    differing only in an embedded constant get distinct keys)."""
    if spec is None:
        return None
    tag = spec[0]
    if tag == "leaf":
        return spec
    if tag == "const":
        return ("const", _const_sig(spec[1]))
    f, subs = spec
    return (f.name, tuple(_tree_sig(s) for s in subs))


def _ufunc_sig(uf):
    return (uf.name, str(uf.out_dtype), _tree_sig(uf.tree))


def _frag_sig(frag):
    return (frag.block, frag.local, frag.owner)


class _Uncacheable(Exception):
    pass


def _block_dtype(storage, bid, block):
    blk = storage.get((bid, block))
    return None if blk is None else str(blk.dtype)


def _ref_sig(ref, canon: _Canon, storage):
    kind = ref[0]
    if kind == "b":
        _, bid, frag = ref
        return ("b", canon.base(bid), _frag_sig(frag),
                _block_dtype(storage, bid, frag.block))
    if kind == "s":
        return ("s", canon.scr(ref[1]))
    if kind == "c":
        return ("c", _const_sig(ref[1]))
    raise _Uncacheable


def _payload_sig(p, canon: _Canon, storage):
    if isinstance(p, MapPayload):
        return ("map", _ufunc_sig(p.ufunc), canon.base(p.out_base),
                _frag_sig(p.out_frag),
                _block_dtype(storage, p.out_base, p.out_frag.block),
                str(p.out_dtype),
                tuple(_ref_sig(r, canon, storage) for r in p.args))
    if isinstance(p, TransferPayload):
        return ("xfer", _ref_sig(p.src, canon, storage),
                canon.scr(p.dst_scratch))
    if isinstance(p, ReducePartialPayload):
        return ("rpart", p.ufunc_name, _ref_sig(p.src, canon, storage),
                p.axes, canon.scr(p.dst_scratch), p.keepdims)
    if isinstance(p, CombinePayload):
        return ("comb", p.ufunc_name, canon.base(p.out_base),
                _frag_sig(p.out_frag),
                _block_dtype(storage, p.out_base, p.out_frag.block),
                canon.scr(p.src_scratch), p.init)
    if isinstance(p, MatmulPayload):
        return ("mm", canon.base(p.out_base), _frag_sig(p.out_frag),
                _block_dtype(storage, p.out_base, p.out_frag.block),
                _ref_sig(p.a, canon, storage),
                _ref_sig(p.b, canon, storage),
                p.trans_a, p.trans_b, p.init)
    if isinstance(p, FillPayload):
        return ("fill", canon.base(p.out_base), _frag_sig(p.out_frag),
                _block_dtype(storage, p.out_base, p.out_frag.block),
                _const_sig(p.value))
    # plan-produced payloads (coalesced / fused) are never *recorded*,
    # and anything else is a payload kind this module does not know
    raise _Uncacheable


def _access_key_sig(key, canon: _Canon):
    if isinstance(key, tuple) and key and key[0] == "s":
        return ("s", canon.scr(key[1]))
    bid, block = key
    return ("b", canon.base(bid), block)


def _op_sig(op, canon: _Canon, storage):
    return (
        op.kind,
        op.procs,
        _payload_sig(op.payload, canon, storage),
        tuple(
            (_access_key_sig(a.key, canon), a.region, a.write)
            for a in op.accesses
        ),
    )


def _args_patch(pre_args, post_args):
    """Diff a map's pre-plan argument tuple against its post-plan one
    into a ``((pos, const_value), …)`` patch — const folding is the only
    in-place arg rewrite the pipeline performs, so any other difference
    is unexpressible (raises)."""
    if len(pre_args) != len(post_args):
        raise _Uncacheable
    patch = []
    for k, (old, new) in enumerate(zip(pre_args, post_args)):
        if old is new or old == new:
            continue
        if new[0] != "c":
            raise _Uncacheable
        patch.append((k, new[1]))
    return tuple(patch)


def _apply_patch(op, patch) -> None:
    from .fusion import _rebuild_map_accesses

    p = op.payload
    args = list(p.args)
    for k, v in patch:
        args[k] = ("c", v)
    p.args = tuple(args)
    _rebuild_map_accesses(op, p)


@dataclass
class PlanCacheEntry:
    """One cached plan shape: the replay recipe plus everything needed
    to re-verify the plan on demand (`pre`/`post` footprint snapshots,
    rewrite provenance, drop records — the exact inputs of
    ``repro.analysis.check(rules=("plan", "deadlock"))``)."""

    steps: tuple  # ("keep", i, patch) | ("coalesce", idxs) | ("fuse", mi, ri, patch)
    dirty: bool  # did the insert-time plan rebuild the dependency system
    hints: dict
    stats: object  # PlanStats of the insert-time plan
    n_ops: int  # pre-plan op count (sanity check on replay)
    pre_views: tuple  # immutable OpView snapshot of the pre-plan cone
    post_views: tuple  # …and of the planned op list
    provenance: dict
    dropped: dict
    dead_bases: frozenset
    scratch_available: frozenset


class PlanCache:
    """LRU of canonical cone shape → replayable plan recipe.

    Thread-safe: concurrent submitter threads (serving clients planning
    off the record lock) hit one internal lock for lookup/insert;
    signature computation and replay run lock-free on caller state."""

    def __init__(self, maxsize: int = _DEFAULT_MAXSIZE):
        self.maxsize = maxsize
        self._entries: "OrderedDict[tuple, PlanCacheEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.n_uncacheable = 0

    # -- keying -------------------------------------------------------------
    def signature(self, pending, dead_bases, pipeline, storage):
        """Canonical structural signature of a cone, or ``None`` when
        the cone (or the pipeline) is uncacheable."""
        if not _REPLAYABLE_PASSES.issuperset(pipeline):
            with self._lock:
                self.n_uncacheable += 1
            return None
        canon = _Canon()
        try:
            ops_sig = tuple(_op_sig(op, canon, storage) for op in pending)
            # only dead bases the cone actually touches can influence the
            # plan; canonical ids make the set renaming-stable
            dead_sig = tuple(sorted(
                canon.bases[b] for b in dead_bases if b in canon.bases
            ))
            sig = (tuple(pipeline), ops_sig, dead_sig)
            hash(sig)
        except (_Uncacheable, TypeError, ValueError):
            with self._lock:
                self.n_uncacheable += 1
            return None
        return sig

    def lookup(self, sig) -> Optional[PlanCacheEntry]:
        with self._lock:
            entry = self._entries.get(sig)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(sig)
            self.hits += 1
            return entry

    # -- recipe construction (cold path) ------------------------------------
    def insert(self, sig, pending, pre_args, planned, dead_bases, *,
               pre_views, scratch_available) -> Optional[PlanCacheEntry]:
        """Diff ``planned`` against the pre-plan op list into a replay
        recipe and cache it under ``sig``.  Returns ``None`` (without
        caching) when the rewrite is not expressible — every pre-plan
        operation must be accounted for as kept, merged, fused, or
        dropped, and every payload change must be a const-fold patch."""
        pre_index = {op.uid: i for i, op in enumerate(pending)}
        consumed: set = set()
        steps: list = []
        dirty = False
        try:
            for op in planned.ops:
                prov = planned.provenance.get(op.uid)
                if prov is not None:
                    pname, srcs = prov
                    if pname == "coalesce":
                        idxs = tuple(pre_index[u] for u in srcs)
                        consumed.update(srcs)
                        steps.append(("coalesce", idxs))
                        dirty = True
                    elif pname == "fuse":
                        mu, ru = srcs
                        mi, ri = pre_index[mu], pre_index[ru]
                        consumed.update(srcs)
                        # the fused payload references the (possibly
                        # const-folded) map payload; the patch replays
                        # the fold onto the fresh map before fusing
                        patch = _args_patch(
                            pre_args[mu], op.payload.map.args
                        )
                        steps.append(("fuse", mi, ri, patch))
                        dirty = True
                    else:
                        raise _Uncacheable
                    continue
                i = pre_index.get(op.uid)
                if i is None:
                    raise _Uncacheable  # a node from nowhere
                consumed.add(op.uid)
                patch = ()
                if isinstance(op.payload, MapPayload):
                    patch = _args_patch(pre_args[op.uid], op.payload.args)
                    if patch:
                        dirty = True
                steps.append(("keep", i, patch))
            for uid in planned.dropped:
                if uid not in pre_index:
                    raise _Uncacheable
                consumed.add(uid)
                dirty = True
            if consumed != set(pre_index):
                raise _Uncacheable  # an op vanished without provenance
        except (_Uncacheable, KeyError):
            with self._lock:
                self.n_uncacheable += 1
            return None
        from repro.analysis import snapshot_ops

        entry = PlanCacheEntry(
            steps=tuple(steps),
            dirty=dirty,
            hints=dict(planned.hints),
            stats=replace(planned.stats),
            n_ops=len(pending),
            pre_views=tuple(pre_views) if pre_views is not None else (),
            post_views=tuple(snapshot_ops(list(planned.ops))),
            provenance=dict(planned.provenance),
            dropped=dict(planned.dropped),
            dead_bases=frozenset(dead_bases),
            scratch_available=frozenset(scratch_available),
        )
        with self._lock:
            self._entries[sig] = entry
            self._entries.move_to_end(sig)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
        return entry

    # -- replay (hit path) ---------------------------------------------------
    def replay(self, entry: PlanCacheEntry, deps, pending):
        """Apply a cached recipe to a fresh cone: returns
        ``(new_deps, hints, stats)`` exactly as a cold
        :func:`repro.core.plan.plan` call would.  Merged/fused nodes are
        constructed the way the passes construct them — same payloads,
        same access lists, same program order — so the drained result is
        bit-identical to a cold plan of the same cone."""
        if len(pending) != entry.n_ops:
            raise RuntimeError(
                "plan-cache replay on a cone of different size "
                f"({len(pending)} ops, recipe expects {entry.n_ops})"
            )
        out: list = []
        for step in entry.steps:
            tag = step[0]
            if tag == "keep":
                _, i, patch = step
                op = pending[i]
                if patch:
                    _apply_patch(op, patch)
                out.append(op)
            elif tag == "coalesce":
                from .engine import CoalescedTransferPayload

                members = [pending[j] for j in step[1]]
                lead = members[0]
                merged = OperationNode(
                    COMM,
                    CoalescedTransferPayload(
                        tuple(m.payload for m in members)
                    ),
                    procs=lead.procs,
                    nbytes=sum(m.nbytes for m in members),
                    label=f"xfer-coalesced[{len(members)}]",
                )
                for m in members:
                    for acc in m.accesses:
                        merged.add_access(
                            AccessNode(acc.key, acc.region, acc.write)
                        )
                out.append(merged)
            else:  # "fuse"
                _, mi, ri, patch = step
                mop, rop = pending[mi], pending[ri]
                if patch:
                    _apply_patch(mop, patch)
                mp = mop.payload
                p = rop.payload
                node = OperationNode(
                    COMPUTE,
                    FusedMapReducePayload(
                        mp, p.ufunc_name, p.axes, p.dst_scratch, p.keepdims
                    ),
                    procs=mop.procs,
                    cost=mop.cost + rop.cost,
                    label=f"map+reduce:{p.ufunc_name}",
                )
                for a in mop.accesses:
                    if not a.write:
                        node.add_access(
                            AccessNode(a.key, a.region, write=False)
                        )
                node.add_access(
                    AccessNode(("s", p.dst_scratch), None, write=True)
                )
                out.append(node)
        new_deps = type(deps).rebuild(out) if entry.dirty else deps
        return new_deps, dict(entry.hints), replace(entry.stats)

    # -- introspection -------------------------------------------------------
    def entries(self) -> list:
        """Snapshot of resident entries (for on-demand re-verification)."""
        with self._lock:
            return list(self._entries.values())

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __repr__(self):
        return (
            f"PlanCache(n={len(self)}, hits={self.hits}, "
            f"misses={self.misses}, uncacheable={self.n_uncacheable})"
        )
