"""Lazy-evaluation engine (paper §5.5–§5.7).

The :class:`Runtime` records every operation on distributed arrays instead
of executing it (lazy evaluation, §5.6).  Operations are split into
sub-view-block fragments (§5.2), each fragment becoming one operation-node
whose access-nodes are inserted into per-base-block dependency lists
(§5.7.2).  Remote operand fragments generate communication operation-nodes
(transfer → scratch buffer) that the comm-first flush scheduler (§5.7)
initiates aggressively.

A *flush* (triggered by a read of distributed data, by the recorded-op
threshold, or by context exit — §5.6) drains the dependency system through
:func:`repro.core.scheduler.run_schedule`, simultaneously executing the
real NumPy block work and accounting the timeline on the cluster model.

Flushes are *demand-driven* (``sync="demand"``): a readback extracts and
drains only the dependency cone of the blocks being read
(:func:`repro.core.graph.producer_cone`), and ``flush(wait=False)``
submits the drain to the persistent executor and returns a
:class:`FlushTicket` instead of joining, so recording overlaps the
drain.  ``sync="barrier"`` restores the paper's whole-graph blocking
flush (the simulator default).
"""
from __future__ import annotations

import itertools
import os
import threading
import time as _time
import weakref
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import numpy as np

from repro.obs import collector as _obs

from .blocks import (
    Fragment,
    Layout,
    OperandSpec,
    ViewSpec,
    default_process_grid,
    fragment_iteration_space,
)
from .graph import (
    COMM,
    COMPUTE,
    AccessNode,
    DependencySystem,
    OperationNode,
    producer_cone,
)
from .scheduler import run_schedule  # noqa: F401  (registers the built-in modes)
from .timeline import GIGE_2012, ClusterSpec, TimelineResult
from .ufunc import UFunc, get_ufunc, reduce_fn

__all__ = [
    "Runtime",
    "ArrayBase",
    "FlushTicket",
    "PendingFlush",
    "current_runtime",
    "execute_payload",
    "resolve_ref",
]

_base_ids = itertools.count(1)
_scratch_ids = itertools.count(1)

_tls = threading.local()


def current_runtime() -> "Runtime":
    rt = getattr(_tls, "runtime", None)
    if rt is None:
        raise RuntimeError("no active repro.core Runtime — use `with Runtime(...):`")
    return rt


# ---------------------------------------------------------------------------
# Operation payloads (executed by the scheduler at schedule time)
# ---------------------------------------------------------------------------

# input reference: ("b", base_id, Fragment) local block piece,
#                  ("s", scratch_id)        delivered/communicated piece,
#                  ("c", constant)          python scalar


@dataclass
class MapPayload:
    ufunc: UFunc
    out_base: int
    out_frag: Fragment
    args: tuple  # ordered input references
    out_dtype: np.dtype


@dataclass
class TransferPayload:
    src: tuple  # ("b", base_id, Fragment) or ("s", scratch_id)
    dst_scratch: int


@dataclass
class ReducePartialPayload:
    ufunc_name: str
    src: tuple
    axes: tuple[int, ...]  # operand axes to reduce
    dst_scratch: int
    keepdims: bool = False


@dataclass
class CombinePayload:
    ufunc_name: str
    out_base: int
    out_frag: Fragment
    src_scratch: int
    init: bool


@dataclass
class MatmulPayload:
    out_base: int
    out_frag: Fragment
    a: tuple
    b: tuple
    trans_a: bool
    trans_b: bool
    init: bool


@dataclass
class FillPayload:
    out_base: int
    out_frag: Fragment
    value: object


# -- plan-stage payloads (produced by repro.core.plan / repro.core.fusion
# graph passes, never recorded directly) ------------------------------------


@dataclass
class CoalescedTransferPayload:
    """Several same-(src, dst) transfers merged into ONE wire message by
    the ``coalesce`` plan pass: the channel posts a single send whose
    delivery fills every constituent scratch buffer."""

    transfers: tuple  # tuple[TransferPayload, ...]


@dataclass
class FusedMapReducePayload:
    """A map whose only consumer was a partial reduction of the same
    fragment (and whose output base is dead), fused by the ``fuse`` plan
    pass: the elementwise result goes straight into the reduction's
    scratch buffer without a block-storage round trip."""

    map: MapPayload
    ufunc_name: str
    axes: tuple[int, ...]
    dst_scratch: int
    keepdims: bool = False


# ---------------------------------------------------------------------------
# Payload interpretation — shared by the simulated executor (run_schedule's
# ``executor`` callback) and the asynchronous executor in repro.exec.  It is
# deliberately a pure function of (payload, storage, scratch): any executor
# that respects the dependency graph's ordering of conflicting accesses
# produces bit-identical block contents through it.
# ---------------------------------------------------------------------------


def resolve_ref(ref, storage: dict, scratch: dict):
    """Input reference -> ndarray: ("b", base, frag) block piece,
    ("s", sid) scratch buffer, ("c", const) scalar."""
    kind = ref[0]
    if kind == "b":
        _, bid, frag = ref
        return storage[(bid, frag.block)][frag.slices]
    if kind == "s":
        return scratch[ref[1]]
    return ref[1]  # constant


def execute_payload(p, storage: dict, scratch: dict) -> None:
    """Execute one operation payload against block/scratch storage."""
    if isinstance(p, TransferPayload):
        # always materialize a copy: the wire transfer must snapshot the
        # source at send time (an aliasing view would see later writes)
        scratch[p.dst_scratch] = np.array(
            resolve_ref(p.src, storage, scratch), copy=True
        )
    elif isinstance(p, MapPayload):
        args = [resolve_ref(r, storage, scratch) for r in p.args]
        res = p.ufunc(*args)
        blk = storage[(p.out_base, p.out_frag.block)]
        blk[p.out_frag.slices] = res
    elif isinstance(p, ReducePartialPayload):
        arr = resolve_ref(p.src, storage, scratch)
        scratch[p.dst_scratch] = reduce_fn(p.ufunc_name)(
            arr, axis=p.axes if p.axes else None, keepdims=p.keepdims
        )
    elif isinstance(p, CombinePayload):
        part = scratch[p.src_scratch]
        blk = storage[(p.out_base, p.out_frag.block)]
        if p.init:
            blk[p.out_frag.slices] = part
        else:
            cur = blk[p.out_frag.slices]
            blk[p.out_frag.slices] = get_ufunc(p.ufunc_name)(cur, part)
    elif isinstance(p, MatmulPayload):
        a = resolve_ref(p.a, storage, scratch)
        b = resolve_ref(p.b, storage, scratch)
        if p.trans_a:
            a = a.T
        if p.trans_b:
            b = b.T
        val = a @ b
        blk = storage[(p.out_base, p.out_frag.block)]
        if p.init:
            blk[p.out_frag.slices] = val
        else:
            blk[p.out_frag.slices] += val
    elif isinstance(p, FillPayload):
        blk = storage[(p.out_base, p.out_frag.block)]
        blk[p.out_frag.slices] = p.value
    elif isinstance(p, CoalescedTransferPayload):
        for t in p.transfers:
            scratch[t.dst_scratch] = np.array(
                resolve_ref(t.src, storage, scratch), copy=True
            )
    elif isinstance(p, FusedMapReducePayload):
        m = p.map
        args = [resolve_ref(r, storage, scratch) for r in m.args]
        res = np.asarray(m.ufunc(*args))
        # reproduce the store semantics the unfused pair had: the map
        # result was broadcast into (and cast to) the output fragment,
        # then the reduction read exactly that fragment
        res = np.broadcast_to(res, m.out_frag.shape).astype(
            m.out_dtype, copy=False
        )
        scratch[p.dst_scratch] = reduce_fn(p.ufunc_name)(
            res, axis=p.axes if p.axes else None, keepdims=p.keepdims
        )
    else:  # pragma: no cover
        raise TypeError(f"unknown payload {type(p)}")


def _wait_label() -> str:
    """Trace label for a thread blocked on a ticket: ``"main"`` for the
    main thread, a per-thread client label otherwise — concurrent
    waiters must not collide on one wait-span key."""
    t = threading.current_thread()
    if t is threading.main_thread():
        return "main"
    return f"client-{t.ident}"


class FlushTicket:
    """Handle on one (possibly still draining) flush — what
    ``Runtime.flush(wait=False)`` returns instead of joining the
    executor.

    ``wait()`` blocks until the drain completes, merges the drain's
    measured stats into the runtime's accumulated statistics exactly
    once, and returns the flush's stats object; ``done()`` polls.  A
    ticket for a simulated (or empty) flush comes back already
    completed — the API surface is uniform across backends.

    Tickets are thread-safe: with concurrent cone drains (the serving
    runtime), several client threads may wait the same ticket, and the
    runtime's reaper may resolve it first.  Bookkeeping (stats merge,
    ticket-list removal) runs exactly once, on whichever thread resolves
    first; a ticket that failed re-raises its exception on every
    subsequent ``wait()``.

    A ticket may be created *pending* (``pending=True``) before its
    executor future exists: ``Runtime.extract_cone`` hands the ticket
    out while still under the serving record lock, and
    ``Runtime.submit_cone`` later binds the real future (``_bind``) —
    or fails the ticket (``_fail``) — from outside the lock.  Waiters
    that arrive in the window park on an Event until the binding
    resolves, and ``add_done_callback`` queues callbacks until then."""

    __slots__ = ("_rt", "_fut", "_stats", "_resolved", "_tag", "_keys",
                 "_regions", "_exc", "_lock", "_bound", "_callbacks")

    def __init__(self, rt: "Runtime", fut=None, stats=None, tag=None, keys=None,
                 regions=None, pending=False):
        self._rt = rt
        self._fut = fut  # repro.exec Future -> WaitStats, or None
        self._stats = stats  # pre-completed result (sim flush / empty cone)
        self._resolved = fut is None and not pending
        self._tag = tag  # flush id — the trace segment this ticket joins
        # cone access footprint (reads, writes) from cone_access_keys;
        # None = whole-graph flush (conflicts with everything)
        self._keys = keys
        # region-precise footprint (cone_region_footprint), populated
        # only under verify="full" — the race oracle's input
        self._regions = regions
        self._exc: Optional[BaseException] = None
        self._lock = threading.Lock()
        # set once the ticket has either a future or a local resolution;
        # pending tickets (extracted but not yet submitted) leave it clear
        self._bound = threading.Event()
        if fut is not None or not pending:
            self._bound.set()
        self._callbacks: list = []  # queued while pending (unbound)

    def done(self) -> bool:
        return self._resolved or (self._fut is not None and self._fut.done())

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` when the drain resolves (immediately if it
        already has).  Runs on the resolving executor thread — keep it
        short and non-blocking."""
        with self._lock:
            if self._fut is None and not self._resolved:
                self._callbacks.append(fn)  # pending: registered at _bind
                return
            fut = self._fut
        if fut is None:
            fn(self)
        else:
            fut.add_done_callback(lambda _f: fn(self))

    # -- deferred binding (extract_cone / submit_cone split) ---------------
    def _bind(self, fut) -> None:
        """Attach the executor future of a pending ticket (called by
        ``Runtime.submit_cone`` once planning finished off-lock) and
        flush the callbacks queued while unbound."""
        with self._lock:
            self._fut = fut
            cbs = self._callbacks
            self._callbacks = []
        self._bound.set()
        for fn in cbs:
            fut.add_done_callback(lambda _f, fn=fn: fn(self))

    def _resolve_local(self, stats=None) -> None:
        """Resolve a pending ticket without an executor future (empty
        cone, or a simulated cone drain that already ran inline)."""
        with self._lock:
            self._resolved = True
            self._stats = stats
            cbs = self._callbacks
            self._callbacks = []
        self._bound.set()
        self._rt._ticket_discard(self)
        for fn in cbs:
            fn(self)

    def _fail(self, exc: BaseException) -> bool:
        """Fail a still-pending ticket (plan/verify/submit raised before
        a future existed).  No-op — returning False — once a future is
        bound or the ticket resolved: the future's own failure path owns
        the bookkeeping then."""
        with self._lock:
            if self._resolved or self._fut is not None:
                return False
            self._resolved = True
            self._exc = exc
            cbs = self._callbacks
            self._callbacks = []
        self._bound.set()
        self._rt._ticket_failed(self)
        for fn in cbs:
            fn(self)
        return True

    def wait(self, timeout: Optional[float] = None):
        """Block until the drain completes.  Returns the flush's stats
        (a :class:`repro.exec.WaitStats` for async drains, a
        :class:`TimelineResult` for simulated ones, ``None`` when the
        flush had nothing to drain); raises the drain's failure (again,
        on every call — a failed flush stays failed)."""
        with self._lock:
            if self._resolved:
                if self._exc is not None:
                    raise self._exc
                return self._stats
            fut = self._fut
        if fut is None:
            # pending ticket: another thread is still planning/submitting
            # this cone — park until it binds a future or resolves
            if not self._bound.wait(timeout):
                raise TimeoutError(
                    f"flush #{self._tag}: cone still being planned/"
                    f"submitted after {timeout} s"
                )
            with self._lock:
                if self._resolved:
                    if self._exc is not None:
                        raise self._exc
                    return self._stats
                fut = self._fut
        # a thread blocking on a drain is the third wait reason: a
        # barrier (whole-graph flush, or joining a demand-driven cone)
        col = _obs.CURRENT
        span = col is not None and not fut.done()
        label = _wait_label()
        if span:
            col.wait_start(label, "barrier")
        try:
            res = fut.result(timeout)
        except TimeoutError:
            if span:
                col.wait_end(label, "barrier", self._tag)
            raise  # still in flight — the ticket stays waitable
        except BaseException as exc:
            if span:
                col.wait_end(label, "barrier", self._tag)
            with self._lock:
                if not self._resolved:
                    self._resolved = True
                    self._exc = exc
                    self._rt._ticket_failed(self)
            raise
        if span:
            col.wait_end(label, "barrier", self._tag)
        with self._lock:
            if not self._resolved:
                self._resolved = True
                self._stats = res
                self._rt._ticket_done(self, res)
        return res


@dataclass
class PendingFlush:
    """The record-side half of a demand-driven flush, produced by
    :meth:`Runtime.extract_cone` under the caller's record serialization
    and consumed by :meth:`Runtime.submit_cone` *outside* it.

    Everything the plan+submit stage needs is captured here at
    extraction time: the cone's own dependency system (``deps``), its
    access-key footprint (``keys`` — what ``_join_conflicting`` keys
    off), the dead-base set already restricted to bases no remainder
    operation touches, and the flush id.  ``deps is None`` marks an
    empty cone: nothing to drain, but the submit stage must still join
    in-flight writers of the requested blocks (``empty_read`` carries
    the resolved read keys / base ids for that join)."""

    ticket: FlushTicket
    deps: Optional[DependencySystem]
    keys: tuple  # (reads, writes) from cone_access_keys
    dead: set
    fid: Optional[int]
    n_total: int
    empty_read: Optional[tuple] = None  # (read_keys, base_ids), empty cone


class _ConeBatcher:
    """Cross-tenant cone batching: merge several small, mutually
    non-conflicting planned cones arriving from concurrent submitter
    threads into one executor submission (``AsyncExecutor.submit_many``)
    — one global-lock round, one worker wake, one dispatch sweep for
    the whole group instead of per cone.

    Leader/follower: the first thread to enqueue becomes the leader and
    loops submitting whatever has accumulated (up to ``max_batch`` per
    round); threads that enqueue while a leader is active just leave
    their cone in the queue — their ticket is bound to its future by
    whichever leader round picks it up.  Co-queued cones are mutually
    non-conflicting *by construction*: a conflicting later cone blocks
    in ``_join_conflicting`` on the earlier cone's (still unbound)
    ticket before it ever reaches the batcher."""

    __slots__ = ("_rt", "_lock", "_pending", "_leader", "max_batch",
                 "n_batches", "n_merged")

    def __init__(self, rt: "Runtime", max_batch: int = 8):
        self._rt = rt
        self._lock = threading.Lock()
        self._pending: list = []  # (deps, hints, ticket) triples
        self._leader = False
        self.max_batch = max_batch
        self.n_batches = 0
        self.n_merged = 0

    def enqueue(self, deps, hints, ticket) -> None:
        with self._lock:
            self._pending.append((deps, hints, ticket))
            if self._leader:
                return  # the active leader's next round takes it
            self._leader = True
        try:
            while True:
                with self._lock:
                    batch = self._pending[: self.max_batch]
                    del self._pending[: len(batch)]
                    if not batch:
                        self._leader = False
                        return
                    self.n_batches += 1
                    if len(batch) > 1:
                        self.n_merged += len(batch)
                self._rt._submit_batch(batch)
        except BaseException:
            with self._lock:
                leftover = self._pending
                self._pending = []
                self._leader = False
            for _d, _h, t in leftover:
                t._fail(RuntimeError("cone batch submission failed"))
            raise


class ArrayBase:
    """The array-base (paper §5.1): owns the actual memory via the runtime's
    block storage; never manipulated directly by the user."""

    __slots__ = ("id", "shape", "dtype", "layout", "__weakref__")

    def __init__(self, shape, dtype, layout):
        self.id = next(_base_ids)
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        self.layout = layout

    def __repr__(self):
        return f"ArrayBase(id={self.id}, shape={self.shape}, dtype={self.dtype})"


class Runtime:
    """The DistNumPy-style runtime: lazy recording + comm-first flush."""

    def __init__(
        self,
        nprocs: int = 4,
        block_size: Union[int, tuple] = 128,
        mode: str = "latency_hiding",
        cluster: Optional[ClusterSpec] = None,
        flush_threshold: int = 200_000,
        execute: bool = True,
        fusion: bool = False,
        flush_backend: str = "sim",
        exec_backend: str = "numpy",
        exec_channel: Optional[str] = None,
        exec_latency: Union[float, str] = 0.0,  # seconds, or "alpha"
        exec_progress_threads: int = 2,
        exec_steal: bool = True,
        exec_steal_threshold: int = 4,
        exec_steal_latency: float = 1e-4,
        passes: Union[str, Sequence[str]] = "auto",
        sync: str = "auto",
        trace: Union[bool, str] = False,
        verify: str = "off",
        plan_cache: Optional[bool] = None,
        batch_cones: bool = False,
    ):
        self.nprocs = nprocs
        self.block_size = block_size
        self.mode = mode
        self.cluster = (cluster or GIGE_2012).with_nprocs(nprocs)
        self.flush_threshold = flush_threshold
        self.execute = execute
        self.fusion = fusion
        if flush_backend not in ("sim", "async"):
            raise ValueError(f"unknown flush_backend {flush_backend!r} (sim|async)")
        if flush_backend == "async" and not execute:
            raise ValueError("flush_backend='async' requires execute=True "
                             "(it runs the real block work)")
        self.flush_backend = flush_backend
        self.exec_backend = exec_backend
        # channel discipline defaults to the runtime mode: latency-hiding
        # uses the non-blocking progress engine, blocking the sync channel
        self.exec_channel = exec_channel or (
            "async" if mode == "latency_hiding" else "blocking"
        )
        # fail at construction, not at the first flush mid-program; names
        # resolve through the plugin registries (repro.api.registry), so a
        # freshly registered scheduler/backend/channel is valid here too
        from repro.api.registry import BACKENDS, CHANNELS, SCHEDULERS

        if mode not in SCHEDULERS:
            raise ValueError(
                f"unknown mode {mode!r} "
                f"(registered schedulers: {', '.join(SCHEDULERS.available())})"
            )
        if flush_backend == "async":
            if isinstance(exec_backend, str) and exec_backend not in BACKENDS:
                raise ValueError(
                    f"unknown exec_backend {exec_backend!r} "
                    f"(registered: {', '.join(BACKENDS.available())})"
                )
            if isinstance(self.exec_channel, str) and self.exec_channel not in CHANNELS:
                raise ValueError(
                    f"unknown exec_channel {self.exec_channel!r} "
                    f"(registered: {', '.join(CHANNELS.available())})"
                )
        if isinstance(exec_latency, str):
            from repro.comm.emulation import resolve_latency

            exec_latency = resolve_latency(exec_latency, self.cluster)
        self.exec_latency = exec_latency
        self.exec_progress_threads = exec_progress_threads
        self.exec_steal = exec_steal
        self.exec_steal_threshold = exec_steal_threshold
        self.exec_steal_latency = exec_steal_latency
        self.exec_stats = None  # WaitStats accumulated across async flushes
        # plan-stage pass pipeline (record -> PLAN -> execute); "auto"
        # resolves per flush backend: the measured executor gets the
        # default optimization pipeline, the simulator stays the paper's
        # unrewritten graphs.  Resolution validates every name against
        # the pass registry, so typos fail here, not at the first flush.
        from .plan import PlanStats, resolve_pipeline

        self.passes = resolve_pipeline(passes, flush_backend)
        self.plan_stats = PlanStats()
        # readback discipline: "demand" drains only the dependency cone of
        # the array being read, "barrier" the whole recorded graph (the
        # paper's §5.6 semantics).  "auto" resolves to demand under the
        # measured async backend and barrier under the simulator, so every
        # paper figure stays bit-identical by default.
        if sync not in ("auto", "demand", "barrier"):
            raise ValueError(f"unknown sync {sync!r} (auto|demand|barrier)")
        self.sync_mode = (
            sync
            if sync != "auto"
            else ("demand" if flush_backend == "async" else "barrier")
        )
        # compute backend + channel + executor persist across flushes (jit
        # caches, progress threads and the worker pool are expensive to
        # rebuild); created lazily, released by close()
        self._exec_backend_obj = None
        self._exec_channel_obj = None
        self._exec_executor_obj = None
        self._tickets: list[FlushTicket] = []  # outstanding wait=False flushes
        # _tickets is mutated from client threads (ticket bookkeeping runs
        # on whichever thread resolves first under concurrent cone drains)
        self._ticket_lock = threading.Lock()
        # failures first observed by the reaper (no one waited the ticket
        # yet); surfaced — in submission order — at the next full sync
        self._deferred_errors: list[BaseException] = []
        self._closed = False

        self.deps = DependencySystem()
        self.storage: dict[tuple, np.ndarray] = {}  # (base_id, coord) -> block
        self.scratch: dict[int, np.ndarray] = {}
        self._xfer_cache: dict[tuple, int] = {}
        self._write_epoch: dict[tuple, int] = {}  # (base_id, coord) -> version
        self._combine_seen: set = set()
        self._dead_bases: set[int] = set()
        self._live_bases: dict[int, bool] = {}
        self.result = TimelineResult(mode=mode, cluster=self.cluster)
        self.flush_count = 0
        self._recorded_since_flush = 0
        self._in_record = 0
        # -- tracing (repro.obs): a policy/kwarg request, or REPRO_TRACE.
        # "1"/"true" enable collection; any other non-"0" value is also an
        # export path written at close().  A trace() context manager active
        # at __enter__ wins: the runtime adopts the ambient collector so
        # one trace can span several runtimes.
        if trace is False or trace is None:
            env = os.environ.get("REPRO_TRACE", "")
            if env not in ("", "0", "false", "False"):
                trace = True if env in ("1", "true", "True") else env
        self.trace_path = trace if isinstance(trace, str) else None
        self._trace_requested = bool(trace)
        self._trace_owned = False
        self._trace_prev = None
        self.tracer = None
        # -- static verification (repro.analysis): a policy/kwarg request,
        # or REPRO_VERIFY=plan|full from the environment (mirrors
        # REPRO_TRACE: the env only applies when the kwarg stayed "off").
        if verify == "off":
            env = os.environ.get("REPRO_VERIFY", "")
            if env not in ("", "0", "off", "false", "False"):
                verify = env
        if verify not in ("off", "plan", "full"):
            raise ValueError(f"unknown verify {verify!r} (off|plan|full)")
        self.verify_mode = verify
        self.verify_stats = None
        self.last_verify_report = None
        if verify != "off":
            from repro.analysis import VerifyStats

            self.verify_stats = VerifyStats()
        # -- plan-shape cache: a cone whose canonical structural signature
        # was planned (and verified) once replays the recorded rewrite
        # recipe instead of re-running the pass pipeline.  Kwarg wins;
        # None defers to REPRO_PLAN_CACHE (default: enabled).
        if plan_cache is None:
            env = os.environ.get("REPRO_PLAN_CACHE", "")
            plan_cache = env not in ("0", "false", "False", "off")
        self.plan_cache_enabled = bool(plan_cache) and bool(self.passes)
        self._plan_cache = None
        if self.plan_cache_enabled:
            from .plan_cache import PlanCache

            self._plan_cache = PlanCache()
        # guards plan_stats / verify_stats / last_verify_report: with the
        # plan stage off the record lock, several submitting threads
        # plan (and verify) concurrently
        self._stats_lock = threading.Lock()
        # guards lazy executor/backend/channel construction (first
        # concurrent submit_cone calls race to build them)
        self._exec_lock = threading.Lock()
        # -- cross-tenant cone batching: merge several small,
        # non-conflicting in-queue cones into one executor submit round
        self.batch_cones = bool(batch_cones)
        self._batcher = (
            _ConeBatcher(self)
            if self.batch_cones and flush_backend == "async"
            else None
        )

    @classmethod
    def from_config(cls, config=None, policy=None) -> "Runtime":
        """Build a Runtime from :class:`~repro.api.config.RuntimeConfig`
        (array layout / recording) and
        :class:`~repro.api.config.ExecutionPolicy` (scheduling /
        backends) — the config-object front door; ``repro.runtime(...)``
        wraps this."""
        from repro.api.config import ExecutionPolicy, RuntimeConfig

        config = config if config is not None else RuntimeConfig()
        policy = policy if policy is not None else ExecutionPolicy()
        return cls(
            nprocs=config.nprocs,
            block_size=config.block_size,
            mode=policy.scheduler,
            cluster=policy.cluster,
            flush_threshold=config.flush_threshold,
            execute=config.execute,
            fusion=config.fusion,
            flush_backend=policy.flush,
            exec_backend=policy.backend,
            exec_channel=policy.resolved_channel,
            exec_latency=policy.latency,
            exec_progress_threads=policy.progress_threads,
            exec_steal=getattr(policy, "steal", True),
            exec_steal_threshold=getattr(policy, "steal_threshold", 4),
            exec_steal_latency=getattr(policy, "steal_latency", 1e-4),
            passes=policy.passes,
            # resolved here so ExecutionPolicy.resolved_sync is the single
            # authority on what "auto" means for the config path
            sync=policy.resolved_sync,
            trace=policy.trace,
            verify=getattr(policy, "verify", "off"),
            plan_cache=getattr(policy, "plan_cache", None),
            batch_cones=getattr(policy, "batch_cones", False),
        )

    # -- context management -------------------------------------------------
    def __enter__(self):
        if getattr(_tls, "runtime", None) is not None:
            raise RuntimeError("nested Runtimes are not supported")
        _tls.runtime = self
        if _obs.CURRENT is not None:
            # an ambient repro.trace() region owns the collector; adopt it
            self.tracer = _obs.CURRENT
        elif self._trace_requested:
            self.tracer = _obs.TraceCollector()
            self._trace_prev = _obs.activate(self.tracer)
            self._trace_owned = True
        return self

    def __exit__(self, exc_type, exc, tb):
        try:
            if exc_type is None:
                self.flush()  # §5.6 trigger 3: end of program (a barrier)
        finally:
            _tls.runtime = None
            if exc_type is None:
                self.close()  # surfaces any un-delivered drain failure
            else:
                try:
                    self.close()
                except Exception:
                    # the body's exception is the one that matters;
                    # resources were still released
                    pass
        return False

    def close(self) -> None:
        """Release executor resources: join *all* outstanding
        ``FlushTicket``s in submission order, stop the persistent worker
        pool, and shut down the channel's progress threads.  The first
        executor exception encountered while joining — including
        failures parked by the reaper that no waiter ever observed — is
        re-raised *after* every resource is released: a close must not
        silently drop a drain failure.  ``__exit__`` calls this on both
        the clean and the exception path; double-close is a no-op."""
        if self._closed:
            return
        err: Optional[BaseException] = None
        try:
            try:
                self._sync_outstanding()
            except BaseException as exc:
                # a pool-level failure already dropped its executor; the
                # resource release below must still happen before the
                # failure surfaces
                err = exc
        finally:
            self._closed = True
            if self._exec_executor_obj is not None:
                self._exec_executor_obj.close()
                self._exec_executor_obj = None
            if self._exec_channel_obj is not None:
                self._exec_channel_obj.close()
                self._exec_channel_obj = None
                self._exec_backend_obj = None
            if self._trace_owned:
                _obs.deactivate(self._trace_prev)
                self._trace_owned = False
                if self.trace_path and self.tracer is not None:
                    from repro.obs.export import export_trace

                    export_trace(self.tracer, self.trace_path)
        if err is not None:
            raise err

    # -- array creation -------------------------------------------------------
    def _make_layout(self, shape, block_shape=None) -> Layout:
        nd = len(shape)
        if block_shape is None:
            bs = self.block_size
            if isinstance(bs, int):
                block_shape = tuple(max(1, min(bs, s)) for s in shape)
            else:
                block_shape = tuple(
                    max(1, min(b, s)) for b, s in zip(bs, shape)
                )
        # grid-aware process grid: assign process factors to the dims with
        # the most blocks (a [n,1] vector gets pgrid (p,1), not (√p,√p))
        grid = [max(1, -(-s // b)) for s, b in zip(shape, block_shape)]
        pgrid = [1] * nd
        n = self.nprocs
        factors = []
        f = 2
        while f * f <= n:
            while n % f == 0:
                factors.append(f)
                n //= f
            f += 1
        if n > 1:
            factors.append(n)
        if nd:
            for f in sorted(factors, reverse=True):
                i = max(range(nd), key=lambda d: grid[d] / pgrid[d])
                pgrid[i] *= f
        return Layout(tuple(shape), tuple(block_shape), tuple(pgrid))

    def new_base(self, shape, dtype, block_shape=None) -> ArrayBase:
        base = ArrayBase(shape, dtype, self._make_layout(shape, block_shape))
        self._live_bases[base.id] = True
        weakref.finalize(base, self._dead_bases.add, base.id)
        return base

    def scatter(self, base: ArrayBase, data: np.ndarray) -> None:
        """Distribute host data into base-blocks (eager, creation time)."""
        data = np.asarray(data, dtype=base.dtype).reshape(base.shape)
        for coord, sl in base.layout.blocks():
            self.storage[(base.id, coord)] = np.array(data[sl], copy=True)

    def fill_base(self, base: ArrayBase, value) -> None:
        for coord, _ in base.layout.blocks():
            self.storage[(base.id, coord)] = np.full(
                base.layout.block_shape_at(coord), value, dtype=base.dtype
            )

    def gather(self, base: ArrayBase, view: ViewSpec) -> np.ndarray:
        """Read back a view (flushes first — §5.6 trigger 1).

        Under ``sync="demand"`` only the dependency cone of the blocks
        ``view`` touches is drained — the transitive producer closure of
        their pending writes — and everything else stays recorded; under
        ``sync="barrier"`` the whole graph is drained (the paper's
        original semantics)."""
        spec = OperandSpec(view, base.layout, tuple(range(view.ndim)))
        if self.sync_mode == "demand":
            keys = {
                (base.id, frag.block)
                for _, (frag,) in fragment_iteration_space(view.vshape, (spec,))
            }
            self.flush(targets=keys)
        else:
            self.flush()
        out = np.empty(view.vshape, dtype=base.dtype)
        for vint, (frag,) in fragment_iteration_space(view.vshape, (spec,)):
            dst = tuple(slice(lo, hi) for lo, hi in vint)
            blk = self.storage.get((base.id, frag.block))
            if blk is None:
                raise RuntimeError(
                    f"array base {base.id} has no block storage — its blocks "
                    f"were purged after every owning array was garbage-"
                    f"collected; keep a reference to the DistArray (or its "
                    f"ArrayFuture) until readback"
                )
            out[dst] = blk[frag.slices]
        return out

    # -- recording ------------------------------------------------------------
    def _write_version(self, base_id: int, coord: tuple) -> int:
        return self._write_epoch.get((base_id, coord), 0)

    def _bump_write(self, base_id: int, coord: tuple) -> None:
        k = (base_id, coord)
        self._write_epoch[k] = self._write_epoch.get(k, 0) + 1

    def _transfer(self, base: ArrayBase, frag: Fragment, dst_proc: int) -> int:
        """Record (dedup'd) communication of one sub-view-block to
        ``dst_proc``; returns the scratch id the data will land in."""
        key = (
            base.id,
            frag.block,
            frag.local,
            dst_proc,
            self._write_version(base.id, frag.block),
        )
        sid = self._xfer_cache.get(key)
        if sid is not None:
            return sid
        sid = next(_scratch_ids)
        self._xfer_cache[key] = sid
        nbytes = frag.size * base.dtype.itemsize
        op = OperationNode(
            COMM,
            TransferPayload(("b", base.id, frag), sid),
            procs=(frag.owner, dst_proc),
            nbytes=nbytes,
            label=f"xfer b{base.id}{frag.block}->p{dst_proc}",
        )
        op.add_access(AccessNode((base.id, frag.block), frag.region, write=False))
        op.add_access(AccessNode(("s", sid), None, write=True))
        self.deps.insert(op)
        return sid

    def _transfer_scratch(self, sid_src: int, nbytes: int, src: int, dst: int) -> int:
        sid = next(_scratch_ids)
        op = OperationNode(
            COMM,
            TransferPayload(("s", sid_src), sid),
            procs=(src, dst),
            nbytes=nbytes,
            label=f"xfer s{sid_src}->p{dst}",
        )
        op.add_access(AccessNode(("s", sid_src), None, write=False))
        op.add_access(AccessNode(("s", sid), None, write=True))
        self.deps.insert(op)
        return sid

    def _insert_compute(self, payload, out_base, out_frag, reads, cost, label=""):
        op = OperationNode(
            COMPUTE, payload, procs=(out_frag.owner,), cost=cost, label=label
        )
        op.add_access(
            AccessNode((out_base.id, out_frag.block), out_frag.region, write=True)
        )
        for ref in reads:
            kind = ref[0]
            if kind == "b":
                _, bid, frag = ref
                op.add_access(AccessNode((bid, frag.block), frag.region, write=False))
            elif kind == "s":
                op.add_access(AccessNode(("s", ref[1]), None, write=False))
        self.deps.insert(op)
        self._bump_write(out_base.id, out_frag.block)
        self._recorded_since_flush += 1

    def _maybe_flush(self) -> None:
        if self._in_record == 0 and self._recorded_since_flush >= self.flush_threshold:
            # §5.6 trigger 2: threshold.  A demand-driven async runtime
            # kicks the drain off WITHOUT joining it — communication is
            # initiated as aggressively as possible while the main thread
            # keeps recording (the paper's motivation, on real threads).
            if self.sync_mode == "demand" and self.flush_backend == "async":
                self.flush(wait=False)
            else:
                self.flush()

    def record_map(
        self,
        ufunc: UFunc,
        out,  # (ArrayBase, ViewSpec)
        inputs: Sequence,  # list of (ArrayBase, ViewSpec) or ("c", scalar)
    ) -> None:
        """Record an elementwise ufunc over equally-shaped views (with
        numpy-style length-1 broadcasting)."""
        self._in_record += 1
        try:
            self._record_map(ufunc, out, inputs)
        finally:
            self._in_record -= 1
        self._maybe_flush()

    def _record_map(self, ufunc, out, inputs) -> None:
        out_base, out_view = out
        nd = out_view.ndim
        dims = tuple(range(nd))
        specs = [OperandSpec(out_view, out_base.layout, dims)]
        arr_inputs = []
        for inp in inputs:
            if isinstance(inp, tuple) and inp and inp[0] == "c":
                arr_inputs.append(None)
            else:
                b, v = inp
                specs.append(OperandSpec(v, b.layout, dims))
                arr_inputs.append((b, v))
        frags_all = fragment_iteration_space(out_view.vshape, specs)
        for vint, frags in frags_all:
            out_frag = frags[0]
            dst = out_frag.owner
            args = []
            reads = []
            fi = 1
            for inp, orig in zip(arr_inputs, inputs):
                if inp is None:
                    args.append(("c", orig[1]))
                    continue
                b, _ = inp
                frag = frags[fi]
                fi += 1
                if frag.owner != dst:
                    sid = self._transfer(b, frag, dst)
                    ref = ("s", sid)
                else:
                    ref = ("b", b.id, frag)
                args.append(ref)
                reads.append(ref)
            size = out_frag.size
            payload = MapPayload(ufunc, out_base.id, out_frag, tuple(args), out_base.dtype)
            cost = size * ufunc.cost * self.cluster.elem_time
            self._insert_compute(
                payload, out_base, out_frag, reads, cost, label=f"map:{ufunc.name}"
            )

    def record_fill(self, out, value) -> None:
        out_base, out_view = out
        dims = tuple(range(out_view.ndim))
        spec = OperandSpec(out_view, out_base.layout, dims)
        for _, (frag,) in fragment_iteration_space(out_view.vshape, (spec,)):
            payload = FillPayload(out_base.id, frag, value)
            cost = frag.size * self.cluster.elem_time
            self._insert_compute(payload, out_base, frag, (), cost, label="fill")
        self._maybe_flush()

    def record_reduce(
        self, ufunc_name: str, out, inp, axes: tuple[int, ...], keepdims: bool = False
    ) -> None:
        """Record ``out = reduce(ufunc, inp, axes)``; ``out``'s dims are
        ``inp``'s dims with ``axes`` removed (or kept as length-1 when
        ``keepdims``)."""
        self._in_record += 1
        try:
            self._record_reduce(ufunc_name, out, inp, axes, keepdims)
        finally:
            self._in_record -= 1
        self._maybe_flush()

    def _record_reduce(self, ufunc_name, out, inp, axes, keepdims) -> None:
        in_base, in_view = inp
        out_base, out_view = out
        nd = in_view.ndim
        kept = tuple(d for d in range(nd) if d not in axes)
        out_dims = tuple(range(nd)) if keepdims else kept
        specs = (
            OperandSpec(in_view, in_base.layout, tuple(range(nd))),
            OperandSpec(out_view, out_base.layout, out_dims),
        )
        for vint, (in_frag, out_frag) in fragment_iteration_space(
            in_view.vshape, specs
        ):
            src_owner = in_frag.owner
            dst_owner = out_frag.owner
            # stage 1: partial reduce at the data's owner
            sid = next(_scratch_ids)
            p1 = ReducePartialPayload(
                ufunc_name, ("b", in_base.id, in_frag), axes, sid, keepdims
            )
            op = OperationNode(
                COMPUTE,
                p1,
                procs=(src_owner,),
                cost=in_frag.size * self.cluster.elem_time,
                label=f"reduce:{ufunc_name}",
            )
            op.add_access(
                AccessNode((in_base.id, in_frag.block), in_frag.region, write=False)
            )
            op.add_access(AccessNode(("s", sid), None, write=True))
            self.deps.insert(op)
            # stage 2: ship the partial if needed
            if src_owner != dst_owner:
                nbytes = out_frag.size * out_base.dtype.itemsize
                sid = self._transfer_scratch(sid, nbytes, src_owner, dst_owner)
            # stage 3: combine into the output fragment
            ckey = (out_base.id, out_frag.block, out_frag.region)
            init = ckey not in self._combine_seen
            self._combine_seen.add(ckey)
            p3 = CombinePayload(ufunc_name, out_base.id, out_frag, sid, init)
            self._insert_compute(
                p3,
                out_base,
                out_frag,
                (("s", sid),),
                out_frag.size * self.cluster.elem_time,
                label=f"combine:{ufunc_name}",
            )

    def record_matmul(self, out, a, b, trans_a=False, trans_b=False) -> None:
        """Blocked matmul C[m,n] = Σ_k A[m,k]·B[k,n] (SUMMA-style: operand
        blocks are communicated to the owner of the output block, dedup'd
        per destination — paper §6.1.1)."""
        self._in_record += 1
        try:
            self._record_matmul(out, a, b, trans_a, trans_b)
        finally:
            self._in_record -= 1
        self._maybe_flush()

    def _record_matmul(self, out, a, b, trans_a, trans_b) -> None:
        out_base, out_view = out
        a_base, a_view = a
        b_base, b_view = b
        M, N = out_view.vshape
        K = a_view.vshape[0 if trans_a else 1]
        a_dims = (2, 0) if trans_a else (0, 2)
        b_dims = (1, 2) if trans_b else (2, 1)
        specs = (
            OperandSpec(out_view, out_base.layout, (0, 1)),
            OperandSpec(a_view, a_base.layout, a_dims),
            OperandSpec(b_view, b_base.layout, b_dims),
        )
        for vint, (c_frag, a_frag, b_frag) in fragment_iteration_space(
            (M, N, K), specs
        ):
            dst = c_frag.owner
            refs = []
            for base, frag in ((a_base, a_frag), (b_base, b_frag)):
                if frag.owner != dst:
                    refs.append(("s", self._transfer(base, frag, dst)))
                else:
                    refs.append(("b", base.id, frag))
            ckey = (out_base.id, c_frag.block, c_frag.region, "mm")
            init = ckey not in self._combine_seen
            self._combine_seen.add(ckey)
            m, n = (vint[0][1] - vint[0][0]), (vint[1][1] - vint[1][0])
            k = vint[2][1] - vint[2][0]
            payload = MatmulPayload(
                out_base.id, c_frag, refs[0], refs[1], trans_a, trans_b, init
            )
            cost = 2.0 * m * n * k * self.cluster.flop_time
            self._insert_compute(
                payload, out_base, c_frag, refs, cost, label="matmul"
            )

    # -- execution backend ------------------------------------------------
    def _resolve(self, ref):
        return resolve_ref(ref, self.storage, self.scratch)

    def _execute(self, op: OperationNode) -> None:
        execute_payload(op.payload, self.storage, self.scratch)

    # -- flush (§5.6 record -> plan -> §5.7 execute) --------------------------
    def flush(self, wait: bool = True, targets=None):
        """Drain recorded operations — all of them, or just the
        dependency cone of ``targets``.

        ``targets`` (``None`` = whole graph) is an iterable of
        DistArrays / ArrayBases / base ids: only the transitive producer
        closure of their pending writes
        (:func:`repro.core.graph.producer_cone`) is extracted,
        re-inserted via ``DependencySystem.rebuild``, planned, and
        drained; the rest of the recorded graph stays pending.

        ``wait=True`` blocks until the drain completes and returns the
        per-flush stats object (:class:`TimelineResult` under the
        simulated backend, :class:`repro.exec.WaitStats` under the async
        one, ``None`` when nothing had to be drained).  ``wait=False``
        submits the drain to the persistent executor and returns a
        :class:`FlushTicket` immediately, so recording continues on the
        main thread while workers drain and communication overlaps with
        Python-side recording (under the simulated backend the drain is
        synchronous and the ticket comes back completed).

        ``flush`` is *re-entrant with respect to in-flight drains*: a
        cone flush joins only the outstanding tickets whose access
        footprints **conflict** with the new cone
        (:func:`repro.core.graph.cones_conflict`); disjoint cones drain
        concurrently on the shared worker pool.  A whole-graph flush
        (``targets=None``) is a barrier — it joins every outstanding
        ticket first.  Calls to ``flush`` itself must be externally
        serialized (recording is single-threaded; the serve layer's
        record lock guarantees this).

        A cone flush is the :meth:`extract_cone` + :meth:`submit_cone`
        pair run back to back: record-side extraction (which must stay
        under the caller's record serialization) followed by
        plan + verify + executor submission (which does not — the serve
        layer calls the two halves separately, so planning runs off the
        record lock).

        The flush remains a three-stage pipeline: the (cone of the)
        *recorded* graph goes through the *plan* stage
        (:func:`repro.core.plan.plan` runs the configured pass pipeline
        on the cone only), then the planned graph is *executed* by the
        scheduler or the async executor."""
        if self._closed:
            raise RuntimeError("Runtime is closed")
        if targets is not None:
            handle = self.extract_cone(targets)
            ticket = self.submit_cone(handle, cleanup=True)
            if wait:
                res = ticket.wait()
                self._barrier_cleanup()
                return res
            return ticket
        self._sync_outstanding()  # a barrier: join every drain
        deps = self.deps
        dead = set(self._dead_bases)
        n_total = deps.n_pending
        if deps.n_pending == 0:
            self._barrier_cleanup()
            return None if wait else FlushTicket(self)
        self.deps = DependencySystem()  # recording continues here
        fid = self.flush_count + 1
        col = _obs.CURRENT
        if col is not None:
            col.flush_begin(
                fid, n_total, deps.n_pending, self.sync_mode, self.flush_backend
            )
            col.counter("cone-ops", deps.n_pending)
        hints = {}
        if self.passes:
            from .plan import plan as run_plan

            pre_views = None
            if self.verify_mode != "off":
                # snapshot footprints BEFORE planning: passes rewrite
                # payloads/accesses in place (fill→map const folding), so
                # the pre-plan op objects are not a record of the pre-plan
                # program — immutable OpViews are
                from repro.analysis import snapshot_ops

                _t0 = _time.perf_counter()
                pre_views = snapshot_ops(deps.pending_ops())
                with self._stats_lock:
                    self.verify_stats.verify_seconds += (
                        _time.perf_counter() - _t0
                    )
            planned = run_plan(
                deps,
                self.passes,
                dead_bases=dead,
                storage=self.storage,
            )
            deps = planned.deps
            hints = planned.hints
            with self._stats_lock:
                self.plan_stats.merge(planned.stats)
            if pre_views is not None:
                self._verify_plan(pre_views, planned, dead)
        self.flush_count += 1
        self._recorded_since_flush = self.deps.n_pending
        if self.flush_backend == "async":
            ticket = self._flush_async(deps, hints, fid, keys=None,
                                       regions=None)
            if wait:
                res = ticket.wait()
                self._barrier_cleanup()
                return res
            with self._ticket_lock:
                self._tickets.append(ticket)
            return ticket
        from repro.api.registry import get_scheduler

        if col is not None:
            col.drain_begin(fid, deps.n_pending, self.nprocs)
        res = get_scheduler(self.mode)(
            deps,
            self.cluster,
            executor=self._execute if self.execute else None,
        )
        if col is not None:
            col.drain_end(fid)
        self.result.merge(res)
        self._barrier_cleanup()
        return res if wait else FlushTicket(self, stats=res)

    # -- the record/plan split (cone flushes) -------------------------------
    def extract_cone(self, targets) -> PendingFlush:
        """Record-side half of a cone flush: split the recorded graph
        into the dependency cone of ``targets`` and the remainder, and
        return a :class:`PendingFlush` whose (still pending) ticket is
        already registered with the runtime.

        This is the only part of a cone flush that reads or writes
        recording state (``self.deps``, the dead-base set, the flush
        counter), so it is the only part that must run under the
        caller's record serialization — the serve layer holds its
        record lock exactly across this call and releases it before
        :meth:`submit_cone` plans and submits the cone."""
        if self._closed:
            raise RuntimeError("Runtime is closed")
        from .graph import cone_access_keys

        self._reap_tickets()  # fold finished drains' stats, keep going
        resolved = self._resolve_targets(targets)
        dead = set(self._dead_bases)
        n_total = self.deps.n_pending
        cone_ops, rest_ops = producer_cone(self.deps.pending_ops(), resolved)
        # even an empty cone must serialize against in-flight writes
        # to the requested blocks: the caller is about to *read* them
        keys = cone_access_keys(cone_ops)
        if not cone_ops:
            read_keys = {k for k in resolved if isinstance(k, tuple)}
            ids = {k for k in resolved if not isinstance(k, tuple)}
            return PendingFlush(
                ticket=FlushTicket(self, pending=True),
                deps=None,
                keys=keys,
                dead=set(),
                fid=None,
                n_total=n_total,
                empty_read=(read_keys, ids),
            )
        regions = None
        if self.verify_mode == "full":
            # region-level race oracle against the in-flight drains,
            # BEFORE the extraction commits: a failure aborts the flush
            # with the recorded graph and every in-flight drain
            # untouched.  It stays under the caller's record
            # serialization because "in-flight" is defined by extraction
            # order — and it stamps the regions on the pending ticket,
            # so later extractions can race-check against this cone
            # while it is still being planned off the lock.
            from .graph import cone_region_footprint

            _t0 = _time.perf_counter()
            regions = cone_region_footprint(cone_ops)
            self._verify_races(keys, regions)
            with self._stats_lock:
                self.verify_stats.verify_seconds += (
                    _time.perf_counter() - _t0
                )
        # a GC'd base only licenses dead-store elimination when no
        # *remainder* operation still touches it: the cone may hold a
        # dead temp's producer (pulled in as an anti-dependency) while
        # its consumer stays pending — that store is NOT dead yet
        dead -= {acc.key[0] for op in rest_ops for acc in op.accesses}
        self.deps = DependencySystem.rebuild(rest_ops)
        cone_deps = DependencySystem.rebuild(cone_ops)
        self.flush_count += 1
        fid = self.flush_count
        self._recorded_since_flush = self.deps.n_pending
        # the pending ticket joins the outstanding list NOW, before the
        # record serialization is released: a later cone that conflicts
        # with this one must find it and wait, even though its future
        # does not exist yet (extraction order is the total order
        # _join_conflicting's `before=` bound keys off)
        ticket = FlushTicket(self, pending=True, tag=fid, keys=keys,
                             regions=regions)
        with self._ticket_lock:
            self._tickets.append(ticket)
        col = _obs.CURRENT
        if col is not None:
            col.flush_begin(
                fid, n_total, cone_deps.n_pending, self.sync_mode,
                self.flush_backend,
            )
            col.counter("cone-ops", cone_deps.n_pending)
        return PendingFlush(
            ticket=ticket,
            deps=cone_deps,
            keys=keys,
            dead=dead,
            fid=fid,
            n_total=n_total,
        )

    def submit_cone(self, handle: PendingFlush, cleanup: bool = False) -> FlushTicket:
        """Plan, verify, and submit an extracted cone — the half of a
        cone flush that needs **no** record serialization: it touches
        only the :class:`PendingFlush`'s own state plus thread-safe
        runtime structures, so concurrent client threads may plan and
        submit their cones in parallel.

        Any failure (verification, planning, executor submission) fails
        the handle's ticket — waiters and done-callbacks observe it —
        and re-raises on this thread.  ``cleanup=True`` additionally
        runs barrier housekeeping on the inline paths (empty cone /
        simulated drain); callers running off the record lock must
        leave it False, since scratch recycling races with concurrent
        recording."""
        ticket = handle.ticket
        try:
            self._submit_cone_inner(handle, cleanup)
        except BaseException as exc:
            ticket._fail(exc)
            raise
        return ticket

    def _submit_cone_inner(self, handle: PendingFlush, cleanup: bool) -> None:
        ticket = handle.ticket
        if handle.deps is None:  # empty cone: join in-flight writers only
            read_keys, ids = handle.empty_read
            self._join_conflicting((read_keys, set()), base_ids=ids)
            if cleanup:
                self._barrier_cleanup()
            ticket._resolve_local()
            return
        deps = handle.deps
        # (verify="full"'s race oracle already ran in extract_cone,
        # under the record serialization that defines "in-flight")
        self._join_conflicting(handle.keys, before=ticket)
        deps, hints = self._plan_cone(handle)
        if self.flush_backend == "async":
            if self._batcher is not None:
                self._batcher.enqueue(deps, hints, ticket)
            else:
                executor = self._ensure_executor()
                fut = executor.submit(
                    deps,
                    batch_dispatch=bool(hints.get("batch_dispatch")),
                    tag=handle.fid,
                )
                ticket._bind(fut)
            return
        # simulated backend (sync="demand" with flush_backend="sim"):
        # the drain runs inline on this thread, as before the split
        from repro.api.registry import get_scheduler

        col = _obs.CURRENT
        if col is not None:
            col.drain_begin(handle.fid, deps.n_pending, self.nprocs)
        res = get_scheduler(self.mode)(
            deps,
            self.cluster,
            executor=self._execute if self.execute else None,
        )
        if col is not None:
            col.drain_end(handle.fid)
        self.result.merge(res)
        if cleanup:
            ticket._resolve_local(res)
            self._barrier_cleanup()
        else:
            ticket._resolve_local(res)

    def _plan_cone(self, handle: PendingFlush):
        """Plan stage of one extracted cone: plan-shape cache hit →
        replay the recorded rewrite recipe; miss → run the pass
        pipeline, verify, and insert the recipe.  Returns the planned
        ``(deps, hints)``.  Thread-safe: shared counters are folded
        under ``_stats_lock``, the cache locks internally."""
        deps = handle.deps
        if not self.passes:
            return deps, {}
        from .plan import plan as run_plan

        pending = deps.pending_ops()
        cache = self._plan_cache
        col = _obs.CURRENT
        sig = None
        if cache is not None:
            sig = cache.signature(pending, handle.dead, self.passes,
                                  self.storage)
            if sig is not None:
                entry = cache.lookup(sig)
                if entry is not None:
                    if col is not None:
                        col.plan_cache(handle.fid, True, len(pending))
                    new_deps, hints, stats = cache.replay(
                        entry, deps, pending
                    )
                    with self._stats_lock:
                        self.plan_stats.merge(stats)
                    return new_deps, hints
            if col is not None:
                col.plan_cache(handle.fid, False, len(pending))
        pre_views = None
        if self.verify_mode != "off" or sig is not None:
            # snapshot footprints BEFORE planning: passes rewrite
            # payloads/accesses in place, so the pre-plan op objects are
            # not a record of the pre-plan program — immutable OpViews
            # are.  The cache needs the same snapshot: a cached plan
            # must stay re-verifiable on demand (verify_cached_plans).
            from repro.analysis import snapshot_ops

            _t0 = _time.perf_counter()
            pre_views = snapshot_ops(pending)
            if self.verify_mode != "off":
                with self._stats_lock:
                    self.verify_stats.verify_seconds += (
                        _time.perf_counter() - _t0
                    )
        pre_args = None
        if sig is not None:
            # pre-plan map argument tuples: const folding mutates
            # MapPayload.args in place, so the diff against these is the
            # recipe's patch list
            pre_args = {
                op.uid: op.payload.args
                for op in pending
                if isinstance(op.payload, MapPayload)
            }
        planned = run_plan(
            deps, self.passes, dead_bases=handle.dead, storage=self.storage
        )
        with self._stats_lock:
            self.plan_stats.merge(planned.stats)
        if self.verify_mode != "off":
            self._verify_plan(pre_views, planned, handle.dead)
        if sig is not None:
            cache.insert(
                sig,
                pending,
                pre_args,
                planned,
                handle.dead,
                pre_views=pre_views,
                scratch_available=set(self.scratch),
            )
        return planned.deps, planned.hints

    def verify_cached_plans(self):
        """Re-run the static plan verifier over every resident
        plan-cache entry (each was verified — or at least verifiable —
        once at insert; this proves the cached recipes are *still*
        sound on demand, e.g. from the ``graph-lint`` CI job).  Returns
        the list of :class:`repro.analysis.AnalysisReport`; raises
        :class:`repro.analysis.VerificationError` on any error-severity
        finding."""
        if self._plan_cache is None:
            return []
        from repro.analysis import check_cached_plans

        reports = check_cached_plans(self._plan_cache)
        for r in reports:
            r.raise_if_errors()
        return reports

    @staticmethod
    def _resolve_targets(targets) -> set:
        """Normalize flush targets to the mixed set
        :func:`~repro.core.graph.producer_cone` takes: base ids (ints —
        every block of that base) and/or exact ``(base_id, block)``
        keys.  A DistArray contributes only the block keys its *view*
        touches, so reading a sub-view forces a sub-cone."""
        ids = set()
        for t in targets:
            if isinstance(t, (int, np.integer)):
                ids.add(int(t))
            elif isinstance(t, tuple):
                ids.add(t)  # explicit (base_id, block) access key
            elif isinstance(t, ArrayBase):
                ids.add(t.id)
            else:
                base = getattr(t, "_base", None)  # DistArray, duck-typed
                view = getattr(t, "_view", None)
                if not isinstance(base, ArrayBase):
                    raise TypeError(
                        f"cannot flush towards {type(t).__name__}: expected a "
                        f"DistArray, an ArrayBase, a base id, or a "
                        f"(base_id, block) key"
                    )
                spec = OperandSpec(view, base.layout, tuple(range(view.ndim)))
                for _, (frag,) in fragment_iteration_space(
                    view.vshape, (spec,)
                ):
                    ids.add((base.id, frag.block))
        return ids

    def _flush_async(self, deps, hints, tag=None, keys=None,
                     regions=None) -> FlushTicket:
        """Submit ``deps`` to the persistent multi-worker executor
        (repro.exec) and return the in-flight ticket without joining."""
        executor = self._ensure_executor()
        fut = executor.submit(
            deps, batch_dispatch=bool(hints.get("batch_dispatch")), tag=tag
        )
        return FlushTicket(self, fut=fut, tag=tag, keys=keys, regions=regions)

    def _submit_batch(self, batch) -> None:
        """Submit one batcher round — ``(deps, hints, ticket)`` triples
        of mutually non-conflicting planned cones — to the executor and
        bind each ticket to its future.  A single cone goes through the
        plain ``submit`` path; several go through ``submit_many`` (one
        global-lock round for the group).  On failure every ticket in
        the round is failed before re-raising."""
        try:
            executor = self._ensure_executor()
            if len(batch) == 1:
                deps, hints, ticket = batch[0]
                fut = executor.submit(
                    deps,
                    batch_dispatch=bool(hints.get("batch_dispatch")),
                    tag=ticket._tag,
                )
                ticket._bind(fut)
                return
            items = [(deps, ticket._tag) for deps, _h, ticket in batch]
            bd = any(bool(h.get("batch_dispatch")) for _d, h, _t in batch)
            futs = executor.submit_many(items, batch_dispatch=bd)
            for (_d, _h, ticket), fut in zip(batch, futs):
                ticket._bind(fut)
        except BaseException as exc:
            for _d, _h, ticket in batch:
                ticket._fail(exc)
            raise

    def _ensure_executor(self):
        from repro.exec import AsyncExecutor, make_backend, make_channel

        with self._exec_lock:
            return self._ensure_executor_locked(
                AsyncExecutor, make_backend, make_channel
            )

    def _ensure_executor_locked(self, AsyncExecutor, make_backend,
                                make_channel):
        if self._exec_backend_obj is None:
            self._exec_backend_obj = make_backend(
                self.exec_backend, self.storage, self.scratch
            )
            self._exec_channel_obj = make_channel(
                self.exec_channel,
                latency=self.exec_latency,
                progress_threads=self.exec_progress_threads,
            )
        if self._exec_executor_obj is None:
            self._exec_executor_obj = AsyncExecutor(
                nworkers=self.nprocs,
                storage=self.storage,
                scratch=self.scratch,
                backend=self._exec_backend_obj,
                channel=self._exec_channel_obj,
                steal=self.exec_steal,
                steal_threshold=self.exec_steal_threshold,
                steal_latency=self.exec_steal_latency,
            )
        return self._exec_executor_obj

    # -- ticket bookkeeping -------------------------------------------------
    def _sync_outstanding(self) -> None:
        """Join *every* outstanding ``wait=False`` flush in submission
        order, merging stats.  Raises the first failure — deferred
        errors (observed by the reaper with no waiter) first, then the
        first failing join — after all tickets resolved: a barrier must
        never silently drop an executor exception."""
        errors: list[BaseException]
        with self._ticket_lock:
            errors = self._deferred_errors
            self._deferred_errors = []
        while True:
            with self._ticket_lock:
                t = self._tickets[0] if self._tickets else None
            if t is None:
                break
            try:
                t.wait()
            except BaseException as exc:
                errors.append(exc)
        if errors:
            raise errors[0]

    def _reap_tickets(self) -> None:
        """Fold the stats of already-completed tickets without blocking
        on the in-flight ones.  A completed-failed ticket nobody waited
        yet parks its error in ``_deferred_errors`` — surfaced at the
        next barrier (``_sync_outstanding``) — while the ticket itself
        keeps re-raising to any late waiter."""
        with self._ticket_lock:
            done = [t for t in self._tickets if t.done()]
        for t in done:
            try:
                t.wait()
            except BaseException as exc:
                with self._ticket_lock:
                    self._deferred_errors.append(exc)

    def _join_conflicting(self, keys, base_ids=None, before=None) -> None:
        """Join every outstanding ticket whose cone footprint conflicts
        with ``keys`` (``(reads, writes)``); tickets with no footprint
        (whole-graph flushes) conflict with everything.  ``base_ids``
        extends the read set to *all* blocks of the given bases (a
        whole-base readback with nothing pending must still wait for
        in-flight writers of any of its blocks).

        ``before`` bounds the scan at the caller's own (still pending)
        ticket: with planning off the record lock, several threads join
        concurrently, and each may only wait on tickets *extracted
        earlier* than its own — extraction order is a total order, so
        waiting only backwards keeps the wait graph acyclic."""
        from .graph import cones_conflict

        def _conflicts(t: FlushTicket) -> bool:
            if t._keys is None:
                return True
            if cones_conflict(t._keys, keys):
                return True
            if base_ids:
                _, tw = t._keys
                if any(k[0] in base_ids for k in tw if isinstance(k, tuple)):
                    return True
            return False

        while True:
            with self._ticket_lock:
                t = None
                for cand in self._tickets:
                    if cand is before:
                        break
                    if _conflicts(cand):
                        t = cand
                        break
            if t is None:
                return
            t.wait()  # propagates the conflicting drain's failure

    # -- static verification (repro.analysis) -------------------------------
    def _verify_plan(self, pre_views, planned, dead) -> None:
        """verify="plan"/"full": prove the planned op list preserves the
        recorded happens-before order before it reaches the executor.
        Raises :class:`repro.analysis.VerificationError` on any
        error-severity finding — the flush aborts with nothing executed
        (the cone was already extracted from the recorded graph, so the
        runtime is not usable for further flushes after the raise;
        verification failures are fatal by design)."""
        from repro.analysis import check

        _t0 = _time.perf_counter()
        report = check(
            pre=pre_views,
            post=planned.deps.pending_ops(),
            dead_bases=dead,
            provenance=planned.provenance,
            dropped=planned.dropped,
            scratch_available=set(self.scratch),
            rules=("plan", "deadlock"),
        )
        with self._stats_lock:
            stats = self.verify_stats
            stats.verify_seconds += _time.perf_counter() - _t0
            stats.n_flushes_verified += 1
            stats.n_diagnostics += len(report.diagnostics)
            self.last_verify_report = report
        report.raise_if_errors()

    def _verify_races(self, keys, regions) -> None:
        """verify="full": the region-level soundness oracle for the
        key-granular ``cones_conflict`` concurrency test.  A region-level
        conflict that key-level conflict detection misses means two
        drains the runtime would have run concurrently actually race —
        an error.  The reverse (key conflict, no region conflict) is the
        expected over-approximation; it is only *counted* (the precision
        statistic feeding the sub-block cone-precision roadmap item)."""
        from repro.analysis.diagnostics import (
            ERROR,
            AnalysisReport,
            Diagnostic,
        )
        from .graph import cones_conflict, region_footprints_conflict

        stats = self.verify_stats
        with self._ticket_lock:
            inflight = [
                t for t in self._tickets
                if not t.done() and t._keys is not None
                and t._regions is not None
            ]
        report = AnalysisReport(rules_run=("races",))
        with self._stats_lock:
            for t in inflight:
                stats.n_race_checks += 1
                kc = cones_conflict(t._keys, keys)
                rk = region_footprints_conflict(t._regions, regions)
                if rk is not None and not kc:
                    report.diagnostics.append(Diagnostic(
                        rule="races",
                        severity=ERROR,
                        message=(
                            f"region-level conflict with in-flight drain "
                            f"#{t._tag} that key-level cones_conflict missed "
                            f"— the concurrent-drain oracle is unsound"
                        ),
                        ops=(t._tag,),
                        key=rk,
                    ))
                elif kc:
                    stats.n_key_conflicts += 1
                    report.n_key_conflicts += 1
                    if rk is None:
                        stats.n_region_false_positives += 1
                        report.n_region_false_positives += 1
            if report.diagnostics:
                stats.n_diagnostics += len(report.diagnostics)
                self.last_verify_report = report
        if report.diagnostics:
            report.raise_if_errors()

    def _ticket_done(self, ticket: FlushTicket, res) -> None:
        with self._ticket_lock:
            if res is not None:
                self._ensure_exec_stats().merge(res)
            if ticket in self._tickets:
                self._tickets.remove(ticket)

    def _ticket_discard(self, ticket: FlushTicket) -> None:
        """Drop a locally-resolved ticket (empty cone / simulated drain)
        from the outstanding list.  Stats were already merged by the
        resolver; the executor is untouched."""
        with self._ticket_lock:
            if ticket in self._tickets:
                self._tickets.remove(ticket)

    def _ticket_failed(self, ticket: FlushTicket) -> None:
        with self._ticket_lock:
            if ticket in self._tickets:
                self._tickets.remove(ticket)
        # a *pool-level* failure (worker thread death) poisons the
        # executor: drop it so the next flush builds a fresh pool
        # (channel + backend survive — jit caches and progress threads
        # are unaffected).  Per-drain failures (an op raising) leave the
        # pool healthy and concurrent drains running.
        ex = self._exec_executor_obj
        if ex is not None and getattr(ex, "_error", None) is not None:
            self._exec_executor_obj = None
            ex.close()

    def _barrier_cleanup(self) -> None:
        """Housekeeping that is only safe at a true barrier — nothing in
        flight and nothing pending.  Scratch buffers, the transfer-dedup
        cache, and combine-init state must survive partial flushes
        (remainder operations still reference scratch delivered by an
        earlier cone), so they are recycled only here; likewise block
        storage of dead bases may still be read by pending operations."""
        with self._ticket_lock:
            if self._tickets:
                return
        if self.deps.n_pending:
            return
        self.scratch.clear()
        self._xfer_cache.clear()
        self._combine_seen.clear()
        self._purge_dead()

    def _ensure_exec_stats(self):
        if self.exec_stats is None:
            from repro.exec import WaitStats

            mode = "async" if self.exec_channel == "async" else "blocking-channel"
            self.exec_stats = WaitStats(mode=mode, nworkers=self.nprocs)
        return self.exec_stats

    def _purge_dead(self) -> None:
        if not self._dead_bases:
            return
        dead = self._dead_bases
        for key in [k for k in self.storage if k[0] in dead]:
            del self.storage[key]
        for key in [k for k in self._write_epoch if k[0] in dead]:
            del self._write_epoch[key]
        for bid in dead:
            self._live_bases.pop(bid, None)
        self._dead_bases = set()

    # -- reporting -------------------------------------------------------------
    def stats(self):
        """Accumulated run statistics: the simulated
        :class:`TimelineResult`, or the measured
        :class:`repro.exec.WaitStats` when ``flush_backend="async"``
        (both expose makespan / wait_fraction / speedup / summary()).

        Outstanding ``wait=False`` flushes are joined first, so the
        returned object reflects *whole-program* totals — per-cone
        WaitStats merge on ticket completion, never get dropped."""
        if self.flush_backend == "async":
            if not self._closed:
                self._sync_outstanding()
            return self._ensure_exec_stats()
        return self.result
