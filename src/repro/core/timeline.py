"""Discrete-event cluster timeline (paper §6 measurement substrate).

The scheduler emits (op, start, end, resource) events against this model;
the model supplies α–β communication costs and per-element compute costs,
and accounts busy/waiting time per process — reproducing the paper's
"time spent waiting for communication" metric.

Two built-in calibrations:

* ``GIGE_2012``  — the paper's testbed: 16 nodes, GbE (α≈50 µs,
  β≈8.4 ns/B ⇒ ~119 MB/s), ~2012-era per-core element throughput.
* ``TPU_V5E_ICI`` — a TPU-pod projection: 50 GB/s link, 1 µs latency,
  per-chip bf16 compute from the roofline constants.  Used to project the
  paper's schedule benefit onto the target hardware.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["ClusterSpec", "ProcStats", "TimelineResult", "GIGE_2012", "TPU_V5E_ICI"]


@dataclass(frozen=True)
class ClusterSpec:
    """LogGP-style model: a message of B bytes occupies each end-point NIC
    for ``o + B·β`` (send/recv overhead + bandwidth serialization) and is
    delivered after ``α + B·β`` (wire latency is pipelined — it does not
    hold the NIC, so many small messages overlap their latencies)."""

    nprocs: int
    alpha: float  # end-to-end message latency, seconds
    beta: float  # seconds per byte (inverse bandwidth)
    o_msg: float  # per-message NIC/CPU injection overhead, seconds
    elem_time: float  # seconds per scalar ufunc element
    flop_time: float  # seconds per FLOP (dense kernels, e.g. matmul)
    name: str = "cluster"

    def comm_time(self, nbytes: int) -> float:
        """End-to-end delivery time of one message."""
        return self.alpha + nbytes * self.beta

    def occupancy(self, nbytes: int) -> float:
        """NIC occupancy per message (serialization resource)."""
        return self.o_msg + nbytes * self.beta

    def with_nprocs(self, nprocs: int) -> "ClusterSpec":
        return ClusterSpec(
            nprocs,
            self.alpha,
            self.beta,
            self.o_msg,
            self.elem_time,
            self.flop_time,
            self.name,
        )

    def replace(self, **overrides) -> "ClusterSpec":
        """Derive a variant spec (same contract as the api config
        objects' ``.replace()``)."""
        import dataclasses

        return dataclasses.replace(self, **overrides)


# Paper testbed: Gigabit Ethernet, Xeon E5345 (2.33 GHz).  elem_time is
# calibrated to ~3 × 10^8 double-precision ufunc elements/s/core (NumPy-era
# memory-bound ufunc throughput); matmul at ~5 GFLOP/s/core (ATLAS dgemm).
GIGE_2012 = ClusterSpec(
    nprocs=16,
    alpha=50e-6,
    beta=1.0 / 119e6,
    o_msg=10e-6,
    elem_time=1.0 / 3.0e8,
    flop_time=1.0 / 5.0e9,
    name="gige-2012",
)

# TPU v5e-class projection: ICI 50 GB/s/link, ~1 µs collective hop latency,
# 197 TFLOP/s bf16, HBM-bound ufunc elements at 819 GB/s / 4 B.
TPU_V5E_ICI = ClusterSpec(
    nprocs=256,
    alpha=1e-6,
    beta=1.0 / 50e9,
    o_msg=0.2e-6,
    elem_time=4.0 / 819e9,
    flop_time=1.0 / 197e12,
    name="tpu-v5e-ici",
)


@dataclass
class ProcStats:
    compute_busy: float = 0.0
    comm_busy: float = 0.0  # CPU time spent inside blocking comm calls
    nic_busy: float = 0.0  # NIC occupancy (injection + serialization)
    last_end: float = 0.0
    n_compute: int = 0
    n_comm: int = 0


@dataclass
class TimelineResult:
    mode: str
    cluster: ClusterSpec
    makespan: float = 0.0
    procs: list[ProcStats] = field(default_factory=list)
    comm_bytes: int = 0
    n_comm_ops: int = 0
    n_compute_ops: int = 0
    seq_time: float = 0.0  # sum of all compute costs = 1-proc execution

    def __post_init__(self):
        if not self.procs:
            self.procs = [ProcStats() for _ in range(self.cluster.nprocs)]

    # -- paper metrics -----------------------------------------------------
    @property
    def total_compute(self) -> float:
        return sum(p.compute_busy for p in self.procs)

    @property
    def wait_fraction(self) -> float:
        """Fraction of total CPU time spent waiting for communication
        (the paper's headline metric).  Blocking comm counts as waiting."""
        if self.makespan <= 0:
            return 0.0
        total = self.cluster.nprocs * self.makespan
        return max(0.0, 1.0 - self.total_compute / total)

    @property
    def speedup(self) -> float:
        """Speedup vs. the sequential (1-process, no-comm) execution."""
        return self.seq_time / self.makespan if self.makespan > 0 else 0.0

    @property
    def ops_per_sec(self) -> float:
        """Modeled dispatch throughput: operations scheduled per
        simulated second (the measured counterpart lives on
        ``WaitStats``)."""
        total = self.n_compute_ops + self.n_comm_ops
        return total / self.makespan if self.makespan > 0 else 0.0

    @property
    def cpu_utilization(self) -> float:
        return 1.0 - self.wait_fraction

    def merge(self, other: "TimelineResult") -> "TimelineResult":
        """Accumulate a later flush into this result (timelines are
        concatenated: flushes are serialized by the interpreter)."""
        assert other.cluster.nprocs == self.cluster.nprocs
        self.makespan += other.makespan
        self.comm_bytes += other.comm_bytes
        self.n_comm_ops += other.n_comm_ops
        self.n_compute_ops += other.n_compute_ops
        self.seq_time += other.seq_time
        for mine, theirs in zip(self.procs, other.procs):
            mine.compute_busy += theirs.compute_busy
            mine.comm_busy += theirs.comm_busy
            mine.nic_busy += theirs.nic_busy
            mine.last_end += theirs.last_end
            mine.n_compute += theirs.n_compute
            mine.n_comm += theirs.n_comm
        return self

    def summary(self) -> str:
        return (
            f"[{self.mode:>14s}] makespan={self.makespan * 1e3:9.3f} ms "
            f"wait={self.wait_fraction * 100:5.1f}% "
            f"speedup={self.speedup:6.2f} "
            f"comm={self.comm_bytes / 1e6:8.2f} MB "
            f"ops={self.n_compute_ops}c/{self.n_comm_ops}m"
        )
