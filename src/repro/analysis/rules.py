"""The built-in static-analysis rules.

Three rules, registered under the same plugin registry pattern as
passes/backends (``repro.register_rule``):

* ``"plan"`` — the happens-before plan verifier.  Reconstructs the
  region-precise read/write footprint of every pre-plan and post-plan
  operation and proves that each conflicting access pair of the
  original program survives planning **in order** (§5.7: insertion
  order is the total order of conflicting accesses).  Catches
  dependence-inverting rewrites, dead-store elimination of live
  stores, stores a rewrite silently elided, and merged payloads whose
  combined footprint hoists a read past a conflicting write.  Findings
  carry pass provenance from the obs ``rewritten``/``dropped`` events.
* ``"races"`` — region-level race detector for concurrent cone drains:
  every pair of cones assumed concurrent is re-checked at ``Region``
  granularity — a soundness oracle for the key-granular
  :func:`~repro.core.graph.cones_conflict` — and key-level conflicts
  that are region-level false positives are counted as the precision
  report.
* ``"deadlock"`` — static deadlock detection: cycles in the cross-rank
  rendezvous message schedule (the paper's fig. 6 pattern, rejected at
  plan time instead of the runtime refusal), plus dangling scratch
  reads in a planned op list (a consumer whose producer a broken pass
  dropped would stall the drain).

Every rule no-ops when its inputs are absent from the
:class:`AnalysisContext`, so :func:`repro.analysis.check` can run any
subset over whatever the caller has.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.api.registry import register_rule

from .diagnostics import ERROR, INFO, AnalysisReport, Diagnostic
from .footprint import OpView, resolve_positions, snapshot_ops

__all__ = ["AnalysisContext", "check_plan", "check_races", "check_deadlock"]


@dataclass
class AnalysisContext:
    """Everything a rule may inspect.  All inputs optional — a rule
    skips silently when what it needs is missing."""

    pre: Optional[list] = None  # pre-plan OpViews, program order
    post: Optional[list] = None  # post-plan OpViews, planned order
    dead_bases: set = field(default_factory=set)
    provenance: dict = field(default_factory=dict)  # new uid -> (pass, srcs)
    dropped: dict = field(default_factory=dict)  # dropped uid -> pass
    scratch_available: set = field(default_factory=set)  # delivered sids
    cones: Optional[list] = None  # [(label, [OpView])] assumed concurrent
    schedule: Optional[list] = None  # per-rank rendezvous programs
    report: AnalysisReport = field(default_factory=AnalysisReport)
    _positions: Optional[dict] = None

    @property
    def positions(self) -> dict:
        """pre uid -> post index (absent = dropped), provenance-chased."""
        if self._positions is None:
            self._positions = resolve_positions(
                self.pre or [], self.post or [], self.provenance
            )
        return self._positions

    def emit(self, rule, severity, message, ops=(), key=None, pass_name=None):
        self.report.diagnostics.append(
            Diagnostic(rule, severity, message, tuple(ops), key, pass_name)
        )


def _name(op: OpView) -> str:
    return f"{op.label or 'op'}#{op.uid}"


def _is_scratch(key) -> bool:
    return isinstance(key, tuple) and len(key) == 2 and key[0] == "s"


# ---------------------------------------------------------------------------
# rule "plan": happens-before plan verifier
# ---------------------------------------------------------------------------


@register_rule("plan")
def check_plan(ctx: AnalysisContext) -> None:
    if ctx.pre is None or ctx.post is None:
        return
    from repro.core.graph import regions_overlap
    from repro.core.plan import region_covers

    pre, post = ctx.pre, ctx.post
    positions = ctx.positions
    dead = ctx.dead_bases or set()

    def blame(post_idx: Optional[int]) -> Optional[str]:
        if post_idx is None:
            return None
        entry = ctx.provenance.get(post[post_idx].uid)
        return entry[0] if entry else None

    # one forward walk builds the per-key access history (for the order
    # check) and the read index (for the store-liveness checks)
    hist: dict = {}  # key -> [(pre_pos, region, write, uid, post_pos)]
    reads_by_key: dict = {}  # key -> [(pre_pos, region, uid)]
    maxw: dict = {}  # key -> max post position over earlier writes
    maxr: dict = {}  # key -> max post position over earlier reads
    seen_pairs: set = set()
    for i, op in enumerate(pre):
        pos = positions.get(op.uid)
        for key, region, write in op.accesses:
            if not write:
                reads_by_key.setdefault(key, []).append((i, region, op.uid))
            if pos is not None:
                # fast path: the §5.7 common case is that nothing moved —
                # a surviving access at post position >= every earlier
                # conflicting access's position proves the pair order
                # survived without enumerating pairs (O(1) per access)
                ok = pos >= maxw.get(key, -1)
                if ok and write:
                    ok = pos >= maxr.get(key, -1)
                if not ok:
                    # precise scan: only a *conflicting* earlier access
                    # now placed after us is a real inversion (merged
                    # nodes share a position and are exempt)
                    for ppos, pregion, pwrite, puid, ppost in hist.get(key, ()):
                        if ppost is None or ppost <= pos:
                            continue
                        if not (write or pwrite):
                            continue
                        if not regions_overlap(region, pregion):
                            continue
                        pair = (puid, op.uid, key)
                        if pair in seen_pairs:
                            continue
                        seen_pairs.add(pair)
                        ctx.emit(
                            "plan", ERROR,
                            f"conflicting access pair inverted: "
                            f"{_name(pre[ppos])} precedes {_name(op)} in "
                            f"program order but the plan executes it after",
                            ops=(puid, op.uid), key=key,
                            pass_name=blame(pos) or blame(ppost),
                        )
            hist.setdefault(key, []).append((i, region, write, op.uid, pos))
            if pos is not None:
                if write:
                    if pos > maxw.get(key, -1):
                        maxw[key] = pos
                else:
                    if pos > maxr.get(key, -1):
                        maxr[key] = pos

    # store liveness: a write may only vanish from the plan when its
    # base is dead *and* no surviving later operation reads the region
    post_writes = [
        [(k, r) for k, r, w in op.accesses if w] for op in post
    ]

    def _check_lost_store(i, op, key, region, node_pos, pname):
        """A write of pre op ``op`` (at pre position ``i``) is absent
        from the planned graph (``node_pos`` = the surviving node it
        merged into, or None when the whole op was dropped)."""
        base = key[0]
        live = not _is_scratch(key) and base not in dead
        readers = [
            uid for rpos, rregion, uid in reads_by_key.get(key, ())
            if rpos > i
            and uid in positions
            and positions[uid] != node_pos
            and regions_overlap(region, rregion)
        ]
        if live:
            ctx.emit(
                "plan", ERROR,
                f"store of {_name(op)} to live base {base} was "
                f"{'elided by a rewrite' if node_pos is not None else 'dropped'}"
                f" — the base is still gatherable",
                ops=(op.uid,), key=key, pass_name=pname,
            )
        elif readers:
            ctx.emit(
                "plan", ERROR,
                f"store of {_name(op)} was "
                f"{'elided' if node_pos is not None else 'dropped'} as dead "
                f"but {len(readers)} later surviving operation(s) still "
                f"read the stored region",
                ops=(op.uid, *readers), key=key, pass_name=pname,
            )

    for i, op in enumerate(pre):
        pos = positions.get(op.uid)
        if pos is None:
            pname = ctx.dropped.get(op.uid)
            for key, region, write in op.accesses:
                if write:
                    _check_lost_store(i, op, key, region, None, pname)
            continue
        for key, region, write in op.accesses:
            if not write:
                continue
            covered = any(
                k == key and region_covers(r, region)
                for k, r in post_writes[pos]
            )
            if not covered:
                _check_lost_store(i, op, key, region, pos, blame(pos))


# ---------------------------------------------------------------------------
# rule "races": region-level race detector for concurrent cones
# ---------------------------------------------------------------------------


def _view_key_footprint(views) -> tuple[set, set]:
    reads: set = set()
    writes: set = set()
    for op in views:
        for key, _region, write in op.accesses:
            (writes if write else reads).add(key)
    return reads, writes


def view_region_footprint(views) -> dict:
    """Region-precise footprint of a cone of :class:`OpView` snapshots:
    ``key -> ([read regions], [write regions])``, with a whole-block
    access collapsing its list to ``[None]``."""
    fp: dict = {}
    for op in views:
        for key, region, write in op.accesses:
            entry = fp.get(key)
            if entry is None:
                entry = fp[key] = ([], [])
            lst = entry[1] if write else entry[0]
            if lst and lst[0] is None:
                continue
            if region is None:
                lst[:] = [None]
            else:
                lst.append(region)
    return fp


@register_rule("races")
def check_races(ctx: AnalysisContext) -> None:
    if not ctx.cones:
        return
    # key-granular verdicts come from the *current* cones_conflict (the
    # function under test when this rule is used as a soundness oracle)
    from repro.core import graph as _graph
    from repro.core.graph import region_footprints_conflict

    cones = []
    for entry in ctx.cones:
        label, ops = entry if isinstance(entry, tuple) else (None, entry)
        views = snapshot_ops(list(ops))
        cones.append((
            label if label is not None else f"cone{len(cones)}",
            _view_key_footprint(views),
            view_region_footprint(views),
        ))
    for i in range(len(cones)):
        for j in range(i + 1, len(cones)):
            la, ka, ra = cones[i]
            lb, kb, rb = cones[j]
            kc = _graph.cones_conflict(ka, kb)
            rk = region_footprints_conflict(ra, rb)
            if kc:
                ctx.report.n_key_conflicts += 1
                if rk is None:
                    ctx.report.n_region_false_positives += 1
                    ctx.emit(
                        "races", INFO,
                        f"cones {la!r} and {lb!r} conflict at key "
                        f"granularity but their regions are disjoint "
                        f"(serialization is a precision loss, not a "
                        f"correctness need)",
                    )
            elif rk is not None:
                ctx.emit(
                    "races", ERROR,
                    f"cones {la!r} and {lb!r} race: their region-level "
                    f"footprints overlap with a write, but the key-granular "
                    f"conflict check lets them drain concurrently",
                    key=rk,
                )


# ---------------------------------------------------------------------------
# rule "deadlock": message-schedule cycles + dangling scratch reads
# ---------------------------------------------------------------------------


def _format_msg_op(rank, step, kind, tag, peer) -> str:
    # same line format as the runtime refusal in
    # repro.exec.backend.run_rendezvous_bsp_async — tooling keys on it
    return f"p{rank}@step{step}: {kind} tag={tag!r} peer=p{peer}"


def _check_schedule(ctx: AnalysisContext) -> None:
    """Static fig. 6 analysis: match the k-th send p→q with tag t to
    the k-th recv at q from p with tag t (the canonical rendezvous
    matching of a deterministic program), collapse each matched pair
    into one node (both sides block until both arrive), add each rank's
    program-order edges, and look for a cycle."""
    schedule = ctx.schedule
    occ: dict = {}
    members: dict = {}  # pair key -> [(rank, step, kind, tag, peer)]
    rank_chains: list = []  # per rank: [pair key, ...] in program order
    for rank, prog in enumerate(schedule):
        chain = []
        for step, op in enumerate(prog):
            kind = op.get("kind")
            if kind not in ("send", "recv"):
                continue  # compute never blocks
            peer, tag = op["peer"], op["tag"]
            src, dst = (rank, peer) if kind == "send" else (peer, rank)
            k = occ.get((src, dst, tag, kind), 0)
            occ[(src, dst, tag, kind)] = k + 1
            pair = (src, dst, tag, k)
            members.setdefault(pair, []).append((rank, step, kind, tag, peer))
            chain.append(pair)
        rank_chains.append(chain)
    for pair, ops in members.items():
        if len(ops) != 2:
            rank, step, kind, tag, peer = ops[0]
            ctx.emit(
                "deadlock", ERROR,
                f"unmatched two-sided message — "
                f"{_format_msg_op(rank, step, kind, tag, peer)} has no "
                f"rendezvous partner and blocks forever once reached",
                key=pair[:3],
            )
    edges: dict = {}
    for chain in rank_chains:
        for a, b in zip(chain, chain[1:]):
            edges.setdefault(a, set()).add(b)
    # iterative DFS cycle detection over the pair-node graph
    WHITE, GREY, BLACK = 0, 1, 2
    color = {p: WHITE for p in members}
    for start in members:
        if color[start] != WHITE:
            continue
        stack = [(start, iter(edges.get(start, ())))]
        color[start] = GREY
        path = [start]
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                color[node] = BLACK
                stack.pop()
                path.pop()
                continue
            if color[nxt] == GREY:
                cycle = path[path.index(nxt):]
                lines = sorted(
                    _format_msg_op(*op) for p in cycle for op in members[p]
                )
                ctx.emit(
                    "deadlock", ERROR,
                    "rendezvous cycle across ranks (paper fig. 6) — every "
                    "participant waits on a partner later in another "
                    "rank's program.\nstuck operation-nodes:\n  "
                    + "\n  ".join(lines),
                    key=None,
                )
                return
            if color[nxt] == WHITE:
                color[nxt] = GREY
                path.append(nxt)
                stack.append((nxt, iter(edges.get(nxt, ()))))


def _check_dangling_scratch(ctx: AnalysisContext) -> None:
    """A planned op reading a scratch buffer no earlier planned op
    writes (and that previous drains did not already deliver) can never
    become ready — the drain stalls (or the executor crashes on the
    missing buffer).  This is the planned-graph liveness complement of
    the message-schedule cycle check."""
    avail = set(ctx.scratch_available or ())
    drop_blame: dict = {}
    for op in ctx.pre or ():
        if op.uid in ctx.dropped:
            for key, _region, write in op.accesses:
                if write and _is_scratch(key):
                    drop_blame[key[1]] = ctx.dropped[op.uid]
    for op in ctx.post:
        for key, _region, write in op.accesses:
            if write or not _is_scratch(key):
                continue
            sid = key[1]
            if sid not in avail:
                ctx.emit(
                    "deadlock", ERROR,
                    f"{_name(op)} reads scratch buffer {sid} that no "
                    f"earlier planned operation writes and no previous "
                    f"drain delivered — the drain would stall",
                    ops=(op.uid,), key=key,
                    pass_name=drop_blame.get(sid),
                )
        for key, _region, write in op.accesses:
            if write and _is_scratch(key):
                avail.add(key[1])


@register_rule("deadlock")
def check_deadlock(ctx: AnalysisContext) -> None:
    if ctx.schedule is not None:
        _check_schedule(ctx)
    if ctx.post is not None:
        _check_dangling_scratch(ctx)
