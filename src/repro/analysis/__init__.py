"""Static analysis over recorded graphs and planned op lists.

The pass pipeline, the dependency-cone extraction, and the
concurrent-drain conflict checks all rewrite or partition the recorded
graph on one invariant: every conflicting access pair of the original
program keeps its program order (§5.7).  This package *proves* that
invariant statically instead of trusting it:

* on demand — :func:`check` runs registered rules over whatever you
  hand it (pre/post plan op lists, concurrent cone footprints, a
  cross-rank message schedule) and returns an
  :class:`AnalysisReport` of :class:`Diagnostic` findings;
* automatically — ``ExecutionPolicy(verify="plan")`` verifies every
  flush's plan before it executes, ``verify="full"`` additionally runs
  the region-level race oracle over in-flight concurrent drains
  (:class:`~repro.core.engine.Runtime` raises
  :class:`VerificationError` on an error-severity finding and aborts
  the flush);
* from the command line — ``python -m repro.analysis`` runs the
  examples and the stencil benchmark under ``verify="full"`` and exits
  non-zero on any diagnostic (the CI ``graph-lint`` job).

New rules plug in through :func:`repro.register_rule`, mirroring the
pass/backend/channel registries.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.api.registry import (  # noqa: F401  (re-export)
    available_rules,
    get_rule,
    register_rule,
)

from .diagnostics import (  # noqa: F401
    ERROR,
    INFO,
    WARNING,
    AnalysisReport,
    Diagnostic,
    VerificationError,
    VerifyStats,
)
from .footprint import OpView, resolve_positions, snapshot_ops  # noqa: F401
from .rules import AnalysisContext, view_region_footprint  # noqa: F401

__all__ = [
    "check",
    "check_cached_plans",
    "AnalysisContext",
    "AnalysisReport",
    "Diagnostic",
    "VerificationError",
    "VerifyStats",
    "OpView",
    "snapshot_ops",
    "register_rule",
    "get_rule",
    "available_rules",
    "ERROR",
    "WARNING",
    "INFO",
]


def check(
    *,
    pre=None,
    post=None,
    dead_bases=(),
    provenance: Optional[dict] = None,
    dropped: Optional[dict] = None,
    scratch_available=(),
    cones=None,
    schedule=None,
    rules: Optional[Sequence[str]] = None,
) -> AnalysisReport:
    """Run static-analysis rules and return their findings.

    All inputs are optional; each rule silently skips what it cannot
    check from what was provided:

    ``pre`` / ``post``
        The operation list before and after planning (operation-nodes
        or ready-made :class:`OpView` snapshots, program order) — the
        ``"plan"`` rule's happens-before input, and the ``"deadlock"``
        rule's dangling-scratch input.
    ``dead_bases`` / ``provenance`` / ``dropped`` / ``scratch_available``
        Plan-stage context: GC'd base ids licensing dead-store
        elimination, the pass rewrite map (``new uid -> (pass_name,
        source uids)``) and drop map (``uid -> pass_name``) from
        :class:`~repro.core.plan.PlanResult`, and scratch ids already
        delivered by earlier drains.
    ``cones``
        Cones assumed concurrent — a list of op lists (or ``(label,
        ops)`` pairs) — for the ``"races"`` region-level oracle.
    ``schedule``
        Per-rank rendezvous programs (lists of ``{"kind":
        "send"|"recv"|"compute", "tag": ..., "peer": ...}`` dicts) for
        the ``"deadlock"`` rule's static fig. 6 cycle detection.
    ``rules``
        Names to run (default: every registered rule).

    Returns an :class:`AnalysisReport`; call
    :meth:`~AnalysisReport.raise_if_errors` to turn error findings into
    :class:`VerificationError`.
    """
    ctx = AnalysisContext(
        pre=snapshot_ops(list(pre)) if pre is not None else None,
        post=snapshot_ops(list(post)) if post is not None else None,
        dead_bases=set(dead_bases or ()),
        provenance=dict(provenance or {}),
        dropped=dict(dropped or {}),
        scratch_available=set(scratch_available or ()),
        cones=list(cones) if cones is not None else None,
        schedule=list(schedule) if schedule is not None else None,
    )
    names = tuple(rules) if rules is not None else tuple(available_rules())
    for name in names:
        get_rule(name)(ctx)
    ctx.report.rules_run = names
    return ctx.report


def check_cached_plans(cache, rules: Sequence[str] = ("plan", "deadlock")):
    """Re-verify every resident plan-shape-cache entry
    (:class:`repro.core.plan_cache.PlanCache`) — each entry retains the
    pre/post footprint snapshots, rewrite provenance, and drop records
    of its insert-time plan, so the static plan verifier can re-prove
    the cached recipe sound on demand (the ``graph-lint`` story for
    cached plans).  Returns one :class:`AnalysisReport` per entry, in
    cache order; callers decide whether errors raise
    (:meth:`AnalysisReport.raise_if_errors`)."""
    reports = []
    for entry in cache.entries():
        reports.append(check(
            pre=entry.pre_views,
            post=entry.post_views,
            dead_bases=entry.dead_bases,
            provenance=entry.provenance,
            dropped=entry.dropped,
            scratch_available=entry.scratch_available,
            rules=rules,
        ))
    return reports
