"""Region-precise access footprints for the static analyses.

The plan verifier compares *pre-plan* against *post-plan* footprints.
Pre-plan operation objects are NOT a stable snapshot: passes like the
fill→map constant folder mutate payload argument lists and rebuild
access lists in place, so the verifier snapshots every op into plain
immutable :class:`OpView` records **before** the pipeline runs.

A snapshot reconstructs the op's full §5.7 footprint, including the
*implicit* read of non-initializing combines/matmuls (their access
lists only carry the write, but the executor reads the block first —
the same reconstruction :func:`repro.core.plan.op_reads` does).
"""
from __future__ import annotations

from typing import Iterable, Optional

__all__ = ["OpView", "snapshot_ops", "resolve_positions"]


class OpView:
    """Immutable footprint snapshot of one operation-node.

    ``accesses`` is a tuple of ``(key, region, write)`` triples; regions
    are the recorded per-dimension ``(lo, hi)`` tuples (``None`` = whole
    block).  Implicit read-modify-write reads are materialized as
    explicit read triples.
    """

    __slots__ = ("uid", "kind", "label", "accesses")

    def __init__(self, uid, kind, label, accesses):
        self.uid = uid
        self.kind = kind
        self.label = label
        self.accesses = accesses

    def __repr__(self):
        return f"OpView(uid={self.uid}, label={self.label!r})"

    @property
    def writes(self) -> Iterable[tuple]:
        return ((k, r) for k, r, w in self.accesses if w)

    @property
    def reads(self) -> Iterable[tuple]:
        return ((k, r) for k, r, w in self.accesses if not w)


def snapshot_ops(ops) -> list[OpView]:
    """Snapshot operation-nodes (or pass through ready-made
    :class:`OpView` lists) into immutable footprint records."""
    if ops and isinstance(ops[0], OpView):
        return list(ops)
    from repro.core.engine import CombinePayload, MatmulPayload

    out = []
    for op in ops:
        acc = [(a.key, a.region, bool(a.write)) for a in op.accesses]
        p = op.payload
        if isinstance(p, (CombinePayload, MatmulPayload)) and not p.init:
            # non-initializing accumulation: the write target is also read
            acc.extend(
                (a.key, a.region, False) for a in op.accesses if a.write
            )
        out.append(OpView(op.uid, op.kind, op.label, tuple(acc)))
    return out


def resolve_positions(
    pre: list[OpView],
    post: list[OpView],
    provenance: Optional[dict] = None,
) -> dict:
    """Map every *pre*-plan uid to the index of the post-plan node that
    carries it: itself when it survived verbatim, the merged node when a
    pass recorded ``provenance[new_uid] = (pass_name, (src_uid, ...))``
    for it (chains of rewrites are followed), or absent when it was
    dropped entirely."""
    post_index = {op.uid: j for j, op in enumerate(post)}
    rewritten_into: dict = {}
    for new_uid, (_pass, srcs) in (provenance or {}).items():
        for src in srcs:
            rewritten_into[src] = new_uid
    positions: dict = {}
    for op in pre:
        v, hops = op.uid, 0
        while v not in post_index and v in rewritten_into and hops < len(pre) + 1:
            v = rewritten_into[v]
            hops += 1
        if v in post_index:
            positions[op.uid] = post_index[v]
    return positions
