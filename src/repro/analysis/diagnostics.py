"""Diagnostic objects shared by every analysis rule.

A :class:`Diagnostic` is one finding: which rule fired, how severe it
is, a human-readable message, and — when known — the operation uids,
the access key, and the plan pass whose rewrite is to blame (recovered
from the obs ``rewritten``/``dropped`` provenance events the passes
emit through :meth:`~repro.core.plan.PlanContext.note_rewrite`).

:class:`AnalysisReport` is the result of one :func:`repro.analysis.check`
run; :meth:`AnalysisReport.raise_if_errors` turns error-severity
findings into a :class:`VerificationError` — what
``ExecutionPolicy(verify=...)`` raises from inside ``Runtime.flush``
*before* an unsound plan reaches the executor.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

__all__ = [
    "Diagnostic",
    "AnalysisReport",
    "VerificationError",
    "VerifyStats",
    "ERROR",
    "WARNING",
    "INFO",
]

ERROR = "error"
WARNING = "warning"
INFO = "info"
_SEVERITIES = (ERROR, WARNING, INFO)


@dataclass(frozen=True)
class Diagnostic:
    """One static-analysis finding."""

    rule: str  # registered rule name ("plan", "races", "deadlock", ...)
    severity: str  # "error" | "warning" | "info"
    message: str
    ops: tuple = ()  # operation uids (or drain tags) involved
    key: Optional[tuple] = None  # the access key the finding anchors on
    pass_name: Optional[str] = None  # blamed plan pass, when known

    def __post_init__(self):
        if self.severity not in _SEVERITIES:
            raise ValueError(
                f"severity must be one of {_SEVERITIES}, got {self.severity!r}"
            )

    def __str__(self) -> str:
        where = ""
        if self.key is not None:
            where = f" [key={self.key!r}]"
        blame = f" (pass: {self.pass_name})" if self.pass_name else ""
        return f"{self.rule}/{self.severity}: {self.message}{where}{blame}"


@dataclass
class AnalysisReport:
    """All diagnostics from one :func:`repro.analysis.check` run, plus
    the precision counters the region race detector accumulates."""

    diagnostics: list[Diagnostic] = field(default_factory=list)
    # region-precision accounting (the carried-over sub-block cone
    # precision roadmap item feeds on this): how often the key-granular
    # cones_conflict over-approximated the region-precise answer
    n_key_conflicts: int = 0
    n_region_false_positives: int = 0
    rules_run: tuple = ()

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def ok(self) -> bool:
        return not self.errors

    def extend(self, other: "AnalysisReport") -> "AnalysisReport":
        self.diagnostics.extend(other.diagnostics)
        self.n_key_conflicts += other.n_key_conflicts
        self.n_region_false_positives += other.n_region_false_positives
        return self

    def raise_if_errors(self) -> None:
        if self.errors:
            raise VerificationError(self)

    def format(self) -> str:
        if not self.diagnostics:
            return "no diagnostics"
        return "\n".join(str(d) for d in self.diagnostics)

    def __str__(self) -> str:
        return self.format()


class VerificationError(RuntimeError):
    """An error-severity diagnostic was found — the plan (or the
    concurrent-drain schedule) is provably unsound; the flush that
    produced it is aborted before anything executes."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        errs = report.errors
        lines = "\n".join(f"  {d}" for d in errs)
        super().__init__(
            f"static verification failed with {len(errs)} error(s):\n{lines}"
        )


@dataclass
class VerifyStats:
    """Counters a verifying :class:`~repro.core.engine.Runtime`
    accumulates across flushes (``Runtime.verify_stats``)."""

    n_flushes_verified: int = 0
    n_race_checks: int = 0  # in-flight ticket pairs examined (verify=full)
    n_diagnostics: int = 0
    n_key_conflicts: int = 0
    n_region_false_positives: int = 0
    verify_seconds: float = 0.0  # wall time inside the verifier itself

    @property
    def precision(self) -> Optional[float]:
        """Fraction of key-level cone conflicts that were real at
        region granularity (``None`` until a conflict was observed)."""
        if self.n_key_conflicts == 0:
            return None
        return 1.0 - self.n_region_false_positives / self.n_key_conflicts
