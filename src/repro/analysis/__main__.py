"""``python -m repro.analysis`` — the graph-lint entry point.

Runs real programs under ``verify="full"`` and gates on zero
diagnostics:

* the examples (``examples/quickstart.py``,
  ``examples/stencil_latency_hiding.py``) as subprocesses with
  ``REPRO_VERIFY=full`` exported — every flush they perform is
  plan-verified and race-checked inside the child, and a
  :class:`~repro.analysis.VerificationError` fails the child;
* the Jacobi stencil benchmark app in-process (a CI-sized problem), so
  the verifier's precision statistic (key-level cone conflicts that
  were region-level false positives) can be read off
  ``Runtime.verify_stats`` and reported.

Writes ``results/BENCH_graph_lint.json`` (consumed by
``benchmarks/make_report.py``) and exits non-zero when any program
failed verification or produced a diagnostic.

    PYTHONPATH=src python -m repro.analysis
    PYTHONPATH=src python -m repro.analysis --skip-examples   # bench only
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, os.pardir, os.pardir)
)

EXAMPLES = ("examples/quickstart.py", "examples/stencil_latency_hiding.py")


def lint_example(path: str, timeout: float = 900.0) -> dict:
    """Run one example with full verification enabled in its
    environment; a verification failure (or any crash) fails the
    child."""
    env = dict(os.environ)
    env["REPRO_VERIFY"] = "full"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p
    )
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, path],
        cwd=REPO,
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    ok = proc.returncode == 0
    out = {
        "program": path,
        "ok": ok,
        "seconds": round(time.perf_counter() - t0, 3),
    }
    if not ok:
        tail = (proc.stderr or proc.stdout or "").strip().splitlines()[-15:]
        out["failure"] = "\n".join(tail)
    return out


def lint_stencil(n: int = 512, iters: int = 3, nprocs: int = 4) -> dict:
    """Run the stencil benchmark app in-process under verify="full" and
    return the verifier's counters (including the precision stat)."""
    sys.path.insert(0, REPO)
    import numpy as np

    from benchmarks.paper_apps import APPS
    from repro.api.config import ExecutionPolicy, RuntimeConfig
    from repro.core.engine import Runtime

    fn, defaults, _bs = APPS["jacobi_stencil"]
    config = RuntimeConfig(nprocs=nprocs, block_size=64)
    policy = ExecutionPolicy(
        flush="async", channel="async", verify="full", sync="demand"
    )
    t0 = time.perf_counter()
    with Runtime.from_config(config, policy) as rt:
        out = fn(**{**defaults, "n": n, "iters": iters})
        np.asarray(out)
        vs = rt.verify_stats
        report = rt.last_verify_report
    result = {
        "program": f"benchmarks.paper_apps:jacobi_stencil(n={n}, iters={iters})",
        "ok": vs.n_diagnostics == 0,
        "seconds": round(time.perf_counter() - t0, 3),
        "n_flushes_verified": vs.n_flushes_verified,
        "n_race_checks": vs.n_race_checks,
        "n_diagnostics": vs.n_diagnostics,
        "n_key_conflicts": vs.n_key_conflicts,
        "n_region_false_positives": vs.n_region_false_positives,
        "precision": vs.precision,
    }
    if report is not None and report.diagnostics:
        result["diagnostics"] = [str(d) for d in report.diagnostics]
    return result


def lint_overlap_probe(nprocs: int = 4) -> dict:
    """Concurrent-drain probe for the race oracle: two pairs of
    overlapping drains against one shared block.  The first pair
    conflicts only at key granularity (disjoint sub-block regions — the
    expected over-approximation), the second really overlaps, so the
    precision statistic gets a real denominator (expected 50%).

    Best-effort on counters: on a loaded box the producer drain can
    finish before the second flush checks it, so only the zero-
    diagnostics gate is asserted — the counts are reported as-is."""
    import numpy as np

    import repro

    t0 = time.perf_counter()
    with repro.runtime(nprocs=nprocs, block_size=64, flush="async",
                       channel="async", sync="demand", verify="full",
                       latency=2e-3) as rt:
        shared = repro.zeros((64,))
        a = repro.ones((256,))  # 4 blocks: rolls force halo messages
        b = repro.ones((16,))
        rt.flush()  # drain creations: the probed cones are the chains

        def slow_write(lo, hi):
            # a cross-block roll chain keeps the drain in flight long
            # enough (simulated latency per halo message) for the next
            # flush's race check to see it
            c = a
            for _ in range(30):
                c = np.roll(c, 1, axis=0) * 1.001
            shared[lo:hi] = c[lo:hi]
            return rt.flush(wait=False, targets=[shared])

        # pair 1: in-flight write of [0:16) vs read of [32:48) — same
        # block key, disjoint regions: the false positive
        t1 = slow_write(0, 16)
        y = b * 2.0 + shared[32:48]
        rt.flush(wait=False, targets=[y]).wait()
        t1.wait()
        # pair 2: in-flight write of [0:16) vs read of [8:24) — a real
        # region-level overlap
        t2 = slow_write(0, 16)
        z = b * 3.0 + shared[8:24]
        rt.flush(wait=False, targets=[z]).wait()
        t2.wait()
        np.asarray(y)
        np.asarray(z)
        vs = rt.verify_stats
        report = rt.last_verify_report
    result = {
        "program": "repro.analysis:overlap_probe",
        "ok": vs.n_diagnostics == 0,
        "seconds": round(time.perf_counter() - t0, 3),
        "n_flushes_verified": vs.n_flushes_verified,
        "n_race_checks": vs.n_race_checks,
        "n_diagnostics": vs.n_diagnostics,
        "n_key_conflicts": vs.n_key_conflicts,
        "n_region_false_positives": vs.n_region_false_positives,
        "precision": vs.precision,
    }
    if report is not None and report.diagnostics:
        result["diagnostics"] = [str(d) for d in report.diagnostics]
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="graph-lint: run programs under verify='full' and "
        "gate on zero diagnostics",
    )
    ap.add_argument("--skip-examples", action="store_true",
                    help="lint only the in-process stencil benchmark")
    ap.add_argument("--n", type=int, default=512,
                    help="stencil problem size (default 512)")
    ap.add_argument("--iters", type=int, default=3,
                    help="stencil sweeps (default 3)")
    ap.add_argument("--out", default=os.path.join(REPO, "results",
                                                  "BENCH_graph_lint.json"),
                    help="result JSON path ('' disables the write)")
    args = ap.parse_args(argv)

    results = []
    if not args.skip_examples:
        for ex in EXAMPLES:
            print(f"graph-lint: {ex} (REPRO_VERIFY=full) ...", flush=True)
            r = lint_example(os.path.join(REPO, ex))
            results.append(r)
            print(f"  {'ok' if r['ok'] else 'FAILED'} "
                  f"({r['seconds']:.1f}s)")
            if not r["ok"]:
                print(r.get("failure", ""))
    print("graph-lint: jacobi_stencil benchmark (in-process) ...", flush=True)
    results.append(lint_stencil(n=args.n, iters=args.iters))
    print("graph-lint: concurrent-drain overlap probe ...", flush=True)
    results.append(lint_overlap_probe())
    for r in results[-2:]:
        print(f"  {r['program']}: {'ok' if r['ok'] else 'FAILED'} "
              f"({r['seconds']:.1f}s) — "
              f"{r['n_flushes_verified']} flushes verified, "
              f"{r['n_race_checks']} race checks, "
              f"{r['n_diagnostics']} diagnostics")
        if r["precision"] is not None:
            print(f"  cone-conflict precision: {r['precision'] * 100:.1f}% "
                  f"({r['n_region_false_positives']} of "
                  f"{r['n_key_conflicts']} key-level conflicts were "
                  f"region-level false positives)")
        for d in r.get("diagnostics", ()):
            print(f"  {d}")

    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"section": "graph-lint", "results": results}, f,
                      indent=2)
        print(f"wrote {args.out}")

    failed = [r["program"] for r in results if not r["ok"]]
    if failed:
        print(f"graph-lint FAILED for: {', '.join(failed)}")
        return 1
    print("graph-lint: all programs verified clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
