"""DeepSeek-V2-Lite (16B total / 2.4B active) — MLA + fine-grained MoE
[arXiv:2405.04434; hf].

27L, d_model=2048, 16 heads, MLA kv_lora_rank=512 (qk_nope 128, qk_rope 64,
v_head 128), vocab=102400.  Layer 0 dense (d_ff=10944), layers 1-26 MoE:
64 routed experts top-6 + 2 shared experts, expert d_ff=1408.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,           # layer-0 dense FFN
    vocab_size=102400,
    layer_pattern="D" + "E" * 26,
    attn_impl="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
)
