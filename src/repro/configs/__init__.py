"""Architecture config registry.

``get_config(arch_id)`` returns the full published config;
``get_reduced(arch_id)`` a CPU-smoke-testable shrink of the same family.
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeSpec, reduced

ARCHS = [
    "rwkv6_3b",
    "whisper_small",
    "yi_34b",
    "mistral_large_123b",
    "h2o_danube_3_4b",
    "granite_3_8b",
    "internvl2_2b",
    "grok_1_314b",
    "deepseek_v2_lite_16b",
    "zamba2_2p7b",
]

# public ids (dashes) → module names (underscores)
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({a: a for a in ARCHS})
# the assignment's canonical ids
_ALIASES.update(
    {
        "rwkv6-3b": "rwkv6_3b",
        "whisper-small": "whisper_small",
        "yi-34b": "yi_34b",
        "mistral-large-123b": "mistral_large_123b",
        "h2o-danube-3-4b": "h2o_danube_3_4b",
        "granite-3-8b": "granite_3_8b",
        "internvl2-2b": "internvl2_2b",
        "grok-1-314b": "grok_1_314b",
        "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
        "zamba2-2.7b": "zamba2_2p7b",
    }
)


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f".{_ALIASES[arch_id]}", __package__)
    return mod.CONFIG


def get_reduced(arch_id: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch_id), **overrides)


def all_arch_ids() -> list[str]:
    return [a.replace("_", "-").replace("2p7b", "2.7b") for a in ARCHS]


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "get_reduced",
    "all_arch_ids",
    "reduced",
]
