"""Whisper-small — encoder-decoder ASR backbone [arXiv:2212.04356; unverified].

12 enc + 12 dec layers, d_model=768, 12 heads (MHA: kv=12), d_ff=3072,
vocab=51865.  The conv/mel frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings [B, 1500, 768].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,          # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    layer_pattern="A",
    act="gelu",
    enc_dec=True,
    n_enc_layers=12,
    enc_seq=1500,
    tie_embeddings=True,
)
