"""Model/config schema shared by all assigned architectures.

One :class:`ModelConfig` describes every architecture family in the pool:
dense GQA transformers, MoE (top-k routed + shared experts), MLA
(DeepSeek latent attention), Mamba2 SSM, RWKV6, hybrid (Mamba2 + shared
attention), encoder–decoder (whisper) and VLM/audio frontend stubs.

``layer_pattern`` selects the block type per layer:
  ``A`` attention+MLP, ``M`` mamba2, ``R`` rwkv6, ``E`` attention+MoE,
  ``D`` attention+dense-MLP (used for MoE archs' leading dense layers),
  ``H`` mamba2 with a *shared* attention block applied before it (zamba2).
A single letter means "all layers"; otherwise it must have one letter per
layer.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "reduced"]


@dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str = "custom"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm

    # trunk
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0  # 0 = d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    layer_pattern: str = "A"
    act: str = "silu"  # mlp activation: silu (swiglu) | gelu (whisper)
    tie_embeddings: bool = False

    # attention
    attn_impl: str = "gqa"  # gqa | mla
    rope_theta: float = 1e4
    swa_window: Optional[int] = None  # sliding-window size (h2o-danube)
    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (d_ff used for dense layers)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2

    # SSM (mamba2)
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # RWKV6
    rwkv_head_size: int = 64

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500  # precomputed frame-embedding positions (stub)

    # VLM stub
    n_img_tokens: int = 0  # patch-embedding positions prepended (stub)

    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for the >100B archs
    remat: bool = True
    scan_layers: bool = True
    use_flash: bool = False  # route attention through the Pallas kernel
    attn_chunk: int = 1024  # KV-chunk for the online-softmax jnp path
    overlap: str = "ring"  # paper technique: "ring" (LH) | "none" (blocking)
    microbatches: int = 1  # gradient-accumulation steps per train step
    moe_group_size: int = 4096  # token-group chunking of the MoE dispatch
    # cost-pass mode: unroll every scan/map so the compiled artifact's
    # cost_analysis counts true FLOPs (XLA counts while bodies ONCE)
    unroll_scans: bool = False
    # ---- beyond-paper schedule optimizations (§Perf hillclimb) ----
    # vocab-parallel-safe cross-entropy: one-hot·sum + explicit logsumexp
    # instead of take_along_axis (which forces a full logits all-reduce
    # when the vocab dim is model-sharded)
    vocab_parallel_loss: bool = False
    # explicit activation sharding constraints (Megatron-style): pin the
    # residual stream to batch-over-dp and hidden/head dims to model,
    # stopping GSPMD from flip-flopping layouts (AG/AR storms)
    act_sharding: bool = False

    # ----------------------------------------------------------------- utils
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> str:
        p = self.layer_pattern
        return p * self.n_layers if len(p) == 1 else p

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def jparam_dtype(self):
        return jnp.dtype(self.param_dtype)

    def __post_init__(self):
        if len(self.pattern) != self.n_layers:
            raise ValueError(
                f"layer_pattern length {len(self.pattern)} != n_layers {self.n_layers}"
            )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (for 6ND MODEL_FLOPS)
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        H, KV = self.n_heads, self.n_kv_heads
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D
        per = {}
        per["A"] = D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D + 3 * D * F + 2 * D
        per["D"] = per["A"]
        if self.attn_impl == "mla":
            qk = self.qk_nope_head_dim + self.qk_rope_head_dim
            attn = (
                D * H * qk
                + D * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * H * (self.qk_nope_head_dim + self.v_head_dim)
                + H * self.v_head_dim * D
            )
            per["A"] = attn + 3 * D * F + 2 * D
            per["D"] = per["A"]
        mf = self.moe_d_ff or F
        e_all = self.n_experts * 3 * D * mf + self.n_shared_experts * 3 * D * mf
        e_act = (self.top_k + self.n_shared_experts) * 3 * D * mf
        attn_part = per["A"] - 3 * D * F - 2 * D
        per["E"] = attn_part + (e_act if active_only else e_all) + D * self.n_experts + 2 * D
        d_in = self.ssm_expand * D
        nh = d_in // self.ssm_head_dim
        per["M"] = (
            D * (2 * d_in + 2 * self.ssm_state + nh)
            + self.ssm_conv * (d_in + 2 * self.ssm_state)
            + d_in * D
            + 2 * nh
            + D
        )
        per["H"] = per["M"]  # + shared attention counted once below
        hs = self.rwkv_head_size
        per["R"] = 4 * D * D + D * D + 3 * D * F // 2 + 6 * D * 32 + 2 * D  # approx
        for ch in set(self.pattern):
            n += self.pattern.count(ch) * per[ch]
        if "H" in self.pattern:
            n += per["A"] - 3 * D * F  # one shared attention block
        if self.enc_dec:
            # encoder layers + decoder cross-attention
            n += self.n_enc_layers * per["A"]
            n += self.pattern.count("A") * (2 * D * (KV * hd) + D * (H * hd) + (H * hd) * D)
        return int(n)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a full config to a CPU-smoke-testable size of the same family
    (same layer pattern shape, tiny dims)."""
    pat = cfg.pattern
    small_layers = min(cfg.n_layers, 4 if "H" not in pat else 12)
    if "H" in pat:
        # keep the hybrid periodicity: groups of (pattern period)
        period = pat.index("H", 1) if pat.count("H") > 1 else 6
        small_layers = 2 * period
        small_pat = pat[: small_layers]
    elif len(set(pat)) == 1:
        small_pat = pat[0]
    else:
        small_pat = pat[:1] + pat[-1] * (small_layers - 1)
    kw = dict(
        n_layers=small_layers,
        layer_pattern=small_pat,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=32 if cfg.attn_impl == "mla" else cfg.qk_nope_head_dim,
        qk_rope_head_dim=16 if cfg.attn_impl == "mla" else cfg.qk_rope_head_dim,
        v_head_dim=32 if cfg.attn_impl == "mla" else cfg.v_head_dim,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_chunk=32,
        rwkv_head_size=32,
        n_enc_layers=2 if cfg.enc_dec else 0,
        enc_seq=16 if cfg.enc_dec else cfg.enc_seq,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        swa_window=min(cfg.swa_window, 16) if cfg.swa_window else None,
        dtype="float32",
        param_dtype="float32",
        remat=False,
        scan_layers=False,
        microbatches=1,
        attn_chunk=64,
    )
    kw.update(overrides)
    return cfg.replace(**kw)
