"""Mistral-Large-Instruct-2407 (123B) — dense GQA
[hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L, d_model=12288, 96 heads / 8 KV heads (head_dim 128), d_ff=28672,
vocab=32768.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    layer_pattern="A",
    rope_theta=1e6,
    microbatches=8,
    opt_state_dtype="bfloat16",  # >100B: bf16 optimizer moments
)
