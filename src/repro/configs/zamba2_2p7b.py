"""Zamba2-2.7B — Mamba2 backbone with a single SHARED attention(+MLP)
block applied every 6th layer [arXiv:2411.15242; hf].

54L, d_model=2560, shared attn 32 heads (MHA), d_ff=10240 (shared block
MLP), vocab=32000, ssm_state=64.  Pattern (MMMMMH)×9: the 'H' layers run
the one shared attention block, then their own Mamba2 mixer.
(The published model concatenates the original embedding into the shared
block input and uses per-layer LoRA deltas on it; we use the standard
residual form — noted in DESIGN.md §Arch-applicability.)
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern="MMMMMH" * 9,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)
