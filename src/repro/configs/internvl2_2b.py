"""InternVL2-2B — InternViT frontend (STUB) + InternLM2-1.8B LM backbone
[arXiv:2404.16821; hf].

24L, d_model=2048, 16 heads / 8 KV heads (head_dim 128), d_ff=8192,
vocab=92553.  ``input_specs()`` supplies 256 precomputed patch embeddings
per image (the ViT+pixel-shuffle frontend is a stub per the assignment).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    layer_pattern="A",
    rope_theta=1e6,
    n_img_tokens=256,
    tie_embeddings=True,
)
