"""RWKV6 "Finch" 3B — attention-free linear recurrence [arXiv:2404.05892; hf].

32L, d_model=2560, d_ff=8960, vocab=65536, head size 64 (40 wkv heads).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # wkv heads = d_model / rwkv_head_size
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern="R",
    rwkv_head_size=64,
    ssm_chunk=256,       # wkv chunk length
    tie_embeddings=False,
)
