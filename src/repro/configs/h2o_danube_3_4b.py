"""H2O-Danube3-4B — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

24L, d_model=3840, 32 heads / 8 KV heads (head_dim 120), d_ff=10240,
vocab=32000, SWA window 4096 (danube-series default; unverified).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern="A",
    swa_window=4096,
    rope_theta=1e4,
)
