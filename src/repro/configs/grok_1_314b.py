"""Grok-1 (314B) — MoE, 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L, d_model=6144, 48 heads / 8 KV heads (head_dim 128), expert d_ff=32768,
vocab=131072.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    layer_pattern="E",
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    microbatches=8,
    opt_state_dtype="bfloat16",  # >100B: bf16 optimizer moments
)
