"""Pure-jnp oracle for the RWKV6 wkv kernel: exact per-token recurrence.

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, *, init_state=None):
    """r,k,v,w: [B,T,H,N] (w = decay ∈ (0,1)); u: [H,N].
    Returns (y [B,T,H,N], final_state [B,H,N,N])."""
    B, T, H, N = r.shape
    state = (
        jnp.zeros((B, H, N, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # each [B,H,N]
        kv = jnp.einsum("bhn,bhm->bhnm", k_t, v_t)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, state + u[None, :, :, None] * kv)
        state = state * w_t[..., None] + kv
        return state, y

    xs = tuple(
        a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w)
    )
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state
