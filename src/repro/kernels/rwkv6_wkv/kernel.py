"""RWKV6 wkv recurrence as a chunked Pallas TPU kernel.

Grid = (B, H, T/chunk), chunk axis sequential; the [N, N] state is VMEM
scratch.  The per-CHANNEL data-dependent decay (RWKV6's defining
feature) means the intra-chunk weights don't factor out of the r·k dot
— the kernel materializes the per-channel decay ratio tensor
``exp(cumprev_t − cum_j)`` for the chunk ([c, c, N], VMEM-resident) and
contracts it with r and k in one einsum.  On a GPU this is the part the
official CUDA kernel does with per-thread registers over the N lanes;
on TPU the [c,c,N] tile in VMEM plus VPU elementwise + MXU contraction
is the natural equivalent (c=64 ⇒ 1 MB f32 tile for N=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _wkv_kernel(
    r_ref, k_ref, v_ref, w_ref,  # [1, c, 1, N]
    u_ref,  # [1, N]
    s0_ref,  # [1, 1, N, N]
    y_ref,  # [1, c, 1, N]
    sout_ref,  # [1, 1, N, N]
    state_ref,  # scratch [N, N] f32  (S[i, j]: key-dim i, value-dim j)
    *,
    chunk: int,
):
    z = pl.program_id(2)
    nz = pl.num_programs(2)

    @pl.when(z == 0)
    def init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    f32 = jnp.float32
    r = r_ref[0, :, 0, :].astype(f32)  # [c, N]
    k = k_ref[0, :, 0, :].astype(f32)
    v = v_ref[0, :, 0, :].astype(f32)
    w = w_ref[0, :, 0, :].astype(f32)
    u = u_ref[0].astype(f32)  # [N]

    logw = jnp.log(jnp.maximum(w, 1e-12))  # [c, N], <= 0
    cum = jnp.cumsum(logw, axis=0)
    cumprev = cum - logw  # exclusive prefix (y_t sees S_{t-1})

    # intra-chunk, strict j < t, per-channel decay Π_{j<τ<t} w_τ[i]
    dec = jnp.exp(
        jnp.clip(cumprev[:, None, :] - cum[None, :, :], -60.0, 0.0)
    )  # [c(t), c(j), N]
    att = jnp.einsum("ti,tji,ji->tj", r, dec, k)  # [c, c]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ji = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(ti > ji, att, 0.0)
    y = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=f32
    )  # [c, N]

    # diagonal (j == t) with bonus u
    y = y + jnp.sum(r * u[None, :] * k, axis=1)[:, None] * v

    # inter-chunk: state entering step t has decayed by w_{1..t-1}
    st = state_ref[...]
    r_dec = r * jnp.exp(jnp.clip(cumprev, -60.0, 0.0))
    y = y + jax.lax.dot_general(
        r_dec, st, (((1,), (0,)), ((), ())), preferred_element_type=f32
    )
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: S' = diag(Π w) S + Σ_j (k_j ⊙ Π_{j<τ<=C} w_τ) v_jᵀ
    k_dec = k * jnp.exp(jnp.clip(cum[-1:, :] - cum, -60.0, 0.0))
    s_local = jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )  # [N, N]
    state_ref[...] = st * jnp.exp(jnp.clip(cum[-1], -60.0, 0.0))[:, None] + s_local

    @pl.when(z == nz - 1)
    def fin():
        sout_ref[0, 0] = state_ref[...].astype(sout_ref.dtype)


def wkv6_kernel(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w: [B,T,H,N] (T a chunk multiple — ops.py pads); u: [H,N];
    s0: [B,H,N,N].  Returns (y, final_state)."""
    B, T, H, N = r.shape
    grid = (B, H, T // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, z: (b, z, h, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, N), lambda b, h, z: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, z: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, N, N), lambda b, h, z: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(r, k, v, w, u, s0)
