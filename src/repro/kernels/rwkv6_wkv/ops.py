"""jit'd public wrapper for the RWKV6 wkv kernel: padding + init state."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import wkv6_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jax.Array,  # [B, T, H, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay ∈ (0, 1)
    u: jax.Array,  # [H, N]
    init_state: Optional[jax.Array] = None,  # [B, H, N, N]
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z4), jnp.pad(k, z4), jnp.pad(v, z4)
        w = jnp.pad(w, z4, constant_values=1.0)  # decay 1 = no-op padding
    s0 = (
        jnp.zeros((B, H, N, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    y, fin = wkv6_kernel(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)
    if pad:
        y = y[:, :T]
    return y, fin
