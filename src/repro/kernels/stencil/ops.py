"""jit'd public wrappers for the fused Jacobi-sweep kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import jacobi_sweep_kernel, stencil5_block_kernel


@functools.partial(jax.jit, static_argnames=("band", "interpret"))
def jacobi_sweep(x: jax.Array, *, band: int = 128, interpret: bool = True):
    """One fused 5-point Jacobi sweep on [H, W] (Dirichlet boundary)."""
    H, W = x.shape
    band = min(band, H)
    pad = (-H) % band
    if pad:
        # edge-replicate padding: padded rows never influence real rows
        # (they sit "below" the fixed bottom boundary row)
        x = jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)], axis=0)
    # the kernel pins global row H_padded-1; real boundary row is H-1 —
    # handled because padded rows replicate the real last row, and we
    # restore the original rows on return.
    out = jacobi_sweep_kernel(x, band=band, interpret=interpret)
    out = out[:H]
    if pad:
        # re-pin the true last row (it was treated as interior above)
        out = out.at[H - 1].set(x[H - 1])
    return out


@functools.partial(jax.jit, static_argnames=("weight", "interpret"))
def stencil5_block(x0, x1, x2, x3, x4, *, weight: float, interpret: bool = True):
    """Fused per-block 5-point combine ``weight * (x0+..+x4)`` (the
    repro.exec JaxBackend's fast path for fused stencil map payloads)."""
    return stencil5_block_kernel(
        x0, x1, x2, x3, x4, weight=weight, interpret=interpret
    )
