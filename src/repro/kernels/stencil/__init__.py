from .ops import jacobi_sweep, stencil5_block
from .ref import jacobi_sweep_ref

__all__ = ["jacobi_sweep", "stencil5_block", "jacobi_sweep_ref"]
