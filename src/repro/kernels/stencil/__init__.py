from .ops import jacobi_sweep
from .ref import jacobi_sweep_ref

__all__ = ["jacobi_sweep", "jacobi_sweep_ref"]
