"""Pure-jnp oracle for the fused Jacobi stencil: the paper's fig. 10
kernel (five shifted views + ufunc chain), Dirichlet boundary."""
from __future__ import annotations

import jax.numpy as jnp


def jacobi_sweep_ref(x):
    """One 5-point Jacobi sweep on [H, W]; boundary rows/cols fixed."""
    interior = 0.2 * (
        x[1:-1, 1:-1]
        + x[0:-2, 1:-1]
        + x[2:, 1:-1]
        + x[1:-1, 0:-2]
        + x[1:-1, 2:]
    )
    return x.at[1:-1, 1:-1].set(interior.astype(x.dtype))
