"""Fused 5-point Jacobi sweep as a Pallas TPU kernel — the paper's
flagship application class (fig. 10 / §6 Jacobi Stencil), TPU-adapted.

The paper's NumPy expression evaluates five shifted array views through
five separate ufunc passes (5 reads + several temp writes of the whole
grid per sweep).  The paper's §7 "future work" proposes merging chained
ufuncs into one joint operation; this kernel IS that merge on TPU: one
HBM read + one HBM write per sweep, with the halo rows reused out of
VMEM.  Arithmetic intensity rises from ~0.15 flop/B to ~0.5 flop/B —
the same locality win the DistNumPy fusion mode gets, moved from the
interpreter to the memory hierarchy.

Tiling: grid over row bands; each grid step sees three input blocks
(previous / current / next band — the ±1 index maps express the halo)
and writes one band.  Pallas double-buffers the band fetches across
sequential grid steps, which is exactly the paper's double-buffering
(§5.4) applied to the HBM→VMEM pipe instead of the network.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _jacobi_kernel(prev_ref, cur_ref, nxt_ref, o_ref, *, band: int, n_rows: int):
    i = pl.program_id(0)
    cur = cur_ref[...].astype(jnp.float32)  # [band, W]
    up_row = prev_ref[band - 1 : band, :].astype(jnp.float32)  # last row of band i-1
    dn_row = nxt_ref[0:1, :].astype(jnp.float32)  # first row of band i+1
    W = cur.shape[1]

    up = jnp.concatenate([up_row, cur[:-1]], axis=0)
    down = jnp.concatenate([cur[1:], dn_row], axis=0)
    left = jnp.concatenate([cur[:, :1], cur[:, :-1]], axis=1)
    right = jnp.concatenate([cur[:, 1:], cur[:, -1:]], axis=1)
    new = 0.2 * (cur + up + down + left + right)

    # Dirichlet boundary: first/last global row and first/last column
    grow = i * band + jax.lax.broadcasted_iota(jnp.int32, (band, W), 0)
    gcol = jax.lax.broadcasted_iota(jnp.int32, (band, W), 1)
    edge = (grow == 0) | (grow == n_rows - 1) | (gcol == 0) | (gcol == W - 1)
    o_ref[...] = jnp.where(edge, cur, new).astype(o_ref.dtype)


def jacobi_sweep_kernel(x: jax.Array, *, band: int = 128, interpret: bool = False):
    """x: [H, W], H a band multiple (ops.py pads).  One fused sweep."""
    H, W = x.shape
    nb = H // band
    kernel = functools.partial(_jacobi_kernel, band=band, n_rows=H)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            # previous band (clamped at the top edge: i=0 reads band 0,
            # whose "last row" feeds global row -1 — masked as boundary)
            pl.BlockSpec((band, W), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((band, W), lambda i: (i, 0)),
            pl.BlockSpec((band, W), lambda i: (jnp.minimum(i + 1, nb - 1), 0)),
        ],
        out_specs=pl.BlockSpec((band, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), x.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
    )(x, x, x)


# ---------------------------------------------------------------------------
# Block-level fused stencil combine (used by repro.exec's JaxBackend)
# ---------------------------------------------------------------------------


def _stencil5_kernel(x0_ref, x1_ref, x2_ref, x3_ref, x4_ref, o_ref, *, weight):
    acc = x0_ref[...].astype(jnp.float32) + x1_ref[...].astype(jnp.float32)
    acc = acc + x2_ref[...].astype(jnp.float32)
    acc = acc + x3_ref[...].astype(jnp.float32)
    acc = acc + x4_ref[...].astype(jnp.float32)
    o_ref[...] = (weight * acc).astype(o_ref.dtype)


def stencil5_block_kernel(x0, x1, x2, x3, x4, *, weight: float,
                          interpret: bool = False):
    """Fused ``weight * (x0+x1+x2+x3+x4)`` over five same-shape 2-D blocks.

    This is the per-sub-view-block form of the Jacobi sweep: the runtime's
    fragment iteration already materialized the five shifted views as
    separate operands (with halos delivered into scratch buffers by the
    transfer channel), so the remaining compute is a pure 5-way
    elementwise combine — one VMEM pass instead of four ufunc round
    trips.  Addition order matches the interpreter's left-nested chain.
    """
    return pl.pallas_call(
        functools.partial(_stencil5_kernel, weight=weight),
        out_shape=jax.ShapeDtypeStruct(x0.shape, x0.dtype),
        interpret=interpret,
    )(x0, x1, x2, x3, x4)
