"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full S×S score matrix — O(S²) memory, fine at test
sizes, bit-accurate softmax in f32.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q,  # [B, Sq, H, d]
    k,  # [B, Sk, KV, d]
    v,  # [B, Sk, KV, d]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
):
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = d ** -0.5 if scale is None else scale
    qf = q.reshape(B, Sq, KV, G, d).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)  # [B, KV, G, Sq, Sk]
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok = ok & (q_pos >= k_pos)
    if window is not None:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, vf)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, d).astype(q.dtype)
