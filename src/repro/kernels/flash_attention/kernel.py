"""Flash attention (causal + GQA + sliding window) as a Pallas TPU kernel.

Tiling: grid = (B, H, Sq/BQ, Sk/BK) with the KV axis innermost and
``dimension_semantics`` marking it "arbitrary" (sequential) — the online
softmax accumulators live in VMEM scratch across the KV sweep.  Block
shapes are MXU-aligned (multiples of 128 on the sequence dims; head_dim
padded to 128 by the wrapper).  Fully-masked causal/window tiles are
skipped via ``pl.when`` on the block indices — the flash-2 schedule
adapted to the TPU grid model: VMEM scratch + a sequential grid axis
replace the CUDA shared-memory/warp accumulator pattern.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # [1, 1, BQ, d]
    k_ref,  # [1, 1, BK, d]
    v_ref,  # [1, 1, BK, d]
    o_ref,  # [1, 1, BQ, d]
    m_ref,  # scratch [BQ, 128]  (running max, lane-replicated)
    l_ref,  # scratch [BQ, 128]  (running denom)
    acc_ref,  # scratch [BQ, d]
    *,
    causal: bool,
    window: Optional[int],
    scale: float,
    block_q: int,
    block_k: int,
    sk_valid: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip tiles the causal/window mask kills entirely
    first_q = qi * block_q
    last_q = first_q + block_q - 1
    first_k = ki * block_k
    last_k = first_k + block_k - 1
    run = first_k < sk_valid
    if causal:
        run = run & (first_k <= last_q)
    if window is not None:
        run = run & (last_k >= first_q - window + 1)

    @pl.when(run)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [BQ, BK]
        q_pos = first_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = first_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = k_pos < sk_valid
        if causal:
            ok = ok & (q_pos >= k_pos)
        if window is not None:
            ok = ok & (q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr[:, None] + jnp.broadcast_to(
            p.sum(axis=1)[:, None], l_ref.shape
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # [B, H, Sq, d]  (d padded to a 128-multiple by ops.py)
    k: jax.Array,  # [B, H, Sk, d]  (KV heads pre-broadcast to H)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    sk_valid: Optional[int] = None,
    interpret: bool = False,
):
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "wrapper pads to block multiples"
    grid = (B, H, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        sk_valid=Sk if sk_valid is None else sk_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
    )(q, k, v)
