"""jit'd public wrapper for the flash-attention kernel.

Handles layout ([B,S,H,d] ↔ [B,H,S,d]), GQA head broadcast, head-dim
padding to the 128-lane MXU width, and sequence padding to block
multiples.  ``interpret=True`` (the CPU default here) runs the kernel
body in Python for validation; on a real TPU pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, H, d]
    k: jax.Array,  # [B, Sk, KV, d]
    v: jax.Array,  # [B, Sk, KV, d]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = d ** -0.5 if scale is None else scale

    # GQA: broadcast KV heads to H (the kernel is per-head)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    # pad head dim to a 128 multiple (MXU lanes); zero-pad K ⇒ scores exact
    d_pad = (-d) % 128
    if d_pad:
        pad4 = ((0, 0), (0, 0), (0, 0), (0, d_pad))
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)

    # pad sequences to block multiples; padded K positions are masked via
    # sk_valid, padded Q rows are dropped on return
    bq = min(block_q, max(8, 1 << (Sq - 1).bit_length())) if Sq < block_q else block_q
    bk = min(block_k, max(8, 1 << (Sk - 1).bit_length())) if Sk < block_k else block_k
    sq_pad = (-Sq) % bq
    sk_pad = (-Sk) % bk
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(
        qt, kt, vt,
        causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, sk_valid=Sk, interpret=interpret,
    )
    out = out.transpose(0, 2, 1, 3)[:, :Sq, :, :d]
    return out
