"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Each kernel ships three files per the repo convention:
``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling), ``ops.py``
(jit'd public wrapper: padding/layout/GQA broadcast) and ``ref.py``
(pure-jnp oracle the tests sweep against, interpret=True on CPU).

* ``stencil``         — fused 5-point Jacobi sweep: the paper's flagship
                         app (§6) with its §7 ufunc-merging implemented
                         at the VMEM level (1 read + 1 write per sweep).
* ``flash_attention`` — causal/GQA/SWA online-softmax attention.
* ``mamba2_scan``     — chunked SSD scan (zamba2's mixer).
* ``rwkv6_wkv``       — chunked data-dependent-decay wkv recurrence.
"""


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params across the pallas API rename:
    ``pltpu.TPUCompilerParams`` (jax ≤ 0.4.x) became
    ``pltpu.CompilerParams`` (jax ≥ 0.5)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
