"""Pure-jnp oracle for the SSD scan kernel: the naive per-token
recurrence (exact, O(S) sequential)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, B, C, *, init_state=None):
    """x: [b,s,h,p]; dt: [b,s,h] (>0); A: [h] (<0); B,C: [b,s,n].
    Returns (y [b,s,h,p], final_state [b,h,p,n])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp  # [b,h,p], [b,h], [b,n], [b,n]
        dA = jnp.exp(dt_t * A)  # [b,h]
        dBx = jnp.einsum("bn,bh,bhp->bhpn", B_t, dt_t, x_t)
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, C_t)
        return state, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state
