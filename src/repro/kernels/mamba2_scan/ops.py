"""jit'd public wrapper for the SSD-scan kernel: padding + init state."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # [b, s, h, p]
    dt: jax.Array,  # [b, s, h]  (softplus'd, > 0)
    A: jax.Array,  # [h]        (negative)
    B: jax.Array,  # [b, s, n]
    C: jax.Array,  # [b, s, n]
    init_state: Optional[jax.Array] = None,  # [b, h, p, n]
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt=0 padding is exact: exp(0·A)=1 (no decay), dt·x=0 (no input)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    y, fin = ssd_scan_kernel(x, dt, A, B, C, s0, chunk=chunk, interpret=interpret)
    if pad:
        y = y[:, :s]
    return y, fin
