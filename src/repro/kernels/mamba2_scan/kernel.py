"""Chunked SSD scan as a Pallas TPU kernel.

Grid = (B, H, S/chunk) with the chunk axis innermost and sequential
("arbitrary"): the [p, n] per-head state lives in VMEM scratch across
the sweep.  Each grid step does the three SSD pieces as dense MXU work
on one chunk:

    y_diag  = (L ⊙ C Bᵀ) · (dt ⊙ x)        intra-chunk   [c,c]@[c,p]
    y_off   = exp(cum) ⊙ (C · stateᵀ)       inter-chunk   [c,n]@[n,p]
    state'  = exp(cum_C) ⊙ state + (B ⊙ w)ᵀ·x             [n,c]@[c,p]

This is the TPU adaptation of the Mamba2 CUDA kernel: where the GPU
version streams chunks through shared memory with warp-level matmuls,
the TPU version makes each piece an MXU ``dot_general`` over a
VMEM-resident chunk, with the recurrence carried by the sequential grid
axis instead of a persistent thread block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _ssd_kernel(
    x_ref,  # [1, c, 1, p]
    dt_ref,  # [1, c, 1]
    a_ref,  # [1]
    b_ref,  # [1, c, n]
    c_ref,  # [1, c, n]
    s0_ref,  # [1, 1, p, n]  initial state
    y_ref,  # [1, c, 1, p]
    sout_ref,  # [1, 1, p, n] final state
    state_ref,  # scratch [p, n] f32
    *,
    chunk: int,
):
    z = pl.program_id(2)
    nz = pl.num_programs(2)

    @pl.when(z == 0)
    def init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # [c, p]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [c]
    A = a_ref[0].astype(jnp.float32)  # scalar
    Bm = b_ref[0].astype(jnp.float32)  # [c, n]
    Cm = c_ref[0].astype(jnp.float32)  # [c, n]

    dA = dt * A  # [c], negative
    cum = jnp.cumsum(dA)  # [c]

    # intra-chunk decay L[t, l] = exp(cum_t - cum_l) for l <= t
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ldiff = cum[:, None] - cum[None, :]
    L = jnp.where(ti >= li, jnp.exp(ldiff), 0.0)  # [c, c]

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [c, c]
    xdt = x * dt[:, None]  # [c, p]
    y_diag = jax.lax.dot_general(
        L * scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [c, p]

    # inter-chunk: y_off = exp(cum) ⊙ (C · stateᵀ)
    st = state_ref[...]  # [p, n]
    y_off = jax.lax.dot_general(
        Cm, st, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]  # [c, p]

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state' = exp(cum_C) ⊙ state + xᵀ·(B ⊙ w), w = exp(cum_C - cum)·dt
    w = jnp.exp(cum[-1] - cum) * dt  # [c]
    Bw = Bm * w[:, None]  # [c, n]
    s_local = jax.lax.dot_general(
        x, Bw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [p, n]
    state_ref[...] = st * jnp.exp(cum[-1]) + s_local

    @pl.when(z == nz - 1)
    def fin():
        sout_ref[0, 0] = state_ref[...].astype(sout_ref.dtype)


def ssd_scan_kernel(
    x, dt, A, B, C, s0, *, chunk: int = 128, interpret: bool = False
):
    """x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B,C: [b,s,n]; s0: [b,h,p,n].
    s must be a chunk multiple (ops.py pads).  Returns (y, final_state)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nz = s // chunk
    grid = (b, h, nz)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, zi: (bi, zi, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, zi: (bi, zi, hi)),
            pl.BlockSpec((1,), lambda bi, hi, zi: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, zi: (bi, zi, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, zi: (bi, zi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, zi: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, zi: (bi, zi, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, zi: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(x, dt, A, B, C, s0)
