"""Deterministic, shardable synthetic token pipeline.

Design constraints for 1000+-node training:

* **Determinism** — batch ``step`` is a pure function of ``(seed, step)``;
  any host can regenerate any shard of any step.  This is what makes
  checkpoint-restart and *elastic rescale* trivial: after a failure the
  surviving hosts recompute their (new) shard of the same step stream —
  no data-state checkpoint is needed.
* **Host sharding** — each host materializes only ``global_batch /
  n_hosts`` rows (``host_slice``).
* **Prefetch** — a background thread keeps ``prefetch`` batches ready so
  step N+1's host work overlaps step N's device work (the paper's
  latency-hiding invariant applied to the input pipeline).

The token stream is a mixture of Zipf-distributed unigrams with a
repeating-ngram structure so the LM loss actually decreases during the
example runs (pure-uniform tokens give a flat loss).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "TokenPipeline", "make_batch_specs"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2  # unigram skew
    ngram: int = 8  # repeated-motif length (gives the model signal)
    n_motifs: int = 512


class TokenPipeline:
    """Iterator of ``{"tokens": [b, S], "labels": [b, S]}`` host shards."""

    def __init__(
        self,
        cfg: DataConfig,
        *,
        host_id: int = 0,
        n_hosts: int = 1,
        prefetch: int = 2,
    ):
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide by n_hosts")
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self._motifs = self._make_motifs()
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()
        self._prefetch = prefetch
        self._thread: Optional[threading.Thread] = None
        self._next_step = 0

    # -- deterministic generation -----------------------------------------
    def _make_motifs(self) -> np.ndarray:
        rng = np.random.default_rng(self.cfg.seed ^ 0x5F5E5F5)
        V = self.cfg.vocab_size
        # Zipf-ish unigram table (bounded)
        ranks = np.arange(1, V + 1, dtype=np.float64)
        probs = ranks ** (-self.cfg.zipf_a)
        probs /= probs.sum()
        return rng.choice(V, size=(self.cfg.n_motifs, self.cfg.ngram), p=probs)

    def batch_at(self, step: int, *, host_id: Optional[int] = None) -> dict:
        """Pure function of (seed, step, host) → the host's batch shard."""
        host = self.host_id if host_id is None else host_id
        c = self.cfg
        rng = np.random.default_rng((c.seed, step, host))
        b = self.local_batch
        n_slots = c.seq_len // c.ngram + 1
        ids = rng.integers(0, c.n_motifs, size=(b, n_slots))
        toks = self._motifs[ids].reshape(b, -1)[:, : c.seq_len + 1]
        # sprinkle noise tokens so the task is not pure memorization
        noise = rng.random((b, c.seq_len + 1)) < 0.05
        toks = np.where(
            noise, rng.integers(0, c.vocab_size, size=toks.shape), toks
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # -- prefetching iterator ----------------------------------------------
    def _worker(self):
        while not self._stop.is_set():
            batch = self.batch_at(self._next_step)
            self._next_step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[dict]:
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop.clear()
        self._next_step = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self.close()

    def close(self):
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass

    # -- elastic rescale -----------------------------------------------------
    def rescale(self, host_id: int, n_hosts: int) -> "TokenPipeline":
        """Return a pipeline for the new host set (node loss/join).  The
        step→data mapping is preserved because generation is pure."""
        return TokenPipeline(
            self.cfg, host_id=host_id, n_hosts=n_hosts, prefetch=self._prefetch
        )


def make_batch_specs(cfg, shape, *, np_dtype=np.int32) -> dict:
    """ShapeDtypeStruct stand-ins for one *global* batch of this model
    config × shape cell (used by the dry-run; no allocation)."""
    import jax

    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind == "train" or shape.kind == "prefill":
        S_text = S
        if cfg.n_img_tokens:
            S_text = S - cfg.n_img_tokens  # image tokens occupy the prefix
        specs["tokens"] = jax.ShapeDtypeStruct((B, S_text), np_dtype)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S_text), np_dtype)
        if cfg.enc_dec:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), cfg.jdtype
            )
        if cfg.n_img_tokens:
            specs["img_emb"] = jax.ShapeDtypeStruct(
                (B, cfg.n_img_tokens, cfg.d_model), cfg.jdtype
            )
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = jax.ShapeDtypeStruct((B,), np_dtype)
    return specs
