"""Multi-tenant serving runtime on the demand-driven executor.

The paper's thesis — a runtime that tracks data dependencies can hide
communication latency without user effort — extends naturally to
serving: with dependency-cone flush, *each client request is exactly a
cone*, so one shared :class:`~repro.core.engine.Runtime` can drain many
tenants' requests concurrently on its work-stealing worker pool, while
the dependency system keeps every tenant's results bit-identical to a
serialized execution.

* :class:`Server` — owns one shared Runtime, a record lock (recording
  is single-threaded; draining is not), and an
  :class:`AdmissionController` implementing the configured
  :class:`~repro.api.config.ServeConfig` policy (max in-flight cones,
  queue-depth shedding with :class:`AdmissionError`).
* :class:`Session` — one per tenant: records the tenant's graph region
  under the server's record lock, submits each request as a
  ``flush(wait=False, targets=...)`` dependency cone, and accumulates
  per-tenant :class:`TenantStats` (a merged
  :class:`~repro.exec.stats.WaitStats` plus a request
  :class:`LatencyHistogram` with p50/p95/p99).
* :class:`Request` — the in-flight handle; ``result()`` joins the cone
  and gathers the output.

See ``docs/serving.md`` for the lifecycle and the steal-threshold
heuristic (arXiv 1805.01768) that makes concurrent cones profitable.
"""
from .admission import AdmissionController, AdmissionError
from .histogram import LatencyHistogram
from .server import Request, Server, Session, TenantStats

__all__ = [
    "Server",
    "Session",
    "Request",
    "TenantStats",
    "AdmissionController",
    "AdmissionError",
    "LatencyHistogram",
]
