"""The multi-tenant server: one shared Runtime, many request cones.

Execution model:

* **Recording is single-threaded.**  Every :meth:`Session.request`
  records its graph region under the server's record lock, with the
  shared runtime bound as the calling thread's current runtime for the
  duration — user code inside the request function uses the normal
  ``repro.array`` / NumPy surface unchanged.
* **Draining is concurrent.**  The request's outputs are submitted as
  one non-blocking dependency-cone flush
  (``Runtime.flush(wait=False, targets=...)``); the record lock is
  released immediately, and the cone drains on the shared work-stealing
  worker pool alongside every other tenant's in-flight cones.  The
  engine joins only *conflicting* cones
  (:func:`repro.core.graph.cones_conflict`), so disjoint tenants never
  serialize — and any interleaving of non-conflicting cones is
  bit-identical to a barrier flush, which is what makes multi-tenancy
  safe at all.
* **Admission is bounded.**  The :class:`AdmissionController` caps
  in-flight cones and queue depth per :class:`repro.api.config.ServeConfig`;
  overload surfaces as :class:`AdmissionError`, never as unbounded
  latency.

Per-tenant accounting: each drained cone's measured
:class:`~repro.exec.stats.WaitStats` is folded into that tenant's
:class:`TenantStats` (so wait-fraction is attributable per tenant), and
end-to-end request latency — admission queue included — feeds a
mergeable :class:`LatencyHistogram` for p50/p95/p99.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.exec.stats import WaitStats

from .admission import AdmissionController, AdmissionError
from .histogram import LatencyHistogram

__all__ = ["Server", "Session", "Request", "TenantStats"]


class TenantStats:
    """Accumulated per-tenant accounting: ``wait`` (a merged
    :class:`~repro.exec.stats.WaitStats` over the tenant's drained
    cones), ``latency`` (end-to-end request histogram), and the request
    counters.  Metric properties (``wait_fraction``, ``makespan``, …)
    delegate to ``wait`` so :func:`repro.api.reporting.format_stats`
    renders a tenant like any measured stats row."""

    def __init__(self, name: str):
        self.name = name
        self.wait = WaitStats(mode="async", nworkers=0)
        self.latency = LatencyHistogram()
        self.n_requests = 0  # admitted (submitted) requests
        self.n_rejected = 0  # shed by admission control
        self.n_failed = 0  # admitted but failed (record or drain error)

    def __getattr__(self, attr):
        if attr.startswith("_") or attr == "wait":
            raise AttributeError(attr)
        return getattr(self.wait, attr)

    def __repr__(self):
        return (
            f"TenantStats({self.name!r}, n={self.n_requests}, "
            f"rejected={self.n_rejected}, failed={self.n_failed}, "
            f"wait={self.wait_fraction * 100:.1f}%, "
            f"p99={self.latency.p99 * 1e3:.2f}ms)"
        )


def _coerce_outputs(outs):
    """Normalize a request function's return value to a list of
    DistArrays (materializing lazy Exprs — still under the record
    lock/runtime binding, so their recording lands in this cone)."""
    from repro.core.darray import DistArray, Expr

    seq = outs if isinstance(outs, (tuple, list)) else (outs,)
    arrays = []
    for o in seq:
        if isinstance(o, Expr):
            o = o.materialize()
        if not isinstance(o, DistArray):
            raise TypeError(
                f"request function must return DistArrays (or lazy "
                f"expressions), got {type(o).__name__}"
            )
        arrays.append(o)
    if not arrays:
        raise TypeError("request function returned no arrays")
    return arrays


class Request:
    """Handle on one in-flight request: the output arrays plus the
    :class:`~repro.core.engine.FlushTicket` of their cone drain."""

    __slots__ = ("_session", "_arrays", "_ticket", "_t0", "_single")

    def __init__(self, session, arrays, ticket, t0, single):
        self._session = session
        self._arrays = arrays
        self._ticket = ticket
        self._t0 = t0
        self._single = single

    @property
    def session(self) -> "Session":
        return self._session

    def done(self) -> bool:
        return self._ticket.done()

    def wait(self, timeout: Optional[float] = None) -> "Request":
        """Join this request's cone drain without gathering (re-raises
        the drain's failure, if any)."""
        self._ticket.wait(timeout)
        return self

    def result(self, timeout: Optional[float] = None):
        """Join the drain and gather the output host ndarray(s).

        The join happens lock-free (cones drain concurrently); only the
        gather itself takes the server's record lock — by then the cone
        has landed in block storage, so the critical section is a copy,
        not a drain."""
        self._ticket.wait(timeout)
        with self._session._server._record_lock:
            outs = tuple(np.asarray(a) for a in self._arrays)
        return outs[0] if self._single else outs

    # executor-thread callback registered by Session.request: resolves
    # the request's accounting exactly when its drain does, keeping the
    # admission window equal to the true number of in-flight cones even
    # when no client thread ever calls result()
    def _on_drained(self, ticket) -> None:
        session = self._session
        session._server._admission.release()
        dt = time.monotonic() - self._t0
        stats = None
        failed = False
        try:
            # resolves the ticket's bookkeeping (stats fold into the
            # runtime, removal from the outstanding list) on this thread;
            # the future is already done, so this never blocks
            stats = ticket.wait()
        except BaseException:
            failed = True  # re-raised to callers of result()/wait()
        with session._lock:
            t = session.stats
            t.latency.record(dt)
            if failed:
                t.n_failed += 1
            elif isinstance(stats, WaitStats):
                t.wait.merge(stats)

    def __repr__(self):
        state = "ready" if self.done() else "pending"
        return (
            f"Request(tenant={self._session.name!r}, "
            f"n_outputs={len(self._arrays)}, {state})"
        )


class Session:
    """One tenant's handle on the server.  ``request(fn, *args)``
    records ``fn``'s graph region and submits it as a dependency cone;
    per-tenant accounting accumulates in :attr:`stats`."""

    def __init__(self, server: "Server", name: str):
        self._server = server
        self.name = name
        self._lock = threading.Lock()  # guards stats merges
        self.stats = TenantStats(name)

    def request(self, fn, *args, **kwargs) -> Request:
        """Admit, record, and submit one request.

        ``fn(*args, **kwargs)`` runs under the server's record lock with
        the shared runtime active on the calling thread; it must build
        and return the request's output DistArray(s) using the normal
        array surface, without reading results back (readback belongs in
        :meth:`Request.result`, outside the lock).  Raises
        :class:`AdmissionError` when shed by admission control.

        The record lock covers only recording plus cone *extraction*
        (:meth:`~repro.core.engine.Runtime.extract_cone`); planning,
        verification, and executor submission
        (:meth:`~repro.core.engine.Runtime.submit_cone`) run after the
        lock is released, concurrently across client threads — the lock
        hold time (tracked in :attr:`Server.lock_hold`) is recording
        cost only, not planning cost."""
        from repro.core import engine as _engine

        server = self._server
        t0 = time.monotonic()
        try:
            server._admission.admit()
        except AdmissionError:
            with self._lock:
                self.stats.n_rejected += 1
            raise
        try:
            with server._record_lock:
                t_lock = time.perf_counter()
                prev = getattr(_engine._tls, "runtime", None)
                _engine._tls.runtime = server.runtime
                try:
                    outs = fn(*args, **kwargs)
                    arrays = _coerce_outputs(outs)
                    handle = server.runtime.extract_cone(list(arrays))
                finally:
                    _engine._tls.runtime = prev
                    held = time.perf_counter() - t_lock
            server._note_lock_hold(held)
            # off the lock: plan + verify + submit on this client thread
            # (a failure here has already failed the handle's ticket)
            t_plan = time.perf_counter()
            ticket = server.runtime.submit_cone(handle)
            server._note_plan_time(time.perf_counter() - t_plan)
        except BaseException:
            server._admission.release()
            with self._lock:
                self.stats.n_failed += 1
            raise
        with self._lock:
            self.stats.n_requests += 1
        req = Request(
            self, arrays, ticket, t0, single=not isinstance(outs, (tuple, list))
        )
        ticket.add_done_callback(req._on_drained)
        return req

    def __repr__(self):
        return f"Session({self.name!r})"


class Server:
    """One shared runtime serving many tenants.

    Construction mirrors :func:`repro.runtime`: pass config objects or
    keyword overrides (``RuntimeConfig`` / ``ExecutionPolicy`` /
    ``ServeConfig`` fields are routed by name).  The policy must use the
    measured async flush backend with demand-driven sync — concurrent
    cone drains are an executor-level mechanism; the simulator and the
    barrier discipline both serialize everything by design."""

    def __init__(self, config=None, policy=None, serve=None, **overrides):
        from repro.api.config import (
            ExecutionPolicy,
            RuntimeConfig,
            ServeConfig,
            _CONFIG_FIELDS,
            _POLICY_FIELDS,
        )
        from repro.core.engine import Runtime

        serve_fields = {f.name for f in dataclasses.fields(ServeConfig)}
        srv_kw = {k: v for k, v in overrides.items() if k in serve_fields}
        cfg_kw = {k: v for k, v in overrides.items() if k in _CONFIG_FIELDS}
        pol_kw = {k: v for k, v in overrides.items() if k in _POLICY_FIELDS}
        unknown = set(overrides) - serve_fields - _CONFIG_FIELDS - _POLICY_FIELDS
        if unknown:
            raise TypeError(
                f"unknown server option(s) {sorted(unknown)} — valid fields: "
                f"ServeConfig {sorted(serve_fields)}, RuntimeConfig "
                f"{sorted(_CONFIG_FIELDS)}, ExecutionPolicy "
                f"{sorted(_POLICY_FIELDS)}"
            )
        config = (config or RuntimeConfig()).replace(**cfg_kw)
        policy = (policy or ExecutionPolicy(flush="async")).replace(**pol_kw)
        if policy.flush != "async":
            raise ValueError(
                "Server requires ExecutionPolicy(flush='async'): concurrent "
                "cone drains need the measured executor; the simulator "
                "drains synchronously"
            )
        if policy.resolved_sync != "demand":
            raise ValueError(
                "Server requires demand-driven sync (sync='demand' or "
                "'auto'): barrier sync joins every tenant's work on each "
                "readback, serializing the server"
            )
        self.config = config
        self.policy = policy
        self.serve_config = (serve or ServeConfig()).replace(**srv_kw)
        self.runtime = Runtime.from_config(config, policy)
        self._admission = AdmissionController(
            self.serve_config.max_inflight,
            self.serve_config.max_queue,
            self.serve_config.admission_timeout,
        )
        # RLock: Request.result's gather may trigger a (cheap, empty)
        # cone flush that is itself re-entrant from the recording side
        self._record_lock = threading.RLock()
        # record-lock hold time per request (recording + extraction only
        # — planning runs off the lock): the record/plan split's success
        # metric, rendered by benchmarks/serve_load.py
        self.lock_hold = LatencyHistogram()
        # ...and the off-lock plan+verify+submit time per request: the
        # lock-hold + plan-time pair is what the record lock *would*
        # have held in an on-lock design
        self.plan_time = LatencyHistogram()
        self._lock_hold_lock = threading.Lock()
        self._sessions: dict = {}
        self._sessions_lock = threading.Lock()
        self._closed = False

    def _note_lock_hold(self, seconds: float) -> None:
        from repro.obs import collector as _obs

        with self._lock_hold_lock:
            self.lock_hold.record(seconds)
        col = _obs.CURRENT
        if col is not None:
            col.lock_held("record", seconds)

    def _note_plan_time(self, seconds: float) -> None:
        with self._lock_hold_lock:
            self.plan_time.record(seconds)

    @property
    def admission(self) -> AdmissionController:
        return self._admission

    def session(self, name: str) -> Session:
        """The tenant's session, created on first use."""
        with self._sessions_lock:
            if self._closed:
                raise AdmissionError("server is closed", "closed")
            s = self._sessions.get(name)
            if s is None:
                s = self._sessions[name] = Session(self, name)
            return s

    def stats(self) -> dict:
        """``{tenant name: TenantStats}``, sorted by name."""
        with self._sessions_lock:
            items = sorted(self._sessions.items())
        return {name: s.stats for name, s in items}

    def format_stats(self, per_worker: bool = False) -> str:
        """Render every tenant as a row of the unified stats table
        (makespan / wait% / volume, plus the latency-quantile lines)."""
        from repro.api.reporting import format_stats

        return format_stats(
            list(self.stats().items()), per_worker=per_worker
        )

    def close(self) -> None:
        """Shut down: reject queued and future admissions, join every
        outstanding drain (in submission order), release the worker
        pool.  The first drain failure no client observed is re-raised
        after resources are released.  Double-close is a no-op."""
        if self._closed:
            return
        self._closed = True
        self._admission.close()
        with self._record_lock:
            self.runtime.close()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self.close()
        else:
            try:
                self.close()
            except Exception:
                pass  # the body's exception wins; resources are released
        return False

    def __repr__(self):
        return (
            f"Server(nprocs={self.config.nprocs}, "
            f"tenants={len(self._sessions)}, "
            f"inflight={self._admission.inflight}/"
            f"{self.serve_config.max_inflight})"
        )
