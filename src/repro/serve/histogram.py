"""Log-spaced latency histogram for request quantiles.

A serving runtime reports tail latency (p99), not means — the paper's
wait-fraction metric says how well *one* drain hides latency, while the
p99 says what the slowest-in-a-hundred tenant actually experienced
under concurrent load.  Quantiles over a fixed log-spaced bucket grid
are mergeable across tenants (unlike stored percentiles) and O(1) per
record, at the cost of a bounded relative error set by the bucket ratio
(~7% here: 60 buckets per 3 decades spanning 1 µs .. 100 s).
"""
from __future__ import annotations

import math
from bisect import bisect_left

__all__ = ["LatencyHistogram"]

# bucket upper edges: log-spaced, 20 per decade over [1e-6, 1e2] seconds
_N_PER_DECADE = 20
_LO_EXP, _HI_EXP = -6, 2
_EDGES = tuple(
    10.0 ** (_LO_EXP + i / _N_PER_DECADE)
    for i in range((_HI_EXP - _LO_EXP) * _N_PER_DECADE + 1)
)


class LatencyHistogram:
    """Fixed-grid log histogram: ``record(seconds)``, ``quantile(q)``,
    ``merge(other)``.  Values outside [1 µs, 100 s] clamp to the end
    buckets; the exact observed ``max`` is tracked separately so the
    tail is never under-reported by bucketing."""

    __slots__ = ("counts", "count", "sum", "max")

    def __init__(self):
        self.counts = [0] * (len(_EDGES) + 1)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        if seconds < 0 or math.isnan(seconds):
            seconds = 0.0
        elif math.isinf(seconds):
            # clamp to the overflow-bucket edge: an untreated +inf would
            # poison ``max`` — and every quantile, since quantile() clamps
            # its answer to ``max``
            seconds = _EDGES[-1]
        self.counts[bisect_left(_EDGES, seconds)] += 1
        self.count += 1
        self.sum += seconds
        if seconds > self.max:
            self.max = seconds

    def quantile(self, q: float) -> float:
        """Latency at quantile ``q`` in [0, 1] — the upper edge of the
        bucket holding the q-th sample (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        q = min(1.0, max(0.0, q))
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i >= len(_EDGES):  # overflow bucket: only max is honest
                    return self.max
                return min(_EDGES[i], self.max) if self.max else _EDGES[i]
        return self.max

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p95(self) -> float:
        return self.quantile(0.95)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def merge(self, other: "LatencyHistogram") -> None:
        """Fold ``other`` into self (exact: same fixed grid)."""
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        if other.max > self.max:
            self.max = other.max

    def __repr__(self):
        if not self.count:
            return "LatencyHistogram(empty)"
        return (
            f"LatencyHistogram(n={self.count}, p50={self.p50 * 1e3:.2f}ms, "
            f"p99={self.p99 * 1e3:.2f}ms, max={self.max * 1e3:.2f}ms)"
        )
