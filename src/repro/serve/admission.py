"""Admission control for the serving runtime.

Two bounds, both from :class:`~repro.api.config.ServeConfig`:

* ``max_inflight`` — request cones draining concurrently on the shared
  worker pool.  Beyond it, arrivals queue.
* ``max_queue`` — queued arrivals.  Beyond it, the request is shed
  *immediately* with :class:`AdmissionError` (reason ``"queue-full"``)
  rather than building unbounded latency: under overload, fast explicit
  rejection is the only signal a client can act on (back off, retry,
  route elsewhere).  An optional ``admission_timeout`` also rejects
  queued requests that cannot get a slot in time (reason ``"timeout"``).

The controller is a plain counting semaphore with a bounded waiter
queue — no fairness guarantee beyond the condition variable's wakeup
order, which is FIFO-ish under CPython.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

__all__ = ["AdmissionController", "AdmissionError"]


class AdmissionError(RuntimeError):
    """Request shed by admission control.

    ``reason`` is ``"queue-full"`` (arrived with the admission queue at
    ``max_queue``), ``"timeout"`` (queued longer than
    ``admission_timeout``), or ``"closed"`` (server shutting down).
    """

    def __init__(self, message: str, reason: str):
        super().__init__(message)
        self.reason = reason


class AdmissionController:
    """Bounded-concurrency gate: ``admit()`` blocks until an in-flight
    slot frees (or sheds the request), ``release()`` frees a slot."""

    def __init__(
        self,
        max_inflight: int = 8,
        max_queue: int = 64,
        admission_timeout: Optional[float] = None,
    ):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.max_inflight = max_inflight
        self.max_queue = max_queue
        self.admission_timeout = admission_timeout
        self._cv = threading.Condition()
        self._inflight = 0
        self._queued = 0
        self._closed = False
        # observability counters (read under no lock: monotonic ints)
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_over_released = 0
        self.peak_inflight = 0
        self.peak_queued = 0

    # -- introspection ----------------------------------------------------
    @property
    def inflight(self) -> int:
        return self._inflight

    @property
    def queued(self) -> int:
        return self._queued

    # -- the gate ---------------------------------------------------------
    def admit(self) -> None:
        """Take an in-flight slot, queuing if none is free.  Raises
        :class:`AdmissionError` instead of queuing past ``max_queue``,
        waiting past ``admission_timeout``, or after :meth:`close`."""
        timeout = self.admission_timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._closed:
                self.n_rejected += 1
                raise AdmissionError("server is closed", "closed")
            if self._inflight >= self.max_inflight:
                if self._queued >= self.max_queue:
                    self.n_rejected += 1
                    raise AdmissionError(
                        f"admission queue full ({self._queued} waiting, "
                        f"{self._inflight} in flight) — shed, retry with "
                        f"backoff",
                        "queue-full",
                    )
                self._queued += 1
                self.peak_queued = max(self.peak_queued, self._queued)
                try:
                    while self._inflight >= self.max_inflight:
                        if self._closed:
                            self.n_rejected += 1
                            # a release() notify this waiter consumed must
                            # not die with it — pass it on or another
                            # queued waiter strands until its own timeout
                            self._cv.notify()
                            raise AdmissionError("server is closed", "closed")
                        if deadline is None:
                            self._cv.wait()
                        else:
                            remaining = deadline - time.monotonic()
                            if remaining <= 0 or not self._cv.wait(remaining):
                                if self._inflight < self.max_inflight:
                                    break  # slot freed at the wire: take it
                                self.n_rejected += 1
                                self._cv.notify()
                                raise AdmissionError(
                                    f"no in-flight slot within {timeout} s",
                                    "timeout",
                                )
                finally:
                    self._queued -= 1
            self._inflight += 1
            self.n_admitted += 1
            self.peak_inflight = max(self.peak_inflight, self._inflight)

    def release(self) -> None:
        """Free one in-flight slot (called when the request's drain
        resolves, success or failure).  Over-releases are counted and
        clamped rather than raised — this runs on executor callback
        threads, where an exception would poison an unrelated drain."""
        with self._cv:
            if self._inflight <= 0:
                self.n_over_released += 1
                return
            self._inflight -= 1
            self._cv.notify()

    def close(self) -> None:
        """Reject all queued and future admissions (server shutdown)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
