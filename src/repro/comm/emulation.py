"""Bridge between the modeled cluster (α–β) and the real transfer
channels of ``repro.exec``.

The discrete-event simulator charges every message the cluster's wire
latency α; the async executor can *inject* the same α into its channels
(a real sleep per message, pipelined by the progress engine's deadline
heap, exposed inline by the blocking channel).  That makes the measured
wait-for-communication fractions directly comparable with the simulated
ones on a single machine, where the raw memcpy would otherwise be too
fast to need hiding.

``Runtime(..., exec_latency="alpha")`` resolves through
:func:`channel_params_for`.
"""
from __future__ import annotations

from repro.core.timeline import ClusterSpec

__all__ = ["channel_params_for", "resolve_latency"]


def channel_params_for(
    cluster: ClusterSpec, *, scale: float = 1.0, progress_threads: int = 2
) -> dict:
    """Channel emulation parameters for a modeled cluster.

    ``latency`` is the cluster's α (optionally scaled — CI machines can't
    afford 960 × 50 µs of real sleeping at full fidelity, ``scale`` trades
    fidelity for wall-clock).  ``progress_threads`` stands in for the NIC
    serialization resource: transfers' latencies overlap, their data
    movement serializes on these threads.
    """
    return dict(latency=cluster.alpha * scale, progress_threads=progress_threads)


def resolve_latency(spec, cluster: ClusterSpec) -> float:
    """Resolve a Runtime ``exec_latency`` spec: a number is taken as
    seconds; ``"alpha"`` means the modeled cluster's wire latency."""
    if spec == "alpha":
        return channel_params_for(cluster)["latency"]
    return float(spec)
