"""Latency-hiding collective primitives (paper §5.4/§5.7 → XLA ordering).

All functions are written for use **inside** ``jax.shard_map`` (they call
``lax.axis_index`` / ``lax.ppermute`` on a named mesh axis).  The ring
variants decompose one big collective into per-shard-block steps: at every
step the next block's transfer is *initiated before* the current block's
compute is emitted, which is exactly the paper's invariant 2 ("computation
only starts when no communication is ready to initiate") expressed as HLO
op order.  XLA's async collective pairs (``*-start``/``*-done``) then
overlap the permute with the matmul.

Shape convention: ``x`` is the *local shard*; matmuls contract the last
dim of ``x`` with the first dim of ``w``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "ring_all_gather",
    "ring_reduce_scatter",
    "ag_matmul",
    "matmul_rs",
    "halo_exchange",
    "stencil_1d_sharded",
    "jacobi_step_sharded",
]


def _axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, across the jax API change:
    ``lax.axis_size`` (jax >= 0.5) vs ``jax.core.axis_frame`` returning
    the size directly (jax <= 0.4.x)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax as _jax

    frame = _jax.core.axis_frame(axis_name)
    # late 0.4.3x returns the int size directly; earlier 0.4.x return an
    # AxisEnvFrame carrying it
    return getattr(frame, "size", frame)


def _fwd_perm(n: int):
    """ring: rank i sends to i+1 (accumulators travel forward)."""
    return [(i, (i + 1) % n) for i in range(n)]


def _bwd_perm(n: int):
    """ring: rank i sends to i-1 (so we *receive* rank i+1's block)."""
    return [(i, (i - 1) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# Ring all-gather / reduce-scatter (building blocks)
# ---------------------------------------------------------------------------

def ring_all_gather(x: jax.Array, axis_name: str, *, axis: int = 0) -> jax.Array:
    """All-gather via a ring of ``ppermute``s — n-1 steps, each step's
    transfer overlappable with whatever consumes the already-held blocks.

    Returns the gathered array with shard blocks concatenated along
    ``axis`` in rank order.
    """
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    shape = list(x.shape)
    size_local = shape[axis]
    shape[axis] = size_local * n
    out = jnp.zeros(shape, x.dtype)

    def write(out, blk, src):
        return lax.dynamic_update_slice_in_dim(out, blk, src * size_local, axis=axis)

    blk = x
    for k in range(n):
        src = (idx + k) % n  # the rank this block originated from
        if k < n - 1:
            nxt = lax.ppermute(blk, axis_name, _bwd_perm(n))  # comm first
        out = write(out, blk, src)
        if k < n - 1:
            blk = nxt
    return out


def ring_reduce_scatter(
    partials: Callable[[jax.Array], jax.Array] | jax.Array,
    axis_name: str,
    *,
    axis: int = 0,
) -> jax.Array:
    """Reduce-scatter via a forward ring.

    ``partials`` is either the full local partial-sum array (scattered
    along ``axis``) or a callable ``chunk_index -> partial block`` that
    *computes* the partial lazily — the lazy form lets the caller overlap
    each step's ppermute with the *next* partial's computation (the paper's
    sub-view-block interleave).
    """
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)

    if callable(partials):
        get = partials
    else:
        full = partials
        size_local = full.shape[axis] // n

        def get(c):
            return lax.dynamic_slice_in_dim(full, c * size_local, size_local, axis)

    # accumulator for chunk c starts at rank c+1 and travels forward,
    # visiting every rank once and ending at rank c after n-1 hops.
    c0 = (idx - 1) % n
    acc = get(c0)
    for t in range(1, n):
        nxt_partial = get((idx - 1 - t) % n)  # independent of the permute
        acc = lax.ppermute(acc, axis_name, _fwd_perm(n))  # comm first
        acc = acc + nxt_partial
    return acc


# ---------------------------------------------------------------------------
# Overlapped collective matmuls (the TP workhorses)
# ---------------------------------------------------------------------------

def ag_matmul(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    overlap: str = "ring",
    gather_axis: int = -2,
) -> jax.Array:
    """``all_gather(x) @ w`` with the gather hidden behind the matmul.

    ``x``: local shard ``[..., S/n, K]`` (sharded along ``gather_axis``);
    ``w``: ``[K, N_local]`` (already the local TP shard).
    Returns ``[..., S, N_local]``.

    overlap="ring": n partial matmuls, each overlapped with the ppermute
    bringing the next x-block (paper §5.4 schedule).
    overlap="none": one blocking all-gather then one matmul (paper's
    blocking baseline).
    """
    n = _axis_size(axis_name)
    if overlap == "none" or n == 1:
        xg = lax.all_gather(x, axis_name, axis=gather_axis % x.ndim, tiled=True)
        return xg @ w

    idx = lax.axis_index(axis_name)
    ga = gather_axis % x.ndim
    s_local = x.shape[ga]
    out_shape = list(x.shape)
    out_shape[ga] = s_local * n
    out_shape[-1] = w.shape[-1]
    out = jnp.zeros(out_shape, jnp.result_type(x.dtype, w.dtype))

    blk = x
    for k in range(n):
        src = (idx + k) % n
        if k < n - 1:
            nxt = lax.ppermute(blk, axis_name, _bwd_perm(n))  # comm first
        y = blk @ w  # overlaps the in-flight permute
        out = lax.dynamic_update_slice_in_dim(out, y.astype(out.dtype), src * s_local, axis=ga)
        if k < n - 1:
            blk = nxt
    return out


def matmul_rs(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    overlap: str = "ring",
    scatter_axis: int = -2,
) -> jax.Array:
    """``reduce_scatter(x @ w)`` with the scatter hidden behind the matmul.

    ``x``: ``[..., S, K_local]`` (K TP-sharded); ``w``: ``[K_local, N]``.
    Returns ``[..., S/n, N]`` — the fully-reduced shard of rows.

    overlap="ring": the partial matmul for each row-chunk is computed
    just-in-time while the accumulator ring-permutes (each hop overlapped).
    overlap="none": full matmul then one blocking psum_scatter.
    """
    n = _axis_size(axis_name)
    if overlap == "none" or n == 1:
        y = x @ w
        return lax.psum_scatter(y, axis_name, scatter_dimension=scatter_axis % y.ndim, tiled=True)

    sa = scatter_axis % x.ndim
    s = x.shape[sa]
    s_local = s // n

    def partial_chunk(c):
        xc = lax.dynamic_slice_in_dim(x, c * s_local, s_local, sa)
        return xc @ w

    return ring_reduce_scatter(partial_chunk, axis_name, axis=sa)


# ---------------------------------------------------------------------------
# Halo exchange + stencils (the paper's flagship application class)
# ---------------------------------------------------------------------------

def halo_exchange(
    u: jax.Array,
    axis_name: str,
    *,
    halo: int = 1,
    axis: int = 0,
    periodic: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Exchange ``halo``-wide boundary slabs with ring neighbours.

    Returns ``(left_halo, right_halo)`` — the slabs received from the
    previous/next rank along ``axis_name``.  Non-periodic boundaries get
    zero slabs (masked after the permute so the wire pattern is uniform).
    """
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    L = u.shape[axis]

    send_right = lax.slice_in_dim(u, L - halo, L, axis=axis)
    send_left = lax.slice_in_dim(u, 0, halo, axis=axis)
    # both permutes initiated back-to-back — XLA overlaps them with any
    # subsequent independent compute (the interior update).
    left_halo = lax.ppermute(send_right, axis_name, _fwd_perm(n))
    right_halo = lax.ppermute(send_left, axis_name, _bwd_perm(n))
    if not periodic:
        zero = jnp.zeros_like(left_halo)
        left_halo = jnp.where(idx == 0, zero, left_halo)
        right_halo = jnp.where(idx == n - 1, zero, right_halo)
    return left_halo, right_halo


def stencil_1d_sharded(
    u: jax.Array,
    axis_name: str,
    point_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    *,
    overlap: str = "ring",
    periodic: bool = False,
) -> jax.Array:
    """One 3-point-stencil sweep over a 1-D sharded array.

    ``point_fn(left, center, right)`` computes the new center value from the
    shifted neighbours (all same-shape arrays).

    overlap="ring" (paper §5.4): initiate halo permutes, compute the
    *interior* (needs no remote data) while they fly, then patch the two
    boundary cells.  overlap="none": wait for halos, then one full update —
    the halo transfer sits on the critical path.
    """
    L = u.shape[0]
    lh, rh = halo_exchange(u, axis_name, halo=1, axis=0, periodic=periodic)

    if overlap == "none":
        ext = jnp.concatenate([lh, u, rh], axis=0)
        return point_fn(ext[:-2], ext[1:-1], ext[2:])

    # interior update — depends only on local data; emitted after the
    # permute-starts so XLA hides the halo latency behind it.
    interior = point_fn(u[:-2], u[1:-1], u[2:])  # rows 1..L-2
    first = point_fn(lh[0], u[0], u[1])
    last = point_fn(u[L - 2], u[L - 1], rh[0])
    return jnp.concatenate([first[None], interior, last[None]], axis=0)


def jacobi_step_sharded(
    full: jax.Array,
    axis_name: str,
    *,
    overlap: str = "ring",
) -> jax.Array:
    """One 5-point Jacobi sweep on a 2-D grid sharded along rows (axis 0).

    Boundary rows/cols of the *global* grid are Dirichlet (kept fixed);
    interior is updated with the classic 0.2·(c+u+d+l+r) rule from the
    paper's Jacobi-Stencil benchmark (fig. 10).
    """
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    L = full.shape[0]

    lh, rh = halo_exchange(full, axis_name, halo=1, axis=0, periodic=False)

    def update(up, c, down):
        return 0.2 * (c[:, 1:-1] + up[:, 1:-1] + down[:, 1:-1] + c[:, :-2] + c[:, 2:])

    if overlap == "none":
        ext = jnp.concatenate([lh, full, rh], axis=0)
        new_int = update(ext[:-2], ext[1:-1], ext[2:])
    else:
        # interior rows first (local-only), boundary rows after the halos.
        interior = update(full[:-2], full[1:-1], full[2:])  # rows 1..L-2
        top = update(lh, full[:1], full[1:2])
        bot = update(full[L - 2 : L - 1], full[L - 1 :], rh)
        new_int = jnp.concatenate([top, interior, bot], axis=0)

    out = full.at[:, 1:-1].set(new_int)
    # re-pin global Dirichlet boundary rows (first row of rank 0, last of n-1)
    out = jnp.where(
        (idx == 0) & (jnp.arange(L)[:, None] == 0), full, out
    )
    out = jnp.where(
        (idx == n - 1) & (jnp.arange(L)[:, None] == L - 1), full, out
    )
    return out
