"""repro.comm — the paper's communication patterns mapped to TPU/JAX.

The paper's flush algorithm (§5.7) aggressively *initiates* communication
and lazily evaluates compute so transfers hide behind local work.  Inside
one XLA program the analogue is *op ordering*: every primitive here emits
the collective (``ppermute`` / ``all_gather`` / ``psum_scatter``) **before**
the compute that overlaps it, so XLA's async collectives
(``collective-permute-start/done``) get the maximal overlap window.

Each primitive has a ``overlap="ring"`` mode (the paper's latency-hiding
schedule: blocked transfers interleaved with per-block compute — §5.4's
sub-view-block walk) and an ``overlap="none"`` mode (the paper's blocking
baseline: one monolithic collective on the critical path).
"""
from .collectives import (
    ag_matmul,
    halo_exchange,
    jacobi_step_sharded,
    matmul_rs,
    ring_all_gather,
    ring_reduce_scatter,
    stencil_1d_sharded,
)

__all__ = [
    "ag_matmul",
    "matmul_rs",
    "ring_all_gather",
    "ring_reduce_scatter",
    "halo_exchange",
    "stencil_1d_sharded",
    "jacobi_step_sharded",
]
