"""Gradient compression for the cross-pod (DCI) all-reduce axis.

On a multi-pod mesh the ``pod`` axis crosses data-center interconnect at
a fraction of ICI bandwidth; compressing the DP gradient all-reduce on
that axis is the standard lever.  Two transforms, both usable as
``AdamW(grad_transform=...)`` (they compress+decompress locally — in the
compiled program the *compressed* representation is what crosses the pod
axis; see ``launch/train.py: cross_pod_psum_compressed``):

* **int8 stochastic-rounding quantization** — 4× wire reduction, unbiased.
* **top-k with error feedback** — keeps the k largest-|g| entries per
  leaf, accumulating the residual locally (Stich et al.); sparsity ~99%.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "int8_quantize",
    "int8_dequantize",
    "int8_compress_transform",
    "topk_ef_transform",
]


def int8_quantize(g: jax.Array, key: Optional[jax.Array] = None):
    """Per-tensor symmetric int8 with optional stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    x = g / scale
    if key is not None:
        noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
        x = x + noise
    q = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_compress_transform(seed: int = 0):
    """Round-trip int8 transform (models the wire quantization error)."""

    def transform(grads):
        leaves, tdef = jax.tree.flatten(grads)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        out = []
        for g, k in zip(leaves, keys):
            q, s = int8_quantize(g.astype(jnp.float32), k)
            out.append(int8_dequantize(q, s).astype(g.dtype))
        return jax.tree.unflatten(tdef, out)

    return transform


def topk_ef_transform(k_frac: float = 0.01):
    """Top-k sparsification with error feedback.  Stateful: returns
    (transform, init_state) — the residual pytree must be threaded by the
    caller (see launch/train.py)."""

    def init_state(grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def transform(grads, residual):
        def one(g, r):
            x = g.astype(jnp.float32) + r
            flat = x.reshape(-1)
            k = max(1, int(flat.size * k_frac))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            mask = (jnp.abs(x) >= thresh).astype(jnp.float32)
            sent = x * mask
            new_r = x - sent
            return sent.astype(g.dtype), new_r

        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residual)
        pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (
            jax.tree.unflatten(tdef, [p[0] for p in pairs]),
            jax.tree.unflatten(tdef, [p[1] for p in pairs]),
        )

    return transform, init_state
