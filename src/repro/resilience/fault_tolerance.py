"""Fault tolerance for 1000+-node runs.

Components (all host-side; they orchestrate, XLA executes):

* :class:`ClusterMonitor` — heartbeat table with failure detection
  (deadline-based, like the TPU pod coordinator).  Hosts report
  heartbeats; ``failed()`` returns hosts past the deadline.
* :class:`ElasticPlan` — given the surviving host set, recompute the data
  sharding (which host reads which batch rows) and the mesh shape to
  restart with.  Because the data pipeline is a pure function of
  ``(seed, step, host)`` and checkpoints are sharded by leaf (not by
  host), *any* surviving subset can resume from the latest checkpoint —
  this is the elastic-rescale path.
* :class:`StragglerTracker` — per-step deadline tracking; hosts whose
  step time is persistently above ``threshold × median`` are flagged for
  eviction (which feeds the elastic plan).  In-step mitigation on TPU is
  XLA's domain; at the framework level eviction-and-rescale is the
  effective lever.
* :class:`TrainSupervisor` — the restart policy glue used by
  ``launch/train.py``: run steps, checkpoint every N, on failure restore
  the latest checkpoint with the surviving hosts and continue.  The unit
  tests drive it with injected failures.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

__all__ = [
    "HostState",
    "ClusterMonitor",
    "ElasticPlan",
    "StragglerTracker",
    "TrainSupervisor",
]


@dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    alive: bool = True
    step_times: list = field(default_factory=list)


class ClusterMonitor:
    """Deadline-based failure detector over a heartbeat table."""

    def __init__(self, n_hosts: int, *, deadline: float = 30.0, clock=time.monotonic):
        self.deadline = deadline
        self.clock = clock
        now = clock()
        self.hosts = {h: HostState(h, now) for h in range(n_hosts)}

    def heartbeat(self, host_id: int, t: Optional[float] = None) -> None:
        hs = self.hosts[host_id]
        hs.last_heartbeat = self.clock() if t is None else t
        hs.alive = True

    def failed(self) -> list[int]:
        now = self.clock()
        out = []
        for hs in self.hosts.values():
            if hs.alive and now - hs.last_heartbeat > self.deadline:
                hs.alive = False
            if not hs.alive:
                out.append(hs.host_id)
        return sorted(out)

    def alive(self) -> list[int]:
        dead = set(self.failed())
        return sorted(h for h in self.hosts if h not in dead)

    def evict(self, host_id: int) -> None:
        self.hosts[host_id].alive = False


@dataclass(frozen=True)
class ElasticPlan:
    """Re-sharding plan for a surviving host set."""

    hosts: tuple[int, ...]  # surviving physical host ids, sorted
    n_hosts: int  # len(hosts)
    rank_of: dict  # physical host -> new contiguous rank
    global_batch: int
    rows_per_host: int

    @staticmethod
    def make(surviving: list[int], global_batch: int) -> "ElasticPlan":
        hosts = tuple(sorted(surviving))
        n = len(hosts)
        if n == 0:
            raise RuntimeError("no surviving hosts")
        # keep the global batch; if it no longer divides, shrink to the
        # largest multiple (documented drop — determinism preserved)
        rows = global_batch // n
        if rows == 0:
            raise RuntimeError("more hosts than batch rows")
        return ElasticPlan(
            hosts=hosts,
            n_hosts=n,
            rank_of={h: i for i, h in enumerate(hosts)},
            global_batch=rows * n,
            rows_per_host=rows,
        )

    def mesh_shape(self, model_parallel: int) -> tuple[int, int]:
        """(data, model) mesh for the survivors; model parallelism is kept,
        data parallelism shrinks."""
        chips = self.n_hosts  # 1 logical chip group per host here
        if chips % model_parallel == 0:
            return (chips // model_parallel, model_parallel)
        return (chips, 1)


class StragglerTracker:
    """Flags hosts whose step time is persistently above
    ``threshold × median`` over a sliding window."""

    def __init__(self, n_hosts: int, *, threshold: float = 2.0, window: int = 8, patience: int = 3):
        self.threshold = threshold
        self.window = window
        self.patience = patience
        self.times: dict[int, list[float]] = {h: [] for h in range(n_hosts)}
        self.strikes: dict[int, int] = {h: 0 for h in range(n_hosts)}

    def record(self, host_id: int, step_time: float) -> None:
        ts = self.times[host_id]
        ts.append(step_time)
        if len(ts) > self.window:
            ts.pop(0)

    def evaluate(self) -> list[int]:
        """Returns hosts to evict (persistent stragglers)."""
        med = np.median([np.median(t) for t in self.times.values() if t] or [0.0])
        if med <= 0:
            return []
        out = []
        for h, ts in self.times.items():
            if ts and np.median(ts) > self.threshold * med:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                out.append(h)
        return sorted(out)


class TrainSupervisor:
    """Checkpoint/restart + elastic-rescale policy loop.

    ``step_fn(state, step, plan) -> state`` runs one training step and may
    raise ``HostFailure`` (injected in tests, real pod: NCCL/ICI error).
    ``save_fn(state, step)`` / ``restore_fn() -> (state, step)`` plug the
    checkpoint manager.  ``on_rescale(plan)`` lets the caller rebuild
    meshes/pipelines for the new host set.
    """

    class HostFailure(RuntimeError):
        def __init__(self, host_id: int):
            super().__init__(f"host {host_id} failed")
            self.host_id = host_id

    def __init__(
        self,
        *,
        n_hosts: int,
        global_batch: int,
        step_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        checkpoint_every: int = 100,
        on_rescale: Optional[Callable] = None,
        max_restarts: int = 8,
    ):
        self.monitor = ClusterMonitor(n_hosts)
        self.global_batch = global_batch
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.checkpoint_every = checkpoint_every
        self.on_rescale = on_rescale
        self.max_restarts = max_restarts
        self.restarts = 0
        self.plan = ElasticPlan.make(list(range(n_hosts)), global_batch)

    def run(self, state, start_step: int, n_steps: int):
        step = start_step
        while step < start_step + n_steps:
            try:
                state = self.step_fn(state, step, self.plan)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(state, step)
            except self.HostFailure as f:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.monitor.evict(f.host_id)
                self.plan = ElasticPlan.make(self.monitor.alive(), self.global_batch)
                if self.on_rescale is not None:
                    self.on_rescale(self.plan)
                state, step = self.restore_fn()
        return state, step
