"""repro.resilience — fault tolerance, elasticity, straggler mitigation,
gradient compression for the cross-pod axis."""
from .compression import int8_compress_transform, topk_ef_transform
from .fault_tolerance import (
    ClusterMonitor,
    ElasticPlan,
    HostState,
    StragglerTracker,
    TrainSupervisor,
)

__all__ = [
    "ClusterMonitor",
    "HostState",
    "ElasticPlan",
    "StragglerTracker",
    "TrainSupervisor",
    "int8_compress_transform",
    "topk_ef_transform",
]
