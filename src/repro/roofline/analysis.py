"""Roofline terms from a compiled (dry-run) XLA artifact.

    T_compute = HLO_FLOPs / (chips × peak)
    T_memory  = HLO_bytes / (chips × HBM_bw)
    T_coll    = Σ_class wire_bytes / (chips × link_bw_class)

``cost_analysis()`` supplies FLOPs / bytes-accessed.  Collective wire
bytes are NOT in cost_analysis — we parse the post-partitioning HLO text
and apply per-algorithm wire factors (ring algorithms):

    all-gather      (g-1)/g × global_output_bytes   per participating device-group
    reduce-scatter  (g-1)/g × global_input_bytes
    all-reduce      2(g-1)/g × buffer_bytes
    all-to-all      (g-1)/g × buffer_bytes
    collective-permute  full buffer_bytes

Device-groups of size 2 on the multi-pod mesh are the "pod" (DCI) axis —
they get the slower link class.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

__all__ = [
    "HW",
    "CollectiveStats",
    "collective_bytes",
    "analyze_compiled",
    "roofline_terms",
    "model_flops",
]


@dataclass(frozen=True)
class HW:
    """TPU v5e-class constants (per chip)."""

    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9  # B/s
    ici_bw: float = 50e9  # B/s per link class (intra-pod)
    dci_bw: float = 25e9  # B/s cross-pod ("pod" axis)
    hbm_bytes: float = 16e9  # capacity


_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# matches e.g. "bf16[16,4096,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRCDST_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shape_bytes(text: str) -> float:
    """Sum sizes of all shapes in ``text`` (a tuple or single shape)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    # wire bytes PER DEVICE, by link class
    ici_bytes: float = 0.0
    dci_bytes: float = 0.0
    by_kind: dict = field(default_factory=dict)
    n_ops: int = 0

    def add(self, kind: str, wire: float, dci: bool):
        self.n_ops += 1
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + wire
        if dci:
            self.dci_bytes += wire
        else:
            self.ici_bytes += wire


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


def collective_bytes(hlo_text: str, *, n_devices: int, pod_group_size: int = 2) -> CollectiveStats:
    """Parse post-partitioning HLO; returns per-device wire bytes."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        # operand shapes: everything inside the op's argument list; output
        # shape: before the op name.  For sizing we take the larger of the
        # two tuple sums (AG: output bigger; RS: input bigger; AR: equal).
        head, _, tail = line.partition(m.group(1))
        out_b = _shape_bytes(head)
        in_b = _shape_bytes(tail)
        buf = max(out_b, in_b)
        if kind == "collective-permute":
            pairs = _SRCDST_RE.search(line)
            wire = in_b if pairs else buf
            # permutes on the pod axis would pair across 256-boundaries;
            # treat as ICI unless the pairs jump by >= 256
            dci = False
            if pairs:
                jumps = [
                    abs(int(a) - int(b)) >= 256
                    for a, b in re.findall(r"\{(\d+),(\d+)\}", pairs.group(1))
                ]
                dci = any(jumps)
            stats.add(kind, wire, dci)
            continue
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        frac = (g - 1) / g
        # HLO shapes here are PER-DEVICE (post-partitioning).  Ring wire
        # bytes per device: AG sends the local shard g-1 times = (g-1) ×
        # in_b = frac × out_b (out = g × in); RS symmetric; AR = AG+RS.
        if kind == "all-gather":
            wire = frac * out_b
        elif kind == "reduce-scatter":
            wire = frac * in_b
        elif kind == "all-to-all":
            wire = frac * buf
        else:  # all-reduce
            wire = 2 * frac * in_b
        dci = g == pod_group_size and n_devices > 256
        stats.add(kind, wire, dci)
    return stats


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (inference) per step, N = active params."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens


def analyze_compiled(compiled, *, n_devices: int, hw: HW = HW()) -> dict:
    """Extract flops / bytes / collective wire bytes from a compiled
    executable.  cost_analysis flops are whole-program (all devices)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo, n_devices=n_devices)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            mem[k] = getattr(ma, k, None)
    except Exception:
        pass
    return {
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "coll_ici_bytes": coll.ici_bytes,
        "coll_dci_bytes": coll.dci_bytes,
        "coll_by_kind": coll.by_kind,
        "coll_ops": coll.n_ops,
        "memory": mem,
    }


def roofline_terms(analysis: dict, *, n_devices: int, hw: HW = HW()) -> dict:
    """The three terms in seconds + the dominant bottleneck.

    ``cost_analysis()`` on the compiled artifact reports the PER-PARTITION
    (per-device) program — verified against 6·N·D in EXPERIMENTS.md — so
    each term divides by the per-chip rate directly, NOT by chips again.
    Collective wire bytes from the parser are likewise per-device.
    """
    t_compute = analysis["hlo_flops"] / hw.peak_flops
    t_memory = analysis["hlo_bytes"] / hw.hbm_bw
    t_coll = (
        analysis["coll_ici_bytes"] / hw.ici_bw
        + analysis["coll_dci_bytes"] / hw.dci_bw
    )
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = t_compute / bound if bound > 0 else 0.0
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dom,
        "roofline_fraction": frac,  # compute-term share of the bound
    }
