"""repro.roofline — three-term roofline from compiled dry-run artifacts."""
from .analysis import (
    HW,
    CollectiveStats,
    analyze_compiled,
    collective_bytes,
    model_flops,
    roofline_terms,
)

__all__ = [
    "HW",
    "CollectiveStats",
    "analyze_compiled",
    "collective_bytes",
    "model_flops",
    "roofline_terms",
]
