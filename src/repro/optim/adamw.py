"""AdamW with global-norm clipping and LR schedules, pure JAX pytrees.

The optimizer state dtype is configurable (``cfg.opt_state_dtype``):
f32 moments by default, bf16 for the >100B archs where the moment
memory would not fit HBM — the de-facto large-scale practice.

Distributed-optimization hooks:

* ``grad_transform`` — applied to the gradient pytree *before* the
  update; used by ``repro.resilience.compression`` to plug in int8 /
  top-k error-feedback compression of the cross-pod all-reduce.
* the update is shape-preserving and elementwise, so it shards under
  whatever PartitionSpec the parameters carry (FSDP-friendly).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState", "cosine_schedule", "linear_warmup_cosine"]


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: dict  # first moment, like params
    nu: dict  # second moment, like params


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        t = jnp.minimum(step, total_steps) / max(1, total_steps)
        c = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * c)

    return lr


def linear_warmup_cosine(
    base_lr: float, warmup: int, total_steps: int, final_frac: float = 0.1
):
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup), final_frac)

    def lr(step):
        warm = base_lr * step / max(1, warmup)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return lr


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"
    grad_transform: Optional[Callable] = None  # e.g. compression

    def init(self, params) -> OptState:
        dt = jnp.dtype(self.moment_dtype)
        z = lambda p: jnp.zeros(p.shape, dt)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(z, params),
            nu=jax.tree.map(z, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        if self.grad_transform is not None:
            grads = self.grad_transform(grads)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        lr = self._lr(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m32.astype(mdt), v32.astype(mdt)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
