"""repro.optim — AdamW + clipping + LR schedules (no external deps)."""
from .adamw import AdamW, OptState, cosine_schedule, linear_warmup_cosine

__all__ = ["AdamW", "OptState", "cosine_schedule", "linear_warmup_cosine"]
