"""Wait attribution: charge every worker wait span to its cause.

The paper's metric — the fraction of worker time spent waiting on
communication — is a single number.  This module decomposes it: every
``wait-start``/``wait-end`` span in a trace is charged back to the
op or message that *ended* it, so "wait% = 9%" becomes "7% is the
halo-exchange transfers".

Charging rules per wait reason:

* ``empty-queue`` — the worker's ready queue was empty; the span ends
  when a newly-ready op arrives.  The charge goes to the op whose
  completion made the ender ready (the ``ready`` causality event), so a
  compute op that only became ready when its transfer delivered charges
  the *transfer*, not itself.  With no recorded cause the ender itself
  is charged.
* ``channel`` — the worker was blocked inside a synchronous channel
  post; the charge is the comm op itself.
* ``barrier`` — the main thread blocked in ``FlushTicket.wait``; the
  charge is the flush (reported separately from worker waits — it is
  not part of the per-worker wait fraction).

Spans are clipped to the union of the trace's drain segments
(``drain-begin``/``drain-end``): workers park on empty queues *between*
drains while the main thread records, and that parked time is not
latency — the clipping mirrors the ``Worker._idle_floor`` accounting of
:class:`~repro.exec.stats.WaitStats`, which is why the report's
``wait_fraction`` agrees with the measured one.

Offenders aggregate by *label group*: the op label up to its first
space / ``[`` (so ``xfer b3(0, 1)->p2`` and ``xfer b7(1, 1)->p3`` both
charge the group ``xfer``, while ``map:add`` and ``map+reduce:sum``
stay distinct).  Message traffic (count, bytes, mean post→deliver
latency) is attached per group from the ``msg-*`` events.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

__all__ = ["attribution", "AttributionReport", "WaitSpan"]


@dataclass
class WaitSpan:
    worker: object  # int rank, or "main" for barrier waits
    reason: str
    t0: float
    t1: float
    ender: Optional[int]  # uid of the op/message/flush that ended the wait

    @property
    def duration(self) -> float:
        return max(0.0, self.t1 - self.t0)


def _label_group(label: str, kind: str, uid) -> str:
    """Strip per-block/per-proc detail so spans aggregate by op family."""
    g = label.split(" ", 1)[0].split("[", 1)[0] if label else ""
    return g or f"{kind}#{uid}"


@dataclass
class AttributionReport:
    """Structured result of :func:`attribution`."""

    nworkers: int
    elapsed: float  # summed drain-segment wall-clock (trace-derived)
    total_compute: float  # summed compute-slice durations, clipped
    total_wait: float  # summed wait-span durations, clipped
    barrier_wait: float  # main-thread barrier time (not in total_wait)
    offenders: list = field(default_factory=list)  # dicts, sorted desc
    per_worker: dict = field(default_factory=dict)
    n_spans: int = 0
    dropped_events: int = 0

    @property
    def wait_fraction(self) -> float:
        """1 - compute/(nworkers*elapsed) — the same construction as
        :attr:`repro.exec.stats.WaitStats.wait_fraction`, from trace
        spans instead of worker accounting."""
        total = self.nworkers * self.elapsed
        if total <= 0:
            return 0.0
        return max(0.0, 1.0 - self.total_compute / total)

    @property
    def span_wait_fraction(self) -> float:
        """Share of worker time covered by explicit wait spans."""
        total = self.nworkers * self.elapsed
        return self.total_wait / total if total > 0 else 0.0

    def top(self, k: int = 10) -> list:
        return self.offenders[:k]

    def format(self, k: int = 10) -> str:
        lines = [
            f"wait attribution — {self.nworkers} workers, "
            f"{self.elapsed * 1e3:.1f} ms traced drain time, "
            f"{self.n_spans} wait spans"
            + (f" ({self.dropped_events} events dropped)" if self.dropped_events else ""),
            f"  worker wait {self.total_wait * 1e3:.1f} worker-ms "
            f"({self.span_wait_fraction * 100:.1f}% of worker time; "
            f"compute {self.total_compute * 1e3:.1f} worker-ms, "
            f"wait_fraction {self.wait_fraction * 100:.1f}%)"
            + (f"; main-thread barrier {self.barrier_wait * 1e3:.1f} ms"
               if self.barrier_wait else ""),
        ]
        if not self.offenders:
            lines.append("  no wait spans to attribute")
            return "\n".join(lines)
        lines.append(
            f"  {'#':>2s}  {'offender':<24s} {'wait ms':>10s} {'share%':>7s} "
            f"{'spans':>6s}  detail"
        )
        denom = self.nworkers * self.elapsed
        for i, off in enumerate(self.offenders[:k], 1):
            detail = ""
            if off.get("n_msgs"):
                detail = (
                    f"{off['n_msgs']} msgs, {off['msg_bytes'] / 1e6:.2f} MB"
                )
                if off.get("msg_latency") is not None:
                    detail += f", mean post→deliver {off['msg_latency'] * 1e3:.2f} ms"
            if off.get("example"):
                detail = (detail + ", " if detail else "") + f"e.g. {off['example']!r}"
            share = off["seconds"] / denom * 100 if denom > 0 else 0.0
            lines.append(
                f"  {i:>2d}  {off['group']:<24s} {off['seconds'] * 1e3:10.2f} "
                f"{share:6.1f}% {off['n_spans']:>6d}  {detail}"
            )
        if len(self.offenders) > k:
            lines.append(f"  ... {len(self.offenders) - k} more sources")
        return "\n".join(lines)


def _clip(t0: float, t1: float, segments) -> float:
    """Overlap of [t0, t1] with the union of (sorted, disjoint) segments.
    With no segments recorded the span counts in full."""
    if not segments:
        return max(0.0, t1 - t0)
    total = 0.0
    for s0, s1 in segments:
        lo, hi = max(t0, s0), min(t1, s1)
        if hi > lo:
            total += hi - lo
    return total


def attribution(collector, k: Optional[int] = None) -> AttributionReport:
    """Build an :class:`AttributionReport` from a collector (``k`` is
    accepted for symmetry with ``report.top(k)`` but does not truncate
    the stored offender list)."""
    events = list(collector.events)
    ops = dict(collector.ops)
    last_ts = events[-1][0] if events else 0.0

    segments: list = []
    seg_open: dict = {}  # tag -> t0
    nworkers = 0
    ready_cause: dict = {}
    wait_open: dict = {}  # worker -> (t0, reason)
    spans: list[WaitSpan] = []
    comp_open: dict = {}  # worker -> (t0, cpu0)
    comp_spans: list = []  # (worker, t0, t1, cpu seconds or None)
    msg_posted: dict = {}  # uid -> ts
    msg_latency: dict = {}  # uid -> post->deliver seconds

    for ts, et, uid, worker, extra in events:
        if et == "ready":
            if extra is not None:
                ready_cause[uid] = extra
        elif et == "wait-start":
            wait_open[worker] = (ts, extra)
        elif et == "wait-end":
            opened = wait_open.pop(worker, None)
            reason, ender = extra
            if opened is not None:
                spans.append(WaitSpan(worker, reason, opened[0], ts, ender))
        elif et == "compute-start":
            comp_open[worker] = (ts, extra)
        elif et == "compute-end":
            opened = comp_open.pop(worker, None)
            if opened is not None:
                t0, cpu0 = opened
                cpu = (
                    extra - cpu0
                    if isinstance(extra, float) and isinstance(cpu0, float)
                    else None
                )
                comp_spans.append((worker, t0, ts, cpu))
        elif et == "drain-begin":
            seg_open[uid] = ts
            nworkers = max(nworkers, extra[1])
        elif et == "drain-end":
            t0 = seg_open.pop(uid, None)
            if t0 is not None:
                segments.append((t0, ts))
        elif et == "msg-posted":
            msg_posted[uid] = ts
        elif et == "msg-delivered":
            t0 = msg_posted.get(uid)
            if t0 is not None:
                msg_latency[uid] = ts - t0

    # close anything still open at the end of the traced window
    for worker, (t0, reason) in wait_open.items():
        spans.append(WaitSpan(worker, reason, t0, last_ts, None))
    for tag, t0 in seg_open.items():
        segments.append((t0, last_ts))
    # merge overlapping drain segments into a disjoint union: concurrent
    # cone drains overlap in time, and clipping against raw overlapping
    # intervals would double-charge every span under them (and inflate
    # the traced elapsed, deflating wait_fraction)
    segments.sort()
    merged: list = []
    for s0, s1 in segments:
        if merged and s0 <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], s1)
        else:
            merged.append([s0, s1])
    segments = [(s0, s1) for s0, s1 in merged]

    int_workers = {w for w in comp_open if isinstance(w, int)} | {
        s.worker for s in spans if isinstance(s.worker, int)
    } | {w for w, *_ in comp_spans if isinstance(w, int)}
    nworkers = max(nworkers, (max(int_workers) + 1) if int_workers else 0, 1)
    elapsed = sum(s1 - s0 for s0, s1 in segments)
    if elapsed <= 0.0 and events:
        elapsed = last_ts - events[0][0]

    per_worker: dict = {
        w: {"compute": 0.0, "empty-queue": 0.0, "channel": 0.0, "other": 0.0}
        for w in range(nworkers)
    }
    # compute charges use the slice's CPU-clock delta (what
    # WaitStats.compute_busy measures) scaled by the clipped share of
    # its wall extent — the wall slice includes GIL preemption, which
    # the measured wait_fraction counts as *waiting*, not computing
    total_compute = 0.0
    for w, t0, t1, cpu in comp_spans:
        wall = max(0.0, t1 - t0)
        d = _clip(t0, t1, segments)
        if cpu is not None:
            d = cpu * (d / wall) if wall > 0 else 0.0
        total_compute += d
        if w in per_worker:
            per_worker[w]["compute"] += d

    def charge_of(span: WaitSpan):
        """(group, example label, msg uid or None) for one span."""
        if span.reason == "barrier":
            return (f"flush#{span.ender} barrier", "", None)
        ender = span.ender
        if ender is None:
            return ("(end of trace)", "", None)
        uid = ready_cause.get(ender, ender)
        kind, label, _ = ops.get(uid, ("?", "", 0))
        group = _label_group(label, kind, uid)
        return (group, label, uid if uid in msg_posted or kind == "comm" else None)

    agg: dict = {}
    total_wait = barrier_wait = 0.0
    n_spans = 0
    for span in spans:
        d = _clip(span.t0, span.t1, segments)
        if d <= 0.0:
            continue
        n_spans += 1
        group, example, msg_uid = charge_of(span)
        rec = agg.setdefault(
            group,
            {"group": group, "seconds": 0.0, "n_spans": 0, "example": "",
             "n_msgs": 0, "msg_bytes": 0, "msg_uids": set(), "latencies": []},
        )
        rec["seconds"] += d
        rec["n_spans"] += 1
        if example and not rec["example"]:
            rec["example"] = example
        if msg_uid is not None and msg_uid not in rec["msg_uids"]:
            rec["msg_uids"].add(msg_uid)
            rec["n_msgs"] += 1
            rec["msg_bytes"] += ops.get(msg_uid, ("?", "", 0))[2]
            if msg_uid in msg_latency:
                rec["latencies"].append(msg_latency[msg_uid])
        if span.reason == "barrier" or span.worker == "main":
            barrier_wait += d
        else:
            total_wait += d
            if span.worker in per_worker:
                key = span.reason if span.reason in ("empty-queue", "channel") else "other"
                per_worker[span.worker][key] += d

    offenders = []
    for rec in agg.values():
        lat = rec.pop("latencies")
        rec.pop("msg_uids")
        rec["msg_latency"] = sum(lat) / len(lat) if lat else None
        offenders.append(rec)
    offenders.sort(key=lambda r: r["seconds"], reverse=True)

    return AttributionReport(
        nworkers=nworkers,
        elapsed=elapsed,
        total_compute=total_compute,
        total_wait=total_wait,
        barrier_wait=barrier_wait,
        offenders=offenders,
        per_worker=per_worker,
        n_spans=n_spans,
        dropped_events=collector.dropped,
    )
