"""repro.obs — runtime tracing, Perfetto export, and wait attribution.

The observability layer of the record → plan → execute → demand
pipeline.  Three pieces:

* :class:`TraceCollector` (:mod:`repro.obs.collector`) — a lock-free
  ring buffer of structured lifecycle events (op recorded / planned /
  enqueued / executed, message posted / progressed / delivered, worker
  wait spans tagged with *why*), installed globally via
  :func:`repro.trace`, ``ExecutionPolicy(trace=True)`` or
  ``REPRO_TRACE=1``.  Disabled tracing is a true no-op.
* :func:`export_trace` (:mod:`repro.obs.export`) — Chrome-trace /
  Perfetto JSON: one track per worker and per channel, flow arrows from
  each message's delivery to the compute op it unblocked, counter
  tracks for queue depths and in-flight messages.
* :func:`attribution` (:mod:`repro.obs.attribution`) — charges every
  wait span back to the op/message that ended it and reports the top-K
  wait sources, turning the paper's aggregate wait% into named causes.

Quick use::

    import repro

    with repro.trace("run_trace.json") as tr:
        with repro.runtime(flush="async", nprocs=8):
            ... numpy program ...
    print(repro.attribution(tr).format(k=5))
"""
from .attribution import AttributionReport, WaitSpan, attribution
from .collector import (
    CURRENT,
    DEFAULT_CAPACITY,
    TraceCollector,
    activate,
    current_tracer,
    deactivate,
    trace,
)
from .export import export_trace, validate_trace

__all__ = [
    "TraceCollector",
    "trace",
    "activate",
    "deactivate",
    "current_tracer",
    "DEFAULT_CAPACITY",
    "export_trace",
    "validate_trace",
    "attribution",
    "AttributionReport",
    "WaitSpan",
]
