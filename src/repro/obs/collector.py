"""Ring-buffer lifecycle-event collector — the tracing substrate.

One :class:`TraceCollector` holds a bounded ``deque`` of event tuples
``(ts, etype, uid, worker, extra)``; ``ts`` is seconds relative to the
collector's creation (``time.perf_counter``-based).  Appending to a
``maxlen`` deque is GIL-atomic, so workers, channel progress threads and
the recording main thread all emit without any lock — when the buffer
fills, the *oldest* events drop (``dropped`` reports how many).

The collector is installed into the module-global ``CURRENT`` slot
(:func:`activate` / :func:`deactivate`).  Every instrumentation site in
the runtime does::

    col = _obs.CURRENT
    if col is not None:
        col.some_event(...)

— a module-attribute load plus an ``is not None`` test, a few
nanoseconds.  With no collector active, tracing is a true no-op: no
allocation, no branch into this module, no behavioural difference (the
CI ``trace-smoke`` job gates the disabled-path overhead at <1% on the
10k-op dispatch chain).

Event taxonomy (see docs/observability.md for the full reference):

======================  =====================================================
etype                   meaning / extra payload
======================  =====================================================
``recorded``            op inserted into the dependency system
``rewritten``           plan pass built/replaced a node; extra =
                        ``(pass_name, (src_uid, ...))``
``dropped``             plan pass eliminated a node outright (dead-store
                        elimination); extra = pass name
``plan-pass``           one pass ran; extra = ``(name, n_ops_in, n_ops_out)``
``flush-begin``         Runtime.flush started; uid = flush id, extra =
                        ``(n_pending_total, n_cone, sync_mode, backend)``
``drain-begin/-end``    one executor drain segment; uid = flush id (tag),
                        begin extra = ``(n_pending, nworkers)``
``enqueued``            op pushed onto a worker ready queue; extra = qdepth
``dequeued``            op popped by its worker
``compute-start/-end``  backend execution of one compute payload
``msg-posted``          transfer handed to a channel; extra =
                        ``(chan, src_proc, dst_proc, nbytes)``
``msg-progressed``      progress engine picked the message up; extra = chan
``msg-delivered``       data movement done, consumers may decrement
``ready``               op's refcount hit zero; extra = uid of the op whose
                        completion caused it (wait attribution's causality)
``wait-start/-end``     worker (or ``"main"``) blocked; extra = reason, and
                        on end ``(reason, ender_uid)`` — the op/message
                        whose arrival ended the wait
``counter``             gauge sample; uid = counter name, extra = value
``plan-cache``          plan stage consulted the plan-shape cache; uid =
                        flush id, extra = ``(hit, n_ops)``
``lock-held``           a serving lock was held; uid = lock label (e.g.
                        ``"record"``), extra = held seconds
======================  =====================================================
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

__all__ = [
    "TraceCollector",
    "CURRENT",
    "DEFAULT_CAPACITY",
    "activate",
    "deactivate",
    "current_tracer",
    "trace",
]

DEFAULT_CAPACITY = 1_000_000

#: The active collector, or None (tracing disabled).  Instrumentation
#: sites read this attribute directly; keep it a plain module global.
CURRENT: Optional["TraceCollector"] = None


class TraceCollector:
    """Bounded buffer of lifecycle events plus an op-metadata registry.

    ``ops`` maps uid -> ``(kind, label, nbytes)`` so per-op metadata is
    recorded once (at ``recorded``/``rewritten``/``msg-posted`` time)
    instead of repeated on every event.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = capacity
        self.t0 = time.perf_counter()
        self.events: deque = deque(maxlen=capacity)
        self.ops: dict = {}  # uid -> (kind, label, nbytes)
        # uid -> flush/drain tag: with concurrent drains, per-op events
        # interleave across flushes; this registry lets export/attribution
        # route every op back to the drain segment that owns it
        self.flush_of: dict = {}
        self.n_emitted = 0

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    @property
    def dropped(self) -> int:
        """Events pushed out of the ring buffer (oldest first)."""
        return max(0, self.n_emitted - len(self.events))

    def now(self) -> float:
        return time.perf_counter() - self.t0

    # -- recording / planning --------------------------------------------
    def op_recorded(self, op) -> None:
        self.ops[op.uid] = (op.kind, op.label, op.nbytes)
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "recorded", op.uid, None, None)
        )

    def op_rewritten(self, pass_name: str, op, src_uids) -> None:
        self.ops[op.uid] = (op.kind, op.label, op.nbytes)
        self.n_emitted += 1
        self.events.append(
            (
                time.perf_counter() - self.t0,
                "rewritten",
                op.uid,
                None,
                (pass_name, tuple(src_uids)),
            )
        )

    def op_dropped(self, pass_name: str, op) -> None:
        """A plan pass eliminated ``op`` outright (dead-store
        elimination); extra = the pass name.  Together with
        ``rewritten`` this is the complete rewrite provenance the
        static plan verifier (repro.analysis) consumes."""
        if op.uid not in self.ops:
            self.ops[op.uid] = (op.kind, op.label, op.nbytes)
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "dropped", op.uid, None, pass_name)
        )

    def plan_pass(self, name: str, n_in: int, n_out: int) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "plan-pass", None, None, (name, n_in, n_out))
        )

    def plan_cache(self, fid, hit: bool, n_ops: int) -> None:
        """The plan stage consulted the plan-shape cache for flush
        ``fid``: ``hit`` says whether a cached recipe was replayed
        (skipping the pass pipeline and re-verification), ``n_ops`` is
        the cone's pre-plan operation count."""
        self.n_emitted += 1
        self.events.append(
            (
                time.perf_counter() - self.t0,
                "plan-cache",
                fid,
                "main",
                (bool(hit), n_ops),
            )
        )

    def lock_held(self, label: str, seconds: float) -> None:
        """A serving-layer lock (``label``, e.g. ``"record"``) was held
        for ``seconds`` — the record/plan split's success metric."""
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "lock-held", label, "main", seconds)
        )

    # -- flush / drain segments ------------------------------------------
    def flush_begin(self, fid, n_total: int, n_cone: int, sync: str, backend: str) -> None:
        self.n_emitted += 1
        self.events.append(
            (
                time.perf_counter() - self.t0,
                "flush-begin",
                fid,
                "main",
                (n_total, n_cone, sync, backend),
            )
        )

    def drain_begin(self, tag, n_pending: int, nworkers: int) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "drain-begin", tag, None, (n_pending, nworkers))
        )

    def drain_end(self, tag) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "drain-end", tag, None, None)
        )

    def drain_ops(self, tag, uids) -> None:
        """Register every op of a submitted drain under its flush/drain
        tag (no event emitted — pure registry, used to keep traces
        structurally valid when drains interleave)."""
        flush_of = self.flush_of
        for uid in uids:
            flush_of[uid] = tag

    # -- worker queues ----------------------------------------------------
    def enqueued(self, uid, worker, qdepth: int) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "enqueued", uid, worker, qdepth)
        )

    def dequeued(self, uid, worker) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "dequeued", uid, worker, None)
        )

    # batch variants for the per-op hot paths: one timestamp and one
    # method call per *batch* keeps traced dispatch overhead <5% on the
    # 10k-op chain (ops pushed/popped together share one instant anyway)
    def enqueued_many(self, uids, worker, qdepth: int) -> None:
        ts = time.perf_counter() - self.t0
        append = self.events.append
        for uid in uids:
            append((ts, "enqueued", uid, worker, qdepth))
        self.n_emitted += len(uids)

    def dequeued_many(self, uids, worker) -> None:
        ts = time.perf_counter() - self.t0
        append = self.events.append
        for uid in uids:
            append((ts, "dequeued", uid, worker, None))
        self.n_emitted += len(uids)

    def ready_many(self, pairs) -> None:
        """``pairs`` is a list of ``(uid, cause_uid)`` tuples."""
        ts = time.perf_counter() - self.t0
        append = self.events.append
        for uid, cause in pairs:
            append((ts, "ready", uid, None, cause))
        self.n_emitted += len(pairs)

    # extra = per-thread CPU clock sample: wall-clock slice bounds show
    # GIL/scheduler preemption in the timeline, while the CPU delta is
    # what WaitStats.compute_busy measures — attribution uses the delta
    # so its wait_fraction is the same construction as the measured one
    def compute_start(self, uid, worker) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "compute-start", uid, worker,
             time.thread_time())
        )

    def compute_end(self, uid, worker) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "compute-end", uid, worker,
             time.thread_time())
        )

    # -- channel messages --------------------------------------------------
    def msg_posted(self, op, chan: str) -> None:
        uid = op.uid
        if uid not in self.ops:
            self.ops[uid] = (op.kind, op.label, op.nbytes)
        procs = op.procs
        src = procs[0] if procs else None
        dst = procs[-1] if procs else None
        self.n_emitted += 1
        self.events.append(
            (
                time.perf_counter() - self.t0,
                "msg-posted",
                uid,
                None,
                (chan, src, dst, op.nbytes),
            )
        )

    def msg_progressed(self, uid, chan: str) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "msg-progressed", uid, None, chan)
        )

    def msg_delivered(self, uid, chan: str) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "msg-delivered", uid, None, chan)
        )

    # -- causality / waits -------------------------------------------------
    def ready(self, uid, cause_uid) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "ready", uid, None, cause_uid)
        )

    def wait_start(self, worker, reason: str) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "wait-start", None, worker, reason)
        )

    def wait_end(self, worker, reason: str, ender_uid) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "wait-end", None, worker, (reason, ender_uid))
        )

    # -- counters ----------------------------------------------------------
    def counter(self, name: str, value) -> None:
        self.n_emitted += 1
        self.events.append(
            (time.perf_counter() - self.t0, "counter", name, None, value)
        )


def activate(collector: TraceCollector) -> Optional[TraceCollector]:
    """Install ``collector`` as the active tracer; returns the previous
    one (pass it back to :func:`deactivate` to restore nesting)."""
    global CURRENT
    prev = CURRENT
    CURRENT = collector
    return prev


def deactivate(prev: Optional[TraceCollector] = None) -> None:
    """Restore ``prev`` (or disable tracing entirely)."""
    global CURRENT
    CURRENT = prev


def current_tracer() -> Optional[TraceCollector]:
    """The active collector, or None when tracing is disabled."""
    return CURRENT


class trace:
    """Context manager enabling tracing for a region of the program::

        with repro.trace("run_trace.json") as tr:
            ... record / flush / gather ...
        # on exit: tracing restored, trace exported to the given path

    ``path=None`` skips the export — inspect the returned collector with
    :func:`repro.obs.attribution` / :func:`repro.obs.export_trace`
    yourself.  Runtimes entered while a ``trace()`` region is active
    adopt the ambient collector instead of creating their own, so one
    trace can span several runtimes (or one runtime several regions).
    """

    def __init__(self, path: Optional[str] = None, capacity: int = DEFAULT_CAPACITY):
        self.path = path
        self.collector = TraceCollector(capacity=capacity)
        self._prev: Optional[TraceCollector] = None

    def __enter__(self) -> TraceCollector:
        self._prev = activate(self.collector)
        return self.collector

    def __exit__(self, exc_type, exc, tb):
        deactivate(self._prev)
        if self.path is not None and exc_type is None:
            from .export import export_trace

            export_trace(self.collector, self.path)
        return False
