"""Chrome-trace / Perfetto JSON export of a :class:`TraceCollector`.

:func:`export_trace` converts the collector's raw event tuples into the
Chrome Trace Event format (the JSON flavour Perfetto's UI loads
directly — open https://ui.perfetto.dev and drop the file in):

* **pid 1 "runtime (main)"** — flush instants, ``drain#N`` slices
  bracketing each executor drain segment, plan-pass instants, rewrite
  provenance instants, and main-thread barrier waits;
* **pid 2 "workers"** — one thread row per worker rank, with ``X``
  slices for every compute payload (named by the op label) and for
  every wait span (``wait:empty-queue`` / ``wait:channel``);
* **pid 10+** — one process per channel, with async ``b``/``n``/``e``
  events per message (post → progress → deliver), so in-flight message
  latency is a visible horizontal bar;
* **pid 4 "counters"** — ``C`` events for every sampled gauge (queue
  depths, in-flight ops/messages, batch occupancy, cone sizes);
* **flow arrows** — a ``s``→``f`` flow from each message's delivery to
  the compute slice it unblocked (derived from the ``ready`` causality
  events), which is the latency-hiding picture itself: arrows that land
  on already-busy workers are hidden latency, arrows that land on
  waiting workers are exposed latency.

:func:`validate_trace` is the schema check used by tests and the CI
``trace-smoke`` job: structural validation of the emitted JSON (known
phase types, numeric timestamps, balanced async begin/end, named
complete events) without any external dependency.
"""
from __future__ import annotations

import json
from typing import Optional, Union

__all__ = ["export_trace", "validate_trace"]

PID_RUNTIME = 1
PID_WORKERS = 2
PID_COUNTERS = 4
PID_CHANNEL0 = 10  # one pid per channel name, counting up from here

_KNOWN_PH = {"X", "B", "E", "b", "n", "e", "i", "I", "s", "t", "f", "C", "M"}


def _us(ts: float) -> float:
    return round(ts * 1e6, 3)


def export_trace(collector, path: Optional[str] = None, full: bool = False) -> dict:
    """Render ``collector`` as a Chrome-trace dict; write JSON to
    ``path`` when given.  ``full=True`` additionally emits one instant
    per ``recorded``/``enqueued``/``dequeued``/``ready`` event (off by
    default — they dominate the file size on large graphs without
    changing the timeline picture)."""
    events = list(collector.events)
    ops = collector.ops
    te: list[dict] = []

    def meta(pid: int, name: str) -> None:
        te.append({"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
                   "args": {"name": name}})

    meta(PID_RUNTIME, "runtime (main)")
    meta(PID_WORKERS, "workers")
    meta(PID_COUNTERS, "counters")

    def label_of(uid) -> str:
        kind, label, _ = ops.get(uid, ("?", "", 0))
        return label or f"{kind}#{uid}"

    chan_pids: dict[str, int] = {}

    def chan_pid(chan: str) -> int:
        pid = chan_pids.get(chan)
        if pid is None:
            pid = PID_CHANNEL0 + len(chan_pids)
            chan_pids[chan] = pid
            meta(pid, f"channel:{chan}")
        return pid

    worker_tids: set = set()
    comp_open: dict = {}  # worker -> (ts, uid)
    wait_open: dict = {}  # worker -> (ts, reason)
    comp_start: dict = {}  # uid -> (ts, worker) — flow targets
    delivered: dict = {}  # msg uid -> (ts, chan)
    cause: dict = {}  # uid -> cause uid
    posted: set = set()  # msg uids whose "b" survived the ring buffer
    open_drains: set = set()  # drain tags whose "b" survived the buffer
    flush_of = getattr(collector, "flush_of", {})  # uid -> drain tag

    # non-worker wait spans (main thread, serve client threads) render as
    # enumerated rows on the runtime process; "main" is always tid 0
    runtime_tids: dict = {"main": 0}

    def runtime_tid(label) -> int:
        tid = runtime_tids.get(label)
        if tid is None:
            tid = len(runtime_tids)
            runtime_tids[label] = tid
        return tid

    for ts, et, uid, worker, extra in events:
        t = _us(ts)
        if et == "compute-start":
            comp_open[worker] = (ts, uid, extra)
            if uid not in comp_start:
                comp_start[uid] = (ts, worker)
        elif et == "compute-end":
            opened = comp_open.pop(worker, None)
            if opened is not None:
                worker_tids.add(worker)
                args = {"uid": uid}
                fid = flush_of.get(uid)
                if fid is not None:
                    args["flush"] = fid
                if isinstance(extra, float) and isinstance(opened[2], float):
                    # CPU time of the slice; the wall extent additionally
                    # contains GIL/scheduler preemption
                    args["cpu_us"] = _us(max(0.0, extra - opened[2]))
                te.append({"ph": "X", "cat": "compute", "name": label_of(uid),
                           "pid": PID_WORKERS, "tid": worker,
                           "ts": _us(opened[0]), "dur": max(0.0, t - _us(opened[0])),
                           "args": args})
        elif et == "wait-start":
            wait_open[worker] = (ts, extra)
        elif et == "wait-end":
            opened = wait_open.pop(worker, None)
            if opened is not None:
                reason, ender = extra
                if isinstance(worker, int):
                    pid, tid = PID_WORKERS, worker
                    worker_tids.add(worker)
                else:  # "main", "client-<tid>", ... — runtime-side waits
                    pid, tid = PID_RUNTIME, runtime_tid(worker)
                te.append({"ph": "X", "cat": "wait", "name": f"wait:{reason}",
                           "pid": pid, "tid": tid,
                           "ts": _us(opened[0]), "dur": max(0.0, t - _us(opened[0])),
                           "args": {"ender": ender}})
        elif et == "msg-posted":
            chan, src, dst, nbytes = extra
            posted.add(uid)
            te.append({"ph": "b", "cat": "msg", "name": label_of(uid),
                       "id": uid, "pid": chan_pid(chan), "tid": 0, "ts": t,
                       "args": {"src": src, "dst": dst, "nbytes": nbytes}})
        elif et == "msg-progressed":
            if uid in posted:
                te.append({"ph": "n", "cat": "msg", "name": "progressed",
                           "id": uid, "pid": chan_pid(extra), "tid": 0, "ts": t})
        elif et == "msg-delivered":
            delivered[uid] = (ts, extra)
            if uid in posted:
                posted.discard(uid)
                te.append({"ph": "e", "cat": "msg", "name": label_of(uid),
                           "id": uid, "pid": chan_pid(extra), "tid": 0, "ts": t})
        elif et == "drain-begin":
            # async ("b"/"e", keyed by tag) rather than nested ("B"/"E"):
            # concurrent cone drains interleave, and a stack-based E would
            # close the wrong segment
            open_drains.add(uid)
            te.append({"ph": "b", "cat": "drain", "name": f"drain#{uid}",
                       "id": str(uid), "pid": PID_RUNTIME, "tid": 0, "ts": t,
                       "args": {"n_pending": extra[0], "nworkers": extra[1]}})
        elif et == "drain-end":
            if uid in open_drains:  # an end whose begin fell off the ring
                open_drains.discard(uid)  # buffer has no segment to close
                te.append({"ph": "e", "cat": "drain", "name": f"drain#{uid}",
                           "id": str(uid), "pid": PID_RUNTIME, "tid": 0,
                           "ts": t})
        elif et == "flush-begin":
            n_total, n_cone, sync, backend = extra
            te.append({"ph": "i", "s": "p", "cat": "flush",
                       "name": f"flush#{uid}", "pid": PID_RUNTIME, "tid": 0,
                       "ts": t, "args": {"n_pending": n_total, "n_cone": n_cone,
                                         "sync": sync, "backend": backend}})
        elif et == "plan-pass":
            name, n_in, n_out = extra
            te.append({"ph": "i", "s": "t", "cat": "plan",
                       "name": f"pass:{name}", "pid": PID_RUNTIME, "tid": 0,
                       "ts": t, "args": {"ops_in": n_in, "ops_out": n_out}})
        elif et == "rewritten":
            pass_name, srcs = extra
            te.append({"ph": "i", "s": "t", "cat": "plan",
                       "name": f"rewrite:{pass_name}", "pid": PID_RUNTIME,
                       "tid": 0, "ts": t,
                       "args": {"uid": uid, "label": label_of(uid),
                                "sources": list(srcs)}})
        elif et == "dropped":
            te.append({"ph": "i", "s": "t", "cat": "plan",
                       "name": f"drop:{extra}", "pid": PID_RUNTIME,
                       "tid": 0, "ts": t,
                       "args": {"uid": uid, "label": label_of(uid),
                                "pass": extra}})
        elif et == "plan-cache":
            hit, n_ops = extra
            te.append({"ph": "i", "s": "t", "cat": "plan",
                       "name": f"plan-cache:{'hit' if hit else 'miss'}",
                       "pid": PID_RUNTIME, "tid": 0, "ts": t,
                       "args": {"flush": uid, "ops": n_ops}})
        elif et == "lock-held":
            te.append({"ph": "i", "s": "t", "cat": "serve",
                       "name": f"lock:{uid}", "pid": PID_RUNTIME, "tid": 0,
                       "ts": t, "args": {"held_ms": extra * 1e3}})
        elif et == "counter":
            te.append({"ph": "C", "cat": "gauge", "name": uid,
                       "pid": PID_COUNTERS, "tid": 0, "ts": t,
                       "args": {"value": extra}})
        elif et == "ready":
            if extra is not None:
                cause[uid] = extra
            if full:
                te.append({"ph": "i", "s": "t", "cat": "lifecycle",
                           "name": f"ready:{label_of(uid)}", "pid": PID_RUNTIME,
                           "tid": 0, "ts": t, "args": {"uid": uid, "cause": extra}})
        elif full and et in ("recorded", "enqueued", "dequeued"):
            pid, tid = (PID_RUNTIME, 0)
            if et != "recorded" and worker is not None:
                pid, tid = PID_WORKERS, worker
                worker_tids.add(worker)
            te.append({"ph": "i", "s": "t", "cat": "lifecycle",
                       "name": f"{et}:{label_of(uid)}", "pid": pid, "tid": tid,
                       "ts": t, "args": {"uid": uid}})

    # close still-in-flight messages and drains at the end of the traced
    # window so every async "b" has its "e" (bars extend to the edge)
    if events:
        t_end = _us(events[-1][0])
        for uid in sorted(posted, key=str):
            chan = next(iter(chan_pids)) if chan_pids else "channel"
            te.append({"ph": "e", "cat": "msg", "name": label_of(uid),
                       "id": uid, "pid": chan_pid(chan), "tid": 0,
                       "ts": t_end, "args": {"in_flight_at_end": True}})
        for tag in sorted(open_drains, key=str):
            te.append({"ph": "e", "cat": "drain", "name": f"drain#{tag}",
                       "id": str(tag), "pid": PID_RUNTIME, "tid": 0,
                       "ts": t_end, "args": {"in_flight_at_end": True}})

    # flow arrows: message delivery -> the compute slice it unblocked
    flow_id = 0
    for uid, c in cause.items():
        if c in delivered and uid in comp_start:
            d_ts, chan = delivered[c]
            c_ts, w = comp_start[uid]
            flow_id += 1
            te.append({"ph": "s", "cat": "unblocks", "name": "unblocks",
                       "id": flow_id, "pid": chan_pid(chan), "tid": 0,
                       "ts": _us(d_ts)})
            te.append({"ph": "f", "bp": "e", "cat": "unblocks", "name": "unblocks",
                       "id": flow_id, "pid": PID_WORKERS, "tid": w,
                       "ts": _us(c_ts)})

    for tid in sorted(worker_tids, key=str):
        te.append({"ph": "M", "pid": PID_WORKERS, "tid": tid,
                   "name": "thread_name", "args": {"name": f"worker-{tid}"}})
    for label, tid in runtime_tids.items():
        if tid == 0:
            continue  # tid 0 is the runtime (main) row itself
        te.append({"ph": "M", "pid": PID_RUNTIME, "tid": tid,
                   "name": "thread_name", "args": {"name": label}})

    doc = {
        "traceEvents": te,
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.obs",
            "n_events": collector.n_emitted,
            "dropped_events": collector.dropped,
        },
    }
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f, separators=(",", ":"))
    return doc


def validate_trace(trace: Union[str, dict]) -> dict:
    """Structural schema check of a Chrome-trace document (a dict or a
    path to a JSON file).  Raises :class:`ValueError` on the first
    violation; returns a summary ``{"n_events": ..., "per_phase": ...,
    "pids": ...}`` on success."""
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    evs = trace["traceEvents"]
    if not isinstance(evs, list) or not evs:
        raise ValueError("'traceEvents' must be a non-empty list")
    per_phase: dict = {}
    pids: set = set()
    async_balance: dict = {}
    async_open: dict = {}  # (cat, id) -> open depth (b before e, no double-open)
    flow_starts: dict = {}  # (cat, id) -> ts of the "s" endpoint
    flow_finishes: dict = {}  # (cat, id) -> ts of the "f" endpoint
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"event #{i} is not an object")
        ph = ev.get("ph")
        if ph not in _KNOWN_PH:
            raise ValueError(f"event #{i}: unknown phase {ph!r}")
        per_phase[ph] = per_phase.get(ph, 0) + 1
        if "pid" not in ev:
            raise ValueError(f"event #{i} ({ph}): missing pid")
        pids.add(ev["pid"])
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                raise ValueError(f"event #{i} ({ph}): non-numeric ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event #{i}: X slice with bad dur {dur!r}")
            if not ev.get("name"):
                raise ValueError(f"event #{i}: X slice without a name")
        if ph == "C":
            val = (ev.get("args") or {}).get("value")
            if not isinstance(val, (int, float)):
                raise ValueError(f"event #{i}: counter without numeric value")
        if ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"))
            if key[1] is None:
                raise ValueError(f"event #{i}: async {ph} without an id")
            async_balance[key] = async_balance.get(key, 0) + (1 if ph == "b" else -1)
            # nesting: segments (drain/msg) must open before they close
            # and must not double-open the same (cat, id)
            depth = async_open.get(key, 0)
            if ph == "b":
                if depth > 0:
                    raise ValueError(
                        f"event #{i}: async b for {key} opened twice "
                        f"without an intervening e"
                    )
                async_open[key] = depth + 1
            else:
                if depth <= 0:
                    raise ValueError(
                        f"event #{i}: async e for {key} closes a segment "
                        f"that was never opened"
                    )
                async_open[key] = depth - 1
        if ph in ("s", "f"):
            key = (ev.get("cat"), ev.get("id"))
            if key[1] is None:
                raise ValueError(f"event #{i}: flow {ph} without an id")
            side = flow_starts if ph == "s" else flow_finishes
            side[key] = ev.get("ts")
    unbalanced = {k: v for k, v in async_balance.items() if v != 0}
    if unbalanced:
        raise ValueError(
            f"{len(unbalanced)} async event id(s) with unbalanced b/e pairs "
            f"(first: {next(iter(unbalanced))})"
        )
    # flow arrows: every id needs both endpoints, and the arrow must not
    # point backwards in time (delivery happens before the unblocked slice)
    for key in flow_starts.keys() | flow_finishes.keys():
        s_ts = flow_starts.get(key)
        f_ts = flow_finishes.get(key)
        if s_ts is None or f_ts is None:
            missing = "f" if f_ts is None else "s"
            raise ValueError(
                f"flow id {key} is missing its {missing!r} endpoint"
            )
        if s_ts > f_ts:
            raise ValueError(
                f"flow id {key} points backwards in time "
                f"(s at {s_ts} > f at {f_ts})"
            )
    return {"n_events": len(evs), "per_phase": per_phase, "pids": sorted(pids)}
