"""repro — runtime-managed communication latency-hiding for NumPy
programs (reproduction of cs.DC 2012, grown toward a JAX/Pallas system).

The public front-end lives in :mod:`repro.api` and is re-exported here
lazily (PEP 562), so ``import repro.kernels`` or ``import repro.core``
never pays for — or cycles through — the API layer::

    import numpy as np
    import repro

    with repro.runtime(nprocs=16, block_size=64):
        a = repro.array(np.arange(65536.0).reshape(256, 256))
        b = np.exp(a) + np.sum(a, axis=0)   # plain NumPy calls, recorded
        out = np.asarray(b)                  # readback triggers the flush
"""
from __future__ import annotations

_API_EXPORTS = (
    "runtime",
    "RuntimeConfig",
    "ExecutionPolicy",
    "ServeConfig",
    "Runtime",
    "FlushTicket",
    "current_runtime",
    "ArrayFuture",
    "evaluate",
    "gather",
    "wait",
    "register_backend",
    "get_backend",
    "available_backends",
    "register_channel",
    "get_channel",
    "available_channels",
    "register_scheduler",
    "get_scheduler",
    "available_schedulers",
    "register_pass",
    "get_pass",
    "available_passes",
    "register_rule",
    "get_rule",
    "available_rules",
    "check",
    "Diagnostic",
    "AnalysisReport",
    "VerificationError",
    "VerifyStats",
    "DistArray",
    "array",
    "empty",
    "zeros",
    "ones",
    "full",
    "arange",
    "random",
    "ClusterSpec",
    "GIGE_2012",
    "TPU_V5E_ICI",
    "format_stats",
    "trace",
    "TraceCollector",
    "export_trace",
    "validate_trace",
    "attribution",
    "AttributionReport",
    "Server",
    "Session",
    "Request",
    "TenantStats",
    "AdmissionError",
    "LatencyHistogram",
)

__all__ = list(_API_EXPORTS)


def __getattr__(name):
    if name in _API_EXPORTS:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_EXPORTS))
