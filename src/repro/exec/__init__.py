"""repro.exec — asynchronous multi-worker execution backend (wall-clock
latency hiding, not simulated).

The core runtime reproduces the paper's claim on a discrete-event
simulator; this subsystem executes the *same* recorded dependency graphs
with genuine concurrency so the waiting-time metric is measured:

* :class:`AsyncExecutor` — a persistent pool of per-process worker
  threads with comm-first ready queues, sweep-based completion (batched
  per-worker handoffs under the ``"batch"`` plan pass), structural
  deadlock detection.  ``submit(deps)`` starts a drain and returns a
  :class:`Future` resolving to that drain's :class:`WaitStats` — the
  non-blocking primitive behind ``Runtime.flush(wait=False)`` and the
  demand-driven readback surface.
* :mod:`~repro.exec.channels` — non-blocking transfer channel with a
  progress engine (scratch buffers delivered while compute runs) vs. the
  synchronous blocking channel baseline.
* :class:`NumpyBackend` / :class:`JaxBackend` — pluggable compute
  backends; the JAX one jit-compiles block payloads and reuses the
  Pallas stencil kernel from ``repro.kernels``.
* :class:`WaitStats` — measured per-worker wait-for-communication
  fractions, printable next to the simulated ``TimelineResult``.

Select it per runtime: ``Runtime(..., flush_backend="async")``.
"""
from .backend import (
    AsyncExecutor,
    AutoBackend,
    ComputeBackend,
    JaxBackend,
    NumpyBackend,
    make_backend,
    run_rendezvous_bsp_async,
)
from .channels import AsyncChannel, BlockingChannel, RendezvousMailbox, make_channel
from .futures import Future
from .stats import WaitStats, WorkerStats
from .workers import Worker

__all__ = [
    "AsyncExecutor",
    "ComputeBackend",
    "NumpyBackend",
    "JaxBackend",
    "AutoBackend",
    "make_backend",
    "run_rendezvous_bsp_async",
    "AsyncChannel",
    "BlockingChannel",
    "RendezvousMailbox",
    "make_channel",
    "Future",
    "WaitStats",
    "WorkerStats",
    "Worker",
]
