"""Minimal thread-safe futures for the asynchronous executor.

``concurrent.futures.Future`` would work, but it drags in executor
machinery and its callback semantics (exceptions swallowed into the
logger) are wrong for us: a completion callback that raises must surface
as an executor failure, not vanish.  This Future is the small core the
flush executor needs — set-once result/exception, callbacks that run
exactly once (immediately when already done), and a blocking ``result``.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional

__all__ = ["Future", "FutureError"]


class FutureError(RuntimeError):
    pass


class Future:
    """Write-once container for a value produced on another thread."""

    __slots__ = ("_lock", "_event", "_result", "_exception", "_callbacks", "_done")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._callbacks: list[Callable[["Future"], None]] = []
        self._done = False

    # -- producer side ---------------------------------------------------
    def set_result(self, value: Any) -> None:
        with self._lock:
            if self._done:
                raise FutureError("future already resolved")
            self._result = value
            self._done = True
            callbacks, self._callbacks = self._callbacks, []
        self._event.set()
        for cb in callbacks:
            cb(self)

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._done:
                raise FutureError("future already resolved")
            self._exception = exc
            self._done = True
            callbacks, self._callbacks = self._callbacks, []
        self._event.set()
        for cb in callbacks:
            cb(self)

    # -- consumer side ---------------------------------------------------
    def done(self) -> bool:
        return self._done

    def exception(self) -> Optional[BaseException]:
        return self._exception

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("future not resolved within timeout")
        if self._exception is not None:
            raise self._exception
        return self._result

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        """Run ``cb(self)`` when resolved — immediately if already done.
        Callbacks run on the resolving thread; exceptions propagate to it."""
        with self._lock:
            if not self._done:
                self._callbacks.append(cb)
                return
        cb(self)
