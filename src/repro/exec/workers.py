"""Per-process worker threads with comm-first ready queues (paper §5.7,
executed on the wall clock instead of the event simulator).

Each simulated process rank gets one :class:`Worker` thread and one
private ready deque.  The scheduler invariants are preserved exactly:

* invariant 1 — an operation is enqueued only when its refcount hits
  zero (the dependency system guarantees this);
* invariant 2 — a worker always initiates every ready *communication*
  operation before touching ready computation (comm-first pop order; on
  the async channel, initiation is non-blocking so all ready transfers
  are in flight before the first compute payload runs);
* invariant 3 — a worker only blocks (goes idle) when it has neither
  ready communication nor ready computation.

Workers report wall-clock accounting into a :class:`WorkerStats` each:
compute-busy, comm-blocked (synchronous channels), and idle time.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional

from repro.core.graph import COMM, OperationNode

from .stats import WorkerStats

__all__ = ["Worker"]


class Worker(threading.Thread):
    """One simulated process: drains its own ready queue comm-first."""

    def __init__(
        self,
        rank: int,
        execute_op: Callable[[OperationNode, "Worker"], None],
        on_error: Callable[[BaseException], None],
    ):
        super().__init__(name=f"exec-worker-{rank}", daemon=True)
        self.rank = rank
        self._execute_op = execute_op
        self._on_error = on_error
        self._cv = threading.Condition()
        self._queue: deque[OperationNode] = deque()
        self._stopped = False
        self.stats = WorkerStats()

    # -- producer side (executor dispatch) --------------------------------
    def push(self, op: OperationNode) -> None:
        with self._cv:
            self._queue.append(op)
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify()

    # -- consumer side ----------------------------------------------------
    def _pop(self) -> Optional[OperationNode]:
        """Comm-first pop: any ready transfer outranks every ready compute
        (invariant 2).  Blocks while the queue is empty, accounting idle
        time; returns None on shutdown."""
        with self._cv:
            idle_from = None
            while not self._queue:
                if self._stopped:
                    return None
                if idle_from is None:
                    idle_from = time.perf_counter()
                self._cv.wait()
            if idle_from is not None:
                self.stats.idle += time.perf_counter() - idle_from
            for i, op in enumerate(self._queue):
                if op.kind == COMM:
                    del self._queue[i]
                    return op
            return self._queue.popleft()

    def run(self) -> None:
        try:
            while True:
                op = self._pop()
                if op is None:
                    return
                self._execute_op(op, self)
        except BaseException as exc:  # pragma: no cover - surfaced by executor
            self._on_error(exc)
