"""Per-process worker threads with comm-first ready queues (paper §5.7,
executed on the wall clock instead of the event simulator).

Each simulated process rank gets one :class:`Worker` thread and one
private ready deque.  The scheduler invariants hold at the dispatch
granularity:

* invariant 1 — an operation is enqueued only when its refcount hits
  zero (the dependency system guarantees this);
* invariant 2 — a worker initiates every ready *communication*
  operation before touching ready computation (comm-first pop order; on
  the async channel, initiation is non-blocking so all ready transfers
  are in flight before the first compute payload runs).  Under batched
  dispatch this holds *per batch*: a transfer that becomes ready while
  a batch is executing is initiated at the next wakeup, not mid-batch —
  the latency cost of amortizing the handoff (adaptive batch sizing is
  the ROADMAP follow-up).  Async-channel transfers are unaffected:
  they are posted by the completion sweep and never queue on workers;
* invariant 3 — a worker only blocks (goes idle) when it has neither
  ready communication nor ready computation.

Dispatch granularity is pluggable (the ``"batch"`` plan pass): with
``batch=True`` a worker drains its *entire* queue per wakeup
(comm-first within the batch) and the executor completes the whole
batch through one dependency-system sweep, amortizing the lock+event
handoff that otherwise costs ~0.1 ms per operation; with
``batch=False`` it pops one operation per wakeup — the pre-plan
baseline, kept measurable for the dispatch-overhead benchmark.

Workers report wall-clock accounting into a :class:`WorkerStats` each:
compute-busy, comm-blocked (synchronous channels), idle time, and the
number of queue wakeups.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

from repro.core.graph import COMM, OperationNode
from repro.obs import collector as _obs

from .stats import WorkerStats

__all__ = ["Worker"]


class Worker(threading.Thread):
    """One simulated process: drains its own ready queue comm-first,
    one batch (or one op, ``batch=False``) per wakeup."""

    def __init__(
        self,
        rank: int,
        execute_batch: Callable[[list[OperationNode], "Worker"], None],
        on_error: Callable[[BaseException], None],
        batch: bool = True,
    ):
        super().__init__(name=f"exec-worker-{rank}", daemon=True)
        self.rank = rank
        self._execute_batch = execute_batch
        self._on_error = on_error
        self._batch = batch
        self._cv = threading.Condition()
        self._queue: deque[OperationNode] = deque()
        self._stopped = False
        self._idle_floor = 0.0  # drain start; earlier parked time not idle
        self.stats = WorkerStats()

    # -- producer side (executor dispatch) --------------------------------
    def push_batch(self, ops: Sequence[OperationNode]) -> None:
        """Enqueue a list of ready ops with a single lock+notify — one
        handoff regardless of the batch size."""
        col = _obs.CURRENT
        with self._cv:
            self._queue.extend(ops)
            if col is not None:
                depth = len(self._queue)
                col.enqueued_many([op.uid for op in ops], self.rank, depth)
                col.counter(f"w{self.rank}.qdepth", depth)
            self._cv.notify()

    def push(self, op: OperationNode) -> None:
        self.push_batch((op,))

    def set_batch(self, batch: bool) -> None:
        """Switch dispatch granularity between drains.  The persistent
        executor calls this at submit time (no drain in flight, queue
        empty), so the flag never changes under a live batch."""
        with self._cv:
            self._batch = batch

    def drain_started(self) -> None:
        """Mark the start of a new drain: time spent parked on an empty
        queue *before* this point (the main thread recording between
        drains) must not be accounted as dependency-wait idle time."""
        with self._cv:
            self._idle_floor = time.perf_counter()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify()

    # -- consumer side ----------------------------------------------------
    def _pop_batch(self) -> Optional[list[OperationNode]]:
        """Pop the next unit of work: the whole queue (batched) or a
        single comm-first op (unbatched).  Any ready transfer outranks
        every ready compute (invariant 2).  Blocks while the queue is
        empty, accounting idle time; returns None on shutdown."""
        col = _obs.CURRENT
        with self._cv:
            idle_from = None
            while not self._queue:
                if self._stopped:
                    return None
                if idle_from is None:
                    idle_from = time.perf_counter()
                    if col is not None:
                        col.wait_start(self.rank, "empty-queue")
                self._cv.wait()
            if idle_from is not None:
                self.stats.idle += time.perf_counter() - max(
                    idle_from, self._idle_floor
                )
            self.stats.n_wakeups += 1
            if not self._batch:
                ops = None
                for i, op in enumerate(self._queue):
                    if op.kind == COMM:
                        del self._queue[i]
                        ops = [op]
                        break
                if ops is None:
                    ops = [self._queue.popleft()]
            else:
                ops = list(self._queue)
                self._queue.clear()
        if self._batch:
            ops.sort(key=lambda op: op.kind != COMM)  # comm-first, stable
        if col is not None:
            if idle_from is not None:
                col.wait_end(self.rank, "empty-queue", ops[0].uid)
            col.dequeued_many([op.uid for op in ops], self.rank)
            col.counter(f"w{self.rank}.batch", len(ops))
        return ops

    def run(self) -> None:
        try:
            while True:
                ops = self._pop_batch()
                if ops is None:
                    return
                self._execute_batch(ops, self)
        except BaseException as exc:  # pragma: no cover - surfaced by executor
            self._on_error(exc)
