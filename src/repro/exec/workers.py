"""Per-process worker threads with comm-first ready queues (paper §5.7,
executed on the wall clock instead of the event simulator).

Each simulated process rank gets one :class:`Worker` thread and one
private ready deque.  The scheduler invariants hold at the dispatch
granularity:

* invariant 1 — an operation is enqueued only when its refcount hits
  zero (the dependency system guarantees this);
* invariant 2 — a worker initiates every ready *communication*
  operation before touching ready computation (comm-first pop order; on
  the async channel, initiation is non-blocking so all ready transfers
  are in flight before the first compute payload runs).  Under batched
  dispatch this holds *per batch*: a transfer that becomes ready while
  a batch is executing is initiated at the next wakeup, not mid-batch —
  the latency cost of amortizing the handoff (adaptive batch sizing is
  the ROADMAP follow-up).  Async-channel transfers are unaffected:
  they are posted by the completion sweep and never queue on workers;
* invariant 3 — a worker only blocks (goes idle) when it has neither
  ready communication nor ready computation *and* there is nothing
  worth stealing from a loaded peer.

Dispatch granularity is pluggable (the ``"batch"`` plan pass): with
``batch=True`` a worker drains its *entire* queue per wakeup
(comm-first within the batch) and the executor completes the whole
batch through one dependency-system sweep, amortizing the lock+event
handoff that otherwise costs ~0.1 ms per operation; with
``batch=False`` it pops one operation per wakeup — the pre-plan
baseline, kept measurable for the dispatch-overhead benchmark.

Work stealing (arXiv 1805.01768 regime — steal latency vs. task
granularity): a worker whose own queue is empty asks the executor's
steal policy (``steal_fn``) for work before parking.  The victim's
queue is popped from the *tail* under the victim's own lock
(:meth:`Worker.steal_from`), preserving the victim's program-order
head; the stolen batch is re-sorted comm-first by the thief, so
invariant 2 holds per executed batch on both sides.  This is safe for
bit-identical results because two simultaneously-*ready* operations are
never conflicting (invariant 1): any interleaving of ready ops executes
the same payloads against disjoint data.

Workers report wall-clock accounting into a :class:`WorkerStats` each:
compute-busy, comm-blocked (synchronous channels), idle time, the
number of queue wakeups, and steal counters.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

from repro.core.graph import COMM, OperationNode
from repro.obs import collector as _obs

from .stats import WorkerStats

__all__ = ["Worker"]


class Worker(threading.Thread):
    """One simulated process: drains its own ready queue comm-first,
    one batch (or one op, ``batch=False``) per wakeup; steals from
    loaded peers before parking when the executor provides a policy."""

    def __init__(
        self,
        rank: int,
        execute_batch: Callable[[list[OperationNode], "Worker"], None],
        on_error: Callable[[BaseException], None],
        batch: bool = True,
        steal_fn: Optional[Callable[["Worker"], Optional[list]]] = None,
    ):
        super().__init__(name=f"exec-worker-{rank}", daemon=True)
        self.rank = rank
        self._execute_batch = execute_batch
        self._on_error = on_error
        self._batch = batch
        self._steal_fn = steal_fn
        self._cv = threading.Condition()
        self._queue: deque[OperationNode] = deque()
        self._stopped = False
        self._idle_floor = 0.0  # drain start; earlier parked time not idle
        # bumped under _cv by every wake source (push/wake/stop): a thief
        # re-checks it after a failed steal attempt so a wake that fired
        # *during* the attempt is never lost (no polling timeout needed)
        self._wake_seq = 0
        self.stats = WorkerStats()

    # -- producer side (executor dispatch) --------------------------------
    def push_batch(self, ops: Sequence[OperationNode]) -> None:
        """Enqueue a list of ready ops with a single lock+notify — one
        handoff regardless of the batch size."""
        col = _obs.CURRENT
        with self._cv:
            self._queue.extend(ops)
            self._wake_seq += 1
            if col is not None:
                depth = len(self._queue)
                col.enqueued_many([op.uid for op in ops], self.rank, depth)
                col.counter(f"w{self.rank}.qdepth", depth)
            self._cv.notify()

    def push(self, op: OperationNode) -> None:
        self.push_batch((op,))

    def set_batch(self, batch: bool) -> None:
        """Switch dispatch granularity between drains.  The persistent
        executor calls this at submit time (no drain in flight, queue
        empty), so the flag never changes under a live batch."""
        with self._cv:
            self._batch = batch

    def drain_started(self) -> None:
        """Mark the start of a new drain: time spent parked on an empty
        queue *before* this point (the main thread recording between
        drains) must not be accounted as dependency-wait idle time."""
        with self._cv:
            self._idle_floor = time.perf_counter()

    def wake(self) -> None:
        """Nudge a parked worker to re-run its steal policy (called by
        the executor after dispatching a batch heavy enough to steal
        from)."""
        with self._cv:
            self._wake_seq += 1
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._wake_seq += 1
            self._cv.notify()

    # -- victim side of stealing ------------------------------------------
    def qlen(self) -> int:
        """Racy queue-length read — a heuristic input for victim
        selection, never a correctness decision."""
        return len(self._queue)

    def steal_from(self, n: int) -> list[OperationNode]:
        """Pop up to ``n`` ops from the *tail* of this worker's queue
        (always leaving at least one — the victim was woken for it).
        Tail-stealing keeps the victim's head untouched: its comm-first
        program-order prefix is what it pops next.  Returns the stolen
        ops in their original queue order."""
        with self._cv:
            n = min(n, len(self._queue) - 1)
            if n <= 0:
                return []
            stolen = [self._queue.pop() for _ in range(n)]
        stolen.reverse()
        return stolen

    def discard(self, pred: Callable[[OperationNode], bool]) -> int:
        """Drop queued ops matching ``pred`` (a failed drain's leftovers
        must not execute against re-planned state); returns the count."""
        with self._cv:
            before = len(self._queue)
            self._queue = deque(op for op in self._queue if not pred(op))
            return before - len(self._queue)

    # -- consumer side ----------------------------------------------------
    def _pop_locked(self) -> list[OperationNode]:
        """Pop the next unit of work from the (non-empty) own queue —
        the whole queue (batched) or a single comm-first op (unbatched).
        Caller holds ``_cv``."""
        if not self._batch:
            for i, op in enumerate(self._queue):
                if op.kind == COMM:
                    del self._queue[i]
                    return [op]
            return [self._queue.popleft()]
        ops = list(self._queue)
        self._queue.clear()
        return ops

    def _pop_batch(self) -> Optional[list[OperationNode]]:
        """Pop the next unit of work: own queue first, then a steal
        attempt, then park.  Any ready transfer outranks every ready
        compute within the popped batch (invariant 2).  Blocks while
        there is nothing to do, accounting idle time; returns None on
        shutdown."""
        col = _obs.CURRENT
        idle_from = None
        stolen = False
        while True:
            with self._cv:
                if self._queue:
                    ops = self._pop_locked()
                    break
                if self._stopped:
                    return None
                if idle_from is None:
                    idle_from = time.perf_counter()
                    if col is not None:
                        col.wait_start(self.rank, "empty-queue")
                seq = self._wake_seq
            # own queue empty — run the steal policy OUTSIDE our lock
            # (it takes the victim's lock; holding both would order them)
            if self._steal_fn is not None:
                got = self._steal_fn(self)
                if got:
                    ops = got
                    stolen = True
                    break
            with self._cv:
                if not self._queue and not self._stopped and self._wake_seq == seq:
                    self._cv.wait()
        if idle_from is not None:
            self.stats.idle += time.perf_counter() - max(
                idle_from, self._idle_floor
            )
        self.stats.n_wakeups += 1
        if stolen:
            self.stats.n_steals += 1
            self.stats.n_stolen += len(ops)
            # bin the steal into each op's own drain too: overlapped
            # drains report drain.procs (per-op accounting), not the
            # worker-stats lifetime delta a solo drain reports, and the
            # rebalance must stay visible per tenant
            seen_drains = set()
            for op in ops:
                dstats = op._drain.procs[self.rank]
                dstats.n_stolen += 1
                if id(op._drain) not in seen_drains:
                    seen_drains.add(id(op._drain))
                    dstats.n_steals += 1
        if self._batch or stolen:
            ops.sort(key=lambda op: op.kind != COMM)  # comm-first, stable
        if col is not None:
            if idle_from is not None:
                col.wait_end(self.rank, "empty-queue", ops[0].uid)
            col.dequeued_many([op.uid for op in ops], self.rank)
            col.counter(f"w{self.rank}.batch", len(ops))
            if stolen:
                col.counter(f"w{self.rank}.stolen", len(ops))
        return ops

    def run(self) -> None:
        try:
            while True:
                ops = self._pop_batch()
                if ops is None:
                    return
                self._execute_batch(ops, self)
        except BaseException as exc:  # pragma: no cover - surfaced by executor
            self._on_error(exc)
