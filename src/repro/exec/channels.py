"""Transfer channels: the communication substrate of the async executor.

Two interchangeable channel disciplines, mirroring the paper's two
measurement setups on the wall clock:

* :class:`AsyncChannel` — non-blocking.  ``post`` hands the transfer to a
  *progress engine* (dedicated threads playing the role of MPI's
  ``MPI_Testsome`` progress loop / the NIC DMA engine) and returns a
  :class:`~repro.exec.futures.Future` immediately, so the posting worker
  goes straight back to ready computation.  The scratch buffer is
  delivered — and the consumer refcounts decremented — from the progress
  thread via the future's done-callback.
* :class:`BlockingChannel` — synchronous.  ``post`` performs the copy (and
  the simulated wire latency, if any) inline on the calling worker
  thread; the elapsed time is accounted as communication *waiting* by the
  worker, reproducing the paper's blocking baseline.

Both accept an optional ``latency`` (seconds per message): a real sleep
standing in for wire latency on a single machine, so overlap is
measurable even when the memcpy itself is fast.  The async engine sleeps
on its own threads (latency hidden); the blocking channel sleeps on the
worker (latency exposed).

:class:`RendezvousMailbox` implements two-sided rendezvous matching for
the BSP runner (`repro.exec.backend.run_rendezvous_bsp_async`) — the
messaging discipline whose fig. 6 deadlock motivates the paper's
one-sided flush algorithm.  Its deadlock detection is deterministic: when
every live rank is parked on an unmatched send/recv, no progress is
possible and the mailbox trips.
"""
from __future__ import annotations

import heapq
import threading
import time
from typing import Callable, Optional

from repro.api.registry import get_channel, register_channel
from repro.obs import collector as _obs

from .futures import Future

__all__ = ["AsyncChannel", "BlockingChannel", "RendezvousMailbox", "make_channel"]

# execute_fn: callable(op) that performs the actual data movement
TransferFn = Callable[[object], None]


class AsyncChannel:
    """Non-blocking channel backed by a deadline-heap progress engine.

    Wire latency is *pipelined*, exactly as in the α–β cluster model: the
    delivery deadline is stamped when the message is posted (``now +
    latency``), so a thousand in-flight messages overlap their latencies
    instead of serializing them.  Only the actual data movement (the
    memcpy into the scratch buffer — the NIC-occupancy analogue)
    serializes on the progress threads."""

    blocking = False
    trace_name = "async"

    def __init__(self, progress_threads: int = 2, latency: float = 0.0):
        self.latency = latency
        self._cv = threading.Condition()
        self._heap: list = []  # (due, seq, op, execute, fut)
        self._seq = 0
        self._stopped = False
        self._threads = [
            threading.Thread(
                target=self._progress_loop, name=f"progress-{i}", daemon=True
            )
            for i in range(max(1, progress_threads))
        ]
        self.n_posted = 0
        self.n_delivered = 0
        for t in self._threads:
            t.start()

    def post(self, op, execute: TransferFn) -> Future:
        """Initiate a transfer; returns immediately with its future."""
        fut = Future()
        due = time.monotonic() + self.latency
        col = _obs.CURRENT
        with self._cv:
            self.n_posted += 1
            heapq.heappush(self._heap, (due, self._seq, op, execute, fut))
            self._seq += 1
            if col is not None:
                col.msg_posted(op, self.trace_name)
                col.counter("msgs-inflight", self.n_posted - self.n_delivered)
            self._cv.notify()
        return fut

    def post_many(self, items) -> list[Future]:
        """Initiate a batch of transfers — ``items`` is a sequence of
        ``(op, execute)`` pairs — with a single lock acquisition and
        one progress-engine wakeup, the channel-side analogue of the
        batched worker handoff."""
        futs = []
        due = time.monotonic() + self.latency
        col = _obs.CURRENT
        with self._cv:
            for op, execute in items:
                fut = Future()
                self.n_posted += 1
                heapq.heappush(self._heap, (due, self._seq, op, execute, fut))
                self._seq += 1
                if col is not None:
                    col.msg_posted(op, self.trace_name)
                futs.append(fut)
            if col is not None and items:
                col.counter("msgs-inflight", self.n_posted - self.n_delivered)
            self._cv.notify_all()
        return futs

    def _progress_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._stopped:
                        return
                    if self._heap:
                        due = self._heap[0][0]
                        now = time.monotonic()
                        if due <= now:
                            _, _, op, execute, fut = heapq.heappop(self._heap)
                            break
                        self._cv.wait(timeout=due - now)
                    else:
                        self._cv.wait()
            col = _obs.CURRENT
            if col is not None:
                col.msg_progressed(op.uid, self.trace_name)
            try:
                execute(op)
            except BaseException as exc:  # surface through the future
                fut.set_exception(exc)
                continue
            with self._cv:
                self.n_delivered += 1
                if col is not None:
                    col.msg_delivered(op.uid, self.trace_name)
                    col.counter("msgs-inflight", self.n_posted - self.n_delivered)
            fut.set_result(op)

    def close(self) -> None:
        """Stop the progress threads; double-close is a no-op."""
        with self._cv:
            if self._stopped:
                return
            self._stopped = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)


class BlockingChannel:
    """Synchronous channel: the transfer happens on the caller's thread."""

    blocking = True
    trace_name = "blocking"

    def __init__(self, latency: float = 0.0):
        self.latency = latency
        self._count_lock = threading.Lock()  # posts come from all workers
        self.n_posted = 0
        self.n_delivered = 0

    def post(self, op, execute: TransferFn) -> Future:
        fut = Future()
        col = _obs.CURRENT
        with self._count_lock:
            self.n_posted += 1
        if col is not None:
            col.msg_posted(op, self.trace_name)
            col.msg_progressed(op.uid, self.trace_name)
        try:
            if self.latency > 0.0:
                time.sleep(self.latency)
            execute(op)
        except BaseException as exc:
            fut.set_exception(exc)
            return fut
        with self._count_lock:
            self.n_delivered += 1
        if col is not None:
            col.msg_delivered(op.uid, self.trace_name)
        fut.set_result(op)
        return fut

    def post_many(self, items) -> list[Future]:
        """Synchronous batch post: transfers execute inline, in order."""
        return [self.post(op, execute) for op, execute in items]

    def close(self) -> None:
        pass


# Registry entries take the full keyword set; disciplines that don't use
# a knob (the blocking channel has no progress engine) ignore it, so one
# factory signature covers every transport — including the ROADMAP's
# future multi-host channels.
register_channel(
    "async",
    lambda *, latency=0.0, progress_threads=2: AsyncChannel(
        progress_threads=progress_threads, latency=latency
    ),
)
register_channel(
    "blocking",
    lambda *, latency=0.0, progress_threads=2: BlockingChannel(latency=latency),
)


def make_channel(name, *, latency: float = 0.0, progress_threads: int = 2):
    """Resolve a transfer channel through the plugin registry (an
    already-built — possibly shared — channel passes through)."""
    if not isinstance(name, str):
        return name
    return get_channel(name)(latency=latency, progress_threads=progress_threads)


# ---------------------------------------------------------------------------
# Two-sided rendezvous messaging (fig. 6 reproduction substrate)
# ---------------------------------------------------------------------------


class RendezvousDeadlock(Exception):
    """Internal signal: every live rank is parked on an unmatched message."""

    def __init__(self, stuck: list[dict]):
        self.stuck = stuck
        super().__init__(f"{len(stuck)} ranks parked with no matching partner")


class RendezvousMailbox:
    """Two-sided tag matching with rendezvous semantics and deterministic
    deadlock detection.

    A ``send(rank, peer, tag)`` completes only when ``peer`` posts the
    matching ``recv(peer, rank, tag)`` (and vice versa).  Each rank may be
    parked on at most one operation (BSP in-order execution).  When every
    live rank is parked and no pair matches, the mailbox raises
    :class:`RendezvousDeadlock` on *all* parked ranks — there is no
    timeout involved, the stall is detected structurally.
    """

    def __init__(self, nranks: int):
        self.nranks = nranks
        self._cv = threading.Condition()
        # rank -> {"kind", "peer", "tag", "step"} while parked
        self._parked: dict[int, dict] = {}
        self._matched: set[int] = set()
        self._done: set[int] = set()
        self._dead: Optional[list[dict]] = None

    def _match_of(self, rank: int) -> Optional[int]:
        mine = self._parked[rank]
        want = "recv" if mine["kind"] == "send" else "send"
        peer = mine["peer"]
        theirs = self._parked.get(peer)
        if (
            theirs is not None
            and peer not in self._matched
            and theirs["kind"] == want
            and theirs["peer"] == rank
            and theirs["tag"] == mine["tag"]
        ):
            return peer
        return None

    def _check_stall(self) -> None:
        # all live (not-done) ranks parked and unmatched -> global stall
        live = self.nranks - len(self._done)
        if live == 0 or len(self._parked) < live:
            return
        for r in self._parked:
            if r not in self._matched and self._match_of(r) is not None:
                return
        if any(r in self._matched for r in self._parked):
            return  # someone is about to leave; progress still possible
        self._dead = [dict(rank=r, **op) for r, op in sorted(self._parked.items())]
        self._cv.notify_all()

    def transact(self, rank: int, kind: str, peer: int, tag, step: int) -> None:
        """Post a send or recv and block until it rendezvouses."""
        with self._cv:
            if self._dead is not None:
                raise RendezvousDeadlock(self._dead)
            self._parked[rank] = dict(kind=kind, peer=peer, tag=tag, step=step)
            partner = self._match_of(rank)
            if partner is not None:
                # complete both sides of the rendezvous
                self._matched.add(rank)
                self._matched.add(partner)
                self._cv.notify_all()
            while rank not in self._matched:
                if self._dead is not None:
                    del self._parked[rank]
                    raise RendezvousDeadlock(self._dead)
                self._check_stall()
                self._cv.wait(timeout=0.05)
            del self._parked[rank]
            self._matched.discard(rank)
            self._cv.notify_all()

    def finish(self, rank: int) -> None:
        with self._cv:
            self._done.add(rank)
            self._check_stall()
            self._cv.notify_all()
