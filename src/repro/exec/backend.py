"""Asynchronous flush executor and pluggable compute backends.

:class:`AsyncExecutor` drains a recorded
:class:`~repro.core.graph.DependencySystem` with genuine concurrency —
the wall-clock counterpart of ``repro.core.scheduler.run_schedule``:

* one :class:`~repro.exec.workers.Worker` thread per simulated process,
  each with a private comm-first ready queue;
* transfers go through a :mod:`~repro.exec.channels` discipline — the
  non-blocking :class:`AsyncChannel` progress engine delivers scratch
  buffers while compute runs, the :class:`BlockingChannel` reproduces the
  synchronous baseline on the worker's own clock;
* completion is sweep-based: a finished worker batch (or a channel
  future's done-callback) performs the refcount decrements
  (``deps.complete``) and dispatches newly-ready operations — the
  graph's ``on_ready`` hook delivers them straight to worker queues,
  no central scheduler loop.  Under the ``"batch"`` plan pass the
  sweep moves per-worker *lists* per lock round trip
  (``batch_dispatch=True``), amortizing the Python handoff overhead;
* the numerical result is bit-identical to the simulated executor's: the
  dependency system totally orders every pair of conflicting accesses, so
  any schedule that respects it interprets the payloads (shared
  ``repro.core.engine.execute_payload``) into the same block contents.

Deadlock is detected structurally, not by timeout: when nothing is in
flight and the dependency system still has pending operations, no future
can ever resolve — the executor raises
:class:`~repro.core.scheduler.DeadlockError` listing the stuck
operation-nodes.  :func:`run_rendezvous_bsp_async` applies the same
treatment to the paper's fig. 6 schedule executed with real threads and
two-sided rendezvous messaging.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Optional

import numpy as np

from repro.api.registry import get_backend, register_backend
from repro.core.engine import MapPayload, MatmulPayload, execute_payload, resolve_ref
from repro.core.graph import COMM, DependencySystem, OperationNode
from repro.core.scheduler import DeadlockError, format_stuck_ops
from repro.obs import collector as _obs

from .channels import RendezvousDeadlock, RendezvousMailbox, make_channel
from .futures import Future
from .stats import WaitStats, WorkerStats
from .workers import Worker

__all__ = [
    "ComputeBackend",
    "NumpyBackend",
    "JaxBackend",
    "AutoBackend",
    "make_backend",
    "AsyncExecutor",
    "run_rendezvous_bsp_async",
]


# ---------------------------------------------------------------------------
# Compute backends
# ---------------------------------------------------------------------------


class ComputeBackend:
    """Executes operation payloads against the runtime's block storage."""

    name = "abstract"

    def __init__(self, storage: dict, scratch: dict):
        self.storage = storage
        self.scratch = scratch

    def execute(self, op: OperationNode) -> None:
        raise NotImplementedError


class NumpyBackend(ComputeBackend):
    """Eager NumPy interpretation — the reference backend (bit-identical
    to the simulated executor by construction)."""

    name = "numpy"

    def execute(self, op: OperationNode) -> None:
        execute_payload(op.payload, self.storage, self.scratch)


class JaxBackend(ComputeBackend):
    """jit-compiles block payloads with XLA.

    * Elementwise map payloads (including fused expression trees, via
      ``UFunc.tree``) are retraced with ``jax.numpy`` primitives and
      cached per (ufunc, signature).
    * Fused 5-point stencil payloads are routed through the Pallas
      ``stencil5_block`` kernel from ``repro.kernels.stencil`` (interpret
      mode on CPU, compiled on TPU).
    * Matmul payloads run through a jitted ``jnp.dot``.
    * Everything else (transfers, reductions, fills) falls back to the
      NumPy interpreter — those are memory movement, not FLOPs.

    Note: without ``jax_enable_x64`` the payloads compute in float32, so
    results are *numerically close*, not bit-identical, to the NumPy
    backend on float64 programs.
    """

    name = "jax"

    def __init__(self, storage: dict, scratch: dict):
        super().__init__(storage, scratch)
        import jax  # the container bakes in the jax toolchain
        import jax.numpy as jnp

        self._jax = jax
        self._jnp = jnp
        self._x64 = bool(jax.config.read("jax_enable_x64"))
        self._impls = {
            "identity": lambda x: x,
            "add": jnp.add,
            "subtract": jnp.subtract,
            "multiply": jnp.multiply,
            "divide": jnp.divide,
            "power": jnp.power,
            "negative": jnp.negative,
            "absolute": jnp.abs,
            "exp": jnp.exp,
            "log": jnp.log,
            "sqrt": jnp.sqrt,
            "square": jnp.square,
            "maximum": jnp.maximum,
            "minimum": jnp.minimum,
            # comparisons carry a real bool result dtype (UFunc.out_dtype),
            # matching NumPy — no float cast
            "greater": jnp.greater,
            "less": jnp.less,
            "where": jnp.where,
        }
        self._jit_cache: dict = {}
        self._untranslatable: set = set()  # (name, tree_key) with no jnp form
        # interpret the Pallas kernel everywhere but on a real TPU
        self._interpret = jax.default_backend() != "tpu"
        try:
            from repro.kernels.stencil import stencil5_block

            self._stencil5 = stencil5_block
        except Exception:  # pragma: no cover - kernels unavailable
            self._stencil5 = None

    # -- helpers ---------------------------------------------------------
    def _to_device(self, x):
        jnp = self._jnp
        if isinstance(x, np.ndarray) and not self._x64:
            if x.dtype == np.float64:
                return jnp.asarray(x, dtype=jnp.float32)
            if x.dtype == np.int64:
                return jnp.asarray(x, dtype=jnp.int32)
        return jnp.asarray(x)

    def _impl_of(self, u) -> Optional[object]:
        return self._impls.get(u.name)

    def _trace_ufunc(self, ufunc):
        """Build a jnp callable for a primitive or fused ufunc; None if a
        primitive inside has no jnp translation."""
        from repro.core.ufunc import eval_tree

        if ufunc.tree is not None:
            missing = []

            def impl(u):
                f = self._impl_of(u)
                if f is None:
                    missing.append(u.name)
                    return u.fn
                return f

            # dry-walk the tree for translatability (leaves unevaluated)
            def walk(spec):
                if spec[0] in ("leaf", "const"):
                    return
                f, subs = spec
                impl(f)
                for s in subs:
                    walk(s)

            walk(ufunc.tree)
            if missing:
                return None
            return lambda *arrays: eval_tree(ufunc.tree, arrays, self._impl_of)
        f = self._impl_of(ufunc)
        return None if f is None else (lambda *arrays: f(*arrays))

    @staticmethod
    def _stencil5_weight(tree) -> Optional[float]:
        """Match ``w * ((((x0+x1)+x2)+x3)+x4)`` — the fused 5-point
        stencil sweep — returning the weight, else None."""
        if not (isinstance(tree, tuple) and len(tree) == 2):
            return None
        f, subs = tree
        if getattr(f, "name", None) != "multiply" or len(subs) != 2:
            return None
        const, chain = subs
        if const[0] != "const":
            const, chain = chain, const
        if const[0] != "const":
            return None
        expect = 4
        while isinstance(chain, tuple) and len(chain) == 2 and getattr(
            chain[0], "name", None
        ) == "add":
            _, (left, right) = chain
            if right != ("leaf", expect):
                return None
            expect -= 1
            chain = left
        if chain != ("leaf", 0) or expect != 0:
            return None
        return float(const[1])

    # -- execution -------------------------------------------------------
    def execute(self, op: OperationNode) -> None:
        p = op.payload
        if isinstance(p, MapPayload):
            if self._exec_map(p):
                return
        elif isinstance(p, MatmulPayload):
            self._exec_matmul(p)
            return
        execute_payload(p, self.storage, self.scratch)

    def _exec_map(self, p: MapPayload) -> bool:
        ukey = (p.ufunc.name, self._tree_key(p.ufunc.tree))
        if ukey in self._untranslatable:
            return False  # known fallback: skip resolving refs twice
        args = [resolve_ref(r, self.storage, self.scratch) for r in p.args]
        arr_idx = [i for i, r in enumerate(p.args) if r[0] != "c"]
        # Pallas fast path: fused 5-point stencil block sweep
        if (
            self._stencil5 is not None
            and p.ufunc.tree is not None
            and len(arr_idx) == 5
            and all(getattr(args[i], "ndim", 0) == 2 for i in arr_idx)
            and len({args[i].shape for i in arr_idx}) == 1
        ):
            w = self._stencil5_weight(p.ufunc.tree)
            if w is not None:
                xs = [self._to_device(np.ascontiguousarray(args[i])) for i in arr_idx]
                res = self._stencil5(*xs, weight=w, interpret=self._interpret)
                self._store(p, np.asarray(res))
                return True
        fn = self._cached_jit(p, args, arr_idx)
        if fn is None:
            self._untranslatable.add(ukey)
            return False
        dev_args = list(args)
        for i in arr_idx:
            dev_args[i] = self._to_device(np.ascontiguousarray(args[i]))
        self._store(p, np.asarray(fn(*dev_args)))
        return True

    @staticmethod
    def _tree_key(spec):
        """Structural signature of an expression tree: two independently
        built but identical fused expressions must share one jit entry
        (keying on object identity would recompile per materialize and
        pin dead closures in the cache forever)."""
        if spec is None:
            return None
        tag = spec[0]
        if tag in ("leaf", "const"):
            return spec
        f, subs = spec
        return (f.name, tuple(JaxBackend._tree_key(s) for s in subs))

    def _cached_jit(self, p: MapPayload, args, arr_idx):
        sig = tuple(
            (args[i].shape, str(args[i].dtype)) if i in arr_idx else ("c",)
            for i in range(len(args))
        )
        key = (p.ufunc.name, self._tree_key(p.ufunc.tree), sig)
        fn = self._jit_cache.get(key)
        if fn is None and key not in self._jit_cache:
            traced = self._trace_ufunc(p.ufunc)
            fn = None if traced is None else self._jax.jit(traced)
            self._jit_cache[key] = fn
        return fn

    def _exec_matmul(self, p: MatmulPayload) -> None:
        jnp = self._jnp
        a = resolve_ref(p.a, self.storage, self.scratch)
        b = resolve_ref(p.b, self.storage, self.scratch)
        if p.trans_a:
            a = a.T
        if p.trans_b:
            b = b.T
        key = ("mm", a.shape, b.shape, str(a.dtype), str(b.dtype))
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jax.jit(lambda x, y: jnp.dot(x, y))
            self._jit_cache[key] = fn
        val = np.asarray(fn(self._to_device(np.ascontiguousarray(a)),
                            self._to_device(np.ascontiguousarray(b))))
        blk = self.storage[(p.out_base, p.out_frag.block)]
        if p.init:
            blk[p.out_frag.slices] = val
        else:
            blk[p.out_frag.slices] += val

    def _store(self, p: MapPayload, res: np.ndarray) -> None:
        blk = self.storage[(p.out_base, p.out_frag.block)]
        blk[p.out_frag.slices] = res


class AutoBackend(ComputeBackend):
    """Per-payload backend choice — the first registry client beyond the
    two reference backends (ROADMAP "backend autotuning").

    Small block payloads stay on the eager NumPy interpreter (XLA
    dispatch + host↔device staging costs more than the arithmetic);
    payloads whose estimated per-element work clears ``threshold`` go to
    the jit-compiling :class:`JaxBackend` (including its Pallas stencil
    fast path).  The score is ``out_elements × ufunc cost`` for maps and
    output elements for matmuls — the same per-element weights the
    timeline model uses, so the choice needs no calibration run.  The
    JAX backend is built lazily on the first heavy payload and the
    choice is a pure function of the payload, so repeated drains of the
    same graph route identically (results stay deterministic across
    channel disciplines).
    """

    name = "auto"

    # default: a 128×128 float64 block of cost-4 (transcendental) work
    # clears it, a cost-1 copy/add block does not
    DEFAULT_THRESHOLD = 48_000

    def __init__(self, storage: dict, scratch: dict, threshold: int = DEFAULT_THRESHOLD):
        super().__init__(storage, scratch)
        self.threshold = threshold
        self._numpy = NumpyBackend(storage, scratch)
        self._jax: Optional[JaxBackend] = None
        self._jax_unavailable = False
        self.n_numpy = 0
        self.n_jax = 0

    def _jax_backend(self) -> Optional[JaxBackend]:
        if self._jax is None and not self._jax_unavailable:
            try:
                self._jax = JaxBackend(self.storage, self.scratch)
            except ImportError as exc:  # no usable jax: degrade to NumPy
                self._jax_unavailable = True
                import warnings

                warnings.warn(
                    f"backend='auto': jax unavailable ({exc}); all payloads "
                    f"will run on the NumPy interpreter",
                    RuntimeWarning,
                    stacklevel=3,
                )
        return self._jax

    def _score(self, p) -> float:
        if isinstance(p, MapPayload):
            return p.out_frag.size * max(1.0, p.ufunc.cost)
        if isinstance(p, MatmulPayload):
            return float(p.out_frag.size)
        return 0.0  # transfers/reductions/fills: memory movement, stay eager

    def execute(self, op: OperationNode) -> None:
        if self._score(op.payload) >= self.threshold:
            jb = self._jax_backend()
            if jb is not None:
                self.n_jax += 1
                jb.execute(op)
                return
        self.n_numpy += 1
        self._numpy.execute(op)


register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)
register_backend("auto", AutoBackend)


def make_backend(name, storage: dict, scratch: dict) -> ComputeBackend:
    """Resolve a compute backend through the plugin registry (an
    already-built instance passes through)."""
    if isinstance(name, ComputeBackend):
        return name
    return get_backend(name)(storage, scratch)


# ---------------------------------------------------------------------------
# The asynchronous executor
# ---------------------------------------------------------------------------


class _Drain:
    """Bookkeeping for one in-flight drain on the shared pool.

    Every pending op is stamped with its owning drain at submit time
    (``op._drain``), so completion sweeps, per-drain stat accounting and
    failure cleanup can route mixed worker batches back to the right
    drain without a global registry lookup per op."""

    __slots__ = (
        "deps", "fut", "tag", "inflight", "ready_batch", "prev_hook",
        "t0", "snap", "solo", "finished", "procs",
        "comm_bytes", "n_comm_ops", "n_compute_ops", "n_handoffs",
        "n_messages",
    )

    def __init__(self, deps: DependencySystem, tag, nworkers: int):
        self.deps = deps
        self.fut = Future()
        self.tag = tag
        self.inflight = 0
        self.ready_batch: list[OperationNode] = []
        self.prev_hook = None
        self.t0 = 0.0
        self.snap: Optional[dict] = None
        # True while this drain has had the pool to itself for its whole
        # lifetime: its stats can then be the exact lifetime-delta the
        # serialized executor reported (including worker idle time)
        self.solo = True
        self.finished = False
        self.procs = [WorkerStats() for _ in range(nworkers)]
        self.comm_bytes = 0
        self.n_comm_ops = 0
        self.n_compute_ops = 0
        self.n_handoffs = 0
        self.n_messages = 0


class AsyncExecutor:
    """Drains DependencySystems on a persistent work-stealing worker
    pool + transfer channels.

    The executor is *persistent*: :meth:`submit` hands it a recorded
    graph (typically one dependency cone of a demand-driven flush) and
    returns a :class:`~repro.exec.futures.Future` that resolves — from
    the completing worker/progress thread — with that drain's
    :class:`WaitStats`.  The submitting thread keeps running (recording
    more operations) while the drain proceeds, and **multiple drains
    may be in flight concurrently**: each drain carries its own
    dependency system, in-flight counter and per-worker accounting, and
    completion sweeps route mixed batches back per drain.  The caller
    is responsible for only submitting graphs whose access footprints
    don't conflict with in-flight drains (``Runtime.flush`` joins
    conflicting tickets first — see ``repro.core.graph.cones_conflict``);
    ops *within* one submitted graph are ordered by its dependency
    system as always.  :meth:`run` is the blocking convenience
    (``submit().result()``).

    Work stealing: a worker whose queue runs dry asks :meth:`_steal_for`
    for work before parking.  Victim selection is longest-queue-first
    gated by the latency-aware threshold of arXiv 1805.01768 — steal
    only when the victim holds at least ``steal_threshold`` ops *and*
    the expected work moved (half the victim's queue × the EWMA task
    grain) exceeds ``steal_latency``, the measured cost of a steal
    round trip.  Otherwise a slow cone's tail would be diced into
    steals that cost more than they move.

    With ``batch_dispatch=True`` (set by the ``"batch"`` plan pass) the
    completion sweep groups newly-ready compute ops per worker and
    pushes each group with one lock+notify, workers drain their whole
    queue per wakeup, and a finished batch is completed through a
    single dependency-system sweep — the handoff count drops from one
    per operation to one per batch (``WaitStats.n_handoffs``)."""

    def __init__(
        self,
        nworkers: int,
        storage: dict,
        scratch: dict,
        backend: str = "numpy",
        channel: str = "async",
        latency: float = 0.0,
        progress_threads: int = 2,
        batch_dispatch: bool = False,
        steal: bool = True,
        steal_threshold: int = 4,
        steal_latency: float = 1e-4,
    ):
        self.nworkers = nworkers
        self.backend = make_backend(backend, storage, scratch)
        # a channel instance may be shared across flushes (the owner closes
        # it); a name means this executor owns the channel's lifecycle
        self._owns_channel = isinstance(channel, str)
        self.channel = make_channel(
            channel, latency=latency, progress_threads=progress_threads
        )
        self.mode = "blocking-channel" if self.channel.blocking else "async"
        self.batch_dispatch = batch_dispatch
        self.steal = steal and nworkers > 1
        self.steal_threshold = max(2, steal_threshold)
        self.steal_latency = max(0.0, steal_latency)
        # EWMA of per-op compute grain (seconds) — the τ in the 1805.01768
        # gate "move only if n·τ ≥ steal latency".  Starts at the steal
        # latency so the first steals are allowed until measured.
        self._grain_ewma = max(self.steal_latency, 1e-6)
        self.workers = [
            Worker(
                r,
                self._run_batch,
                self._record_error,
                batch=batch_dispatch,
                steal_fn=self._steal_for if self.steal else None,
            )
            for r in range(nworkers)
        ]
        self._glock = threading.Lock()  # guards drains + counters
        self._drains: dict[int, _Drain] = {}  # id(drain) -> drain
        self._anon_tags = itertools.count()
        self._error: Optional[BaseException] = None
        self._workers_started = False
        self._closed = False
        # lifetime totals (executor introspection; per-drain stats are
        # accounted per-op on each _Drain)
        self.comm_bytes = 0
        self.n_comm_ops = 0
        self.n_compute_ops = 0
        self.n_handoffs = 0

    # -- error paths -------------------------------------------------------
    def _record_error(self, exc: BaseException) -> None:
        """Pool-level failure (worker thread death, internal error): the
        pool is no longer trustworthy — poison it and fail every active
        drain."""
        with self._glock:
            if self._error is None:
                self._error = exc
            drains = list(self._drains.values())
        for d in drains:
            self._finish_drain(d, exc)

    def _fail_drain(self, drain: _Drain, exc: BaseException) -> None:
        """Per-op failure: only the owning drain dies; the pool (and any
        concurrent drains) keeps running."""
        self._finish_drain(drain, exc)

    # -- transfer execution (runs on progress threads / workers) ----------
    def _exec_comm(self, op: OperationNode) -> None:
        execute_payload(op.payload, self.backend.storage, self.backend.scratch)

    # -- work stealing -----------------------------------------------------
    def _steal_for(self, thief: Worker) -> Optional[list[OperationNode]]:
        """Steal policy, run by an idle worker before parking: pick the
        longest queue holding at least ``steal_threshold`` ops, take
        half its tail (one op unbatched), but only when the expected
        work moved clears the steal-latency gate (arXiv 1805.01768)."""
        if self._closed or self._error is not None:
            return None
        victim = None
        vlen = self.steal_threshold - 1
        for w in self.workers:
            if w is thief:
                continue
            n = w.qlen()  # racy heuristic read; steal_from re-checks
            if n > vlen:
                victim, vlen = w, n
        if victim is None:
            return None
        n = max(1, vlen // 2) if self.batch_dispatch else 1
        # latency-aware gate: moving n ops pays only when their expected
        # grain amortizes the steal round trip
        if n * self._grain_ewma < self.steal_latency:
            return None
        return victim.steal_from(n) or None

    def _wake_thieves(self, loaded_ranks) -> None:
        """After a dispatch left some queue at/above the steal threshold,
        nudge parked empty-queue workers to re-run the steal policy."""
        for w in self.workers:
            if w.rank not in loaded_ranks and w.qlen() == 0:
                w.wake()

    # -- dispatch ---------------------------------------------------------
    def _count_op(self, op: OperationNode, drain: _Drain) -> None:
        """Op accounting — call with _glock held (many threads dispatch)."""
        if op.kind == COMM:
            self.n_comm_ops += 1
            self.comm_bytes += op.nbytes
            drain.n_comm_ops += 1
            drain.comm_bytes += op.nbytes
            drain.n_messages += 1  # every comm op is posted exactly once
        else:
            self.n_compute_ops += 1
            drain.n_compute_ops += 1

    def _dispatch_batch(self, ops: list[OperationNode]) -> None:
        """Route a sweep of ready ops.  COMM on the async channel is
        initiated immediately from the discovering thread in one batched
        post (aggressive initiation — invariant 2 holds even while the
        owner workers are mid-compute); everything else is grouped per
        owner and handed to the comm-first ready queues — one push per
        worker under batched dispatch, one per op otherwise."""
        if not ops:
            return
        async_comm: list[OperationNode] = []
        per_worker: dict[int, list[OperationNode]] = {}
        for op in ops:
            if op.kind == COMM and not self.channel.blocking:
                async_comm.append(op)
            else:
                per_worker.setdefault(op.procs[0] % self.nworkers, []).append(op)
        if async_comm:
            post_many = getattr(self.channel, "post_many", None)
            items = [(op, self._exec_comm) for op in async_comm]
            if post_many is not None:
                futs = post_many(items)
            else:  # channel plugin without batched posting
                futs = [self.channel.post(op, ex) for op, ex in items]
            for op, fut in zip(async_comm, futs):
                fut.add_done_callback(self._comm_callback(op))
        handoffs = 0
        heavy = False
        for rank, group in per_worker.items():
            if self.batch_dispatch:
                self.workers[rank].push_batch(group)
                handoffs += 1
            else:
                for op in group:
                    self.workers[rank].push(op)
                    handoffs += 1
            heavy = heavy or len(group) >= self.steal_threshold
        if handoffs:
            with self._glock:
                self.n_handoffs += handoffs
                for rank, group in per_worker.items():
                    seen = set()
                    for op in group:
                        d = op._drain
                        if id(d) not in seen:
                            seen.add(id(d))
                            d.n_handoffs += 1
        if self.steal and heavy:
            self._wake_thieves(set(per_worker))

    def _comm_callback(self, op: OperationNode):
        def cb(fut) -> None:
            exc = fut.exception()
            if exc is not None:
                self._fail_drain(op._drain, exc)
            else:
                self._ops_done((op,))

        return cb

    def _run_batch(self, ops: list[OperationNode], worker: Worker) -> None:
        """Execute one worker batch (comm-first order already applied by
        the pop) and complete it through a single dependency sweep.  A
        batch may mix ops from several concurrent drains; per-op stats
        are binned into each op's own drain, and a failing op kills only
        its drain — the rest of the batch still executes."""
        completed: list[OperationNode] = []
        col = _obs.CURRENT
        rank = worker.rank
        for op in ops:
            drain: _Drain = op._drain
            if drain.finished:
                continue  # drain failed elsewhere: its leftovers are void
            dstats = drain.procs[rank]
            if op.kind == COMM:  # blocking channel only: inline transfer
                t0 = time.perf_counter()  # wall: the blocking IS the waiting
                if col is not None:
                    col.wait_start(rank, "channel")
                fut = self.channel.post(op, self._exec_comm)
                try:
                    # wait for resolution: the built-in BlockingChannel
                    # resolves before post() returns, but a registered
                    # blocking transport may resolve from a delivery
                    # thread — the op must not complete before its data
                    fut.result()
                except BaseException as exc:
                    dt = time.perf_counter() - t0
                    worker.stats.comm_busy += dt
                    worker.stats.n_comm += 1
                    dstats.comm_busy += dt
                    dstats.n_comm += 1
                    if col is not None:
                        col.wait_end(rank, "channel", op.uid)
                    self._fail_drain(drain, exc)
                    continue
                dt = time.perf_counter() - t0
                worker.stats.comm_busy += dt
                worker.stats.n_comm += 1
                dstats.comm_busy += dt
                dstats.n_comm += 1
                if col is not None:
                    col.wait_end(rank, "channel", op.uid)
                completed.append(op)
                continue
            # compute is accounted in per-thread CPU time: wall durations on
            # an oversubscribed machine include GIL/scheduler preemption,
            # which would inflate "busy" exactly when contention is worst
            if col is not None:
                col.compute_start(op.uid, rank)
            t0 = time.thread_time()
            try:
                self.backend.execute(op)
            except BaseException as exc:
                if col is not None:
                    col.compute_end(op.uid, rank)
                self._fail_drain(drain, exc)
                continue
            dt = time.thread_time() - t0
            worker.stats.compute_busy += dt
            worker.stats.n_compute += 1
            dstats.compute_busy += dt
            dstats.n_compute += 1
            # unlocked EWMA: a heuristic input for the steal gate only
            self._grain_ewma += 0.2 * (dt - self._grain_ewma)
            if col is not None:
                col.compute_end(op.uid, rank)
            completed.append(op)
        if completed:
            self._ops_done(completed)

    # -- completion (worker batches and channel callbacks land here) -------
    def _ops_done(self, ops) -> None:
        # this runs on worker/progress threads (including as a future
        # done-callback): it must never raise, or the completing thread
        # dies and the drain hangs
        try:
            self._ops_done_inner(ops)
        except BaseException as internal:  # pragma: no cover - defensive
            self._record_error(internal)

    def _ops_done_inner(self, ops) -> None:
        col = _obs.CURRENT
        to_dispatch: list[OperationNode] = []
        finishing: list[tuple[_Drain, Optional[BaseException]]] = []
        with self._glock:
            groups: dict[int, list[OperationNode]] = {}
            for op in ops:
                groups.setdefault(id(op._drain), []).append(op)
            for key, dops in groups.items():
                drain = self._drains.get(key)
                if drain is None or drain.finished:
                    continue  # late completions of an already-failed drain
                deps = drain.deps
                drain.inflight -= len(dops)
                ready_pairs = [] if col is not None else None
                for op in dops:
                    # complete() returns the ops this completion made ready
                    # — the causality edge wait attribution charges along
                    made_ready = deps.complete(op)  # on_ready -> ready_batch
                    if ready_pairs is not None:
                        for nxt in made_ready:
                            ready_pairs.append((nxt.uid, op.uid))
                if ready_pairs:
                    col.ready_many(ready_pairs)
                newly = drain.ready_batch
                drain.ready_batch = []
                drain.inflight += len(newly)
                for nxt in newly:
                    self._count_op(nxt, drain)
                to_dispatch.extend(newly)
                if drain.inflight == 0:
                    finishing.append(
                        (drain, None if deps.done else self._deadlock_error(deps))
                    )
            if col is not None:
                col.counter(
                    "ops-inflight",
                    sum(d.inflight for d in self._drains.values()),
                )
        self._dispatch_batch(to_dispatch)
        for drain, exc in finishing:
            self._finish_drain(drain, exc)

    def _deadlock_error(self, deps: Optional[DependencySystem]) -> DeadlockError:
        stuck = deps.pending_ops() if deps is not None else []
        return DeadlockError(
            f"async flush stalled: {len(stuck)} operations pending, none in "
            f"flight — dependency cycle or lost completion.\nstuck operation-nodes:\n"
            + format_stuck_ops(stuck)
        )

    # -- per-drain accounting ---------------------------------------------
    def _snapshot(self) -> dict:
        return dict(
            workers=[w.stats.snapshot() for w in self.workers],
            comm_bytes=self.comm_bytes,
            n_comm_ops=self.n_comm_ops,
            n_compute_ops=self.n_compute_ops,
            n_handoffs=self.n_handoffs,
            n_posted=getattr(self.channel, "n_posted", 0),
        )

    def _stats_since(self, snap: dict, elapsed: float) -> WaitStats:
        procs = [w.stats.since(s) for w, s in zip(self.workers, snap["workers"])]
        return WaitStats(
            mode=self.mode,
            nworkers=self.nworkers,
            elapsed=elapsed,
            procs=procs,
            comm_bytes=self.comm_bytes - snap["comm_bytes"],
            n_comm_ops=self.n_comm_ops - snap["n_comm_ops"],
            n_compute_ops=self.n_compute_ops - snap["n_compute_ops"],
            seq_time=sum(p.compute_busy for p in procs),
            n_flushes=1,
            n_handoffs=self.n_handoffs - snap["n_handoffs"],
            n_messages=getattr(self.channel, "n_posted", 0) - snap["n_posted"],
        )

    def _drain_stats(self, drain: _Drain, elapsed: float) -> WaitStats:
        """Per-drain WaitStats.  A drain that had the pool to itself its
        whole lifetime reports the exact lifetime-delta the serialized
        executor reported (including worker idle time between its ops);
        an overlapped drain reports its own per-op accounting — worker
        idle/wakeups are shared-pool quantities with no meaningful
        per-drain split, so they stay zero and ``wait_fraction``
        (compute-vs-elapsed) remains well-defined per tenant."""
        if drain.solo:
            return self._stats_since(drain.snap, elapsed)
        return WaitStats(
            mode=self.mode,
            nworkers=self.nworkers,
            elapsed=elapsed,
            procs=drain.procs,
            comm_bytes=drain.comm_bytes,
            n_comm_ops=drain.n_comm_ops,
            n_compute_ops=drain.n_compute_ops,
            seq_time=sum(p.compute_busy for p in drain.procs),
            n_flushes=1,
            n_handoffs=drain.n_handoffs,
            n_messages=drain.n_messages,
        )

    def _finish_drain(
        self, drain: _Drain, exc: Optional[BaseException] = None
    ) -> None:
        """Finalize one drain exactly once: detach its graph, restore its
        hook, and resolve its future — with the measured WaitStats, or
        with ``exc``.  Runs on whichever thread completes (or kills) the
        drain's last in-flight operation."""
        with self._glock:
            if drain.finished:
                return
            drain.finished = True
            self._drains.pop(id(drain), None)
            drain.ready_batch = []
            drain.inflight = 0
        if drain.deps is not None:
            drain.deps.on_ready = drain.prev_hook
        if exc is not None:
            # a failed drain's queued-but-unexecuted leftovers must not
            # run later against state a subsequent flush re-plans
            for w in self.workers:
                w.discard(lambda op: getattr(op, "_drain", None) is drain)
        col = _obs.CURRENT
        if col is not None:
            col.drain_end(drain.tag)
        elapsed = time.perf_counter() - drain.t0
        if exc is not None:
            drain.fut.set_exception(exc)
        else:
            drain.fut.set_result(self._drain_stats(drain, elapsed))

    # -- main entry -------------------------------------------------------
    def submit(
        self,
        deps: DependencySystem,
        batch_dispatch: Optional[bool] = None,
        tag=None,
    ) -> Future:
        """Start draining ``deps`` and return a Future resolving to the
        drain's :class:`WaitStats` (or raising its failure).  Returns
        immediately; the caller keeps its thread.  May be called again
        while prior drains are in flight — concurrent drains share the
        worker pool; the caller guarantees the submitted graphs'
        access footprints don't conflict (``Runtime.flush`` serializes
        conflicting cones by joining their tickets first)."""
        return self.submit_many([(deps, tag)], batch_dispatch=batch_dispatch)[0]

    def submit_many(
        self,
        items: list,
        batch_dispatch: Optional[bool] = None,
    ) -> list:
        """Start draining several graphs — ``items`` is a list of
        ``(deps, tag)`` pairs — in ONE submission round, returning one
        Future per item (in order).  The cross-tenant cone batcher's
        entry point: registering the whole group under a single
        global-lock round, a single worker wake, and a single initial
        dispatch sweep amortizes the per-drain submission overhead that
        dominates small-cone serving workloads.

        Exactly like repeated :meth:`submit` calls otherwise; the caller
        guarantees the graphs' access footprints are mutually
        non-conflicting (the cone batcher inherits this from
        ``Runtime._join_conflicting``'s extraction-order bound).  Every
        drain submitted through a group of two or more is accounted as
        an *overlapped* drain (per-drain stats binning, never the
        solo-exact lifetime delta) — co-submitted cones share the pool
        by construction."""
        if self._closed:
            raise RuntimeError("AsyncExecutor is closed")
        if self._error is not None:
            raise self._error
        col = _obs.CURRENT
        prepared = []  # (deps, drain, pending) per item
        with self._glock:
            if batch_dispatch is not None and batch_dispatch != self.batch_dispatch:
                if self._drains:
                    raise RuntimeError(
                        "cannot switch dispatch granularity while drains "
                        "are in flight"
                    )
                self.batch_dispatch = batch_dispatch
                for w in self.workers:
                    w.set_batch(batch_dispatch)
            for deps, tag in items:
                if tag is None:
                    # drains need a distinguishable id: trace segments of
                    # concurrent drains pair begin/end events by tag
                    tag = f"anon-{next(self._anon_tags)}"
                drain = _Drain(deps, tag, self.nworkers)
                drain.prev_hook = deps.on_ready
                pending = deps.pending_ops()
                for op in pending:
                    op._drain = drain
                prepared.append((deps, drain, pending))
            if self._drains or len(prepared) > 1:
                for d in self._drains.values():
                    d.solo = False
                for _deps, drain, _p in prepared:
                    drain.solo = False
            for _deps, drain, _p in prepared:
                drain.snap = self._snapshot()
                drain.t0 = time.perf_counter()
                self._drains[id(drain)] = drain
            if not self._workers_started:
                self._workers_started = True
                for w in self.workers:
                    w.start()
        for deps, drain, pending in prepared:
            # late-bound: _ops_done swaps ready_batch for a fresh list per
            # sweep; the default-arg binding pins each drain to its hook
            deps.on_ready = lambda op, d=drain: d.ready_batch.append(op)
            if col is not None:
                col.drain_begin(drain.tag, deps.n_pending, self.nworkers)
                col.drain_ops(drain.tag, [op.uid for op in pending])
        for w in self.workers:
            w.drain_started()  # parked-between-drains time is not idle
        # initial dispatch: everything recorded ready before we attached
        to_dispatch = []
        finishing = []
        with self._glock:
            for deps, drain, _p in prepared:
                initial = []
                while True:
                    op = deps.pop_ready()
                    if op is None:
                        break
                    initial.append(op)
                    self._count_op(op, drain)
                drain.inflight += len(initial)
                to_dispatch.extend(initial)
                if not initial:
                    finishing.append(
                        (drain,
                         None if deps.done else self._deadlock_error(deps))
                    )
        for drain, exc in finishing:
            self._finish_drain(drain, exc)  # empty graph: empty stats
        if to_dispatch:
            self._dispatch_batch(to_dispatch)
        return [drain.fut for _deps, drain, _p in prepared]

    @property
    def n_active_drains(self) -> int:
        with self._glock:
            return len(self._drains)

    def run(self, deps: DependencySystem) -> WaitStats:
        """Drain ``deps`` to completion; returns the measured WaitStats
        for this flush (``submit`` + blocking wait).  The worker pool
        persists across calls until :meth:`close`."""
        return self.submit(deps).result()

    def close(self) -> None:
        """Stop the worker pool and (if owned) the channel.  Idempotent —
        a double close is a no-op.  Any still-active drain is failed
        (the owner should have joined its tickets first)."""
        if self._closed:
            return
        self._closed = True
        with self._glock:
            drains = list(self._drains.values())
        for d in drains:
            self._finish_drain(
                d, RuntimeError("AsyncExecutor closed with a drain in flight")
            )
        for w in self.workers:
            w.stop()
        if self._workers_started:
            for w in self.workers:
                w.join(timeout=5.0)
        if self._owns_channel:
            self.channel.close()


# ---------------------------------------------------------------------------
# Fig. 6 on real threads: naive BSP + two-sided rendezvous messaging
# ---------------------------------------------------------------------------


def run_rendezvous_bsp_async(
    per_proc_programs: list[list[dict]], static_check: bool = True
) -> int:
    """Execute the paper's naive evaluation (fig. 6) with real threads:
    each rank walks its own operation list in order; sends and receives
    rendezvous through a :class:`RendezvousMailbox`.

    Well-ordered schedules complete and return the number of completed
    steps.  Schedules like fig. 6's deadlock — rejected *statically at
    plan time* by the ``repro.analysis`` deadlock rule (a cycle in the
    cross-rank message-match graph, or an unmatched message) before any
    thread starts, and — for completeness with ``static_check=False`` —
    also detected structurally at runtime (all live ranks parked on
    unmatched messages).  Both paths refuse with a
    :class:`DeadlockError` listing the stuck operation-nodes.  This is
    the contrast the flush executor exists for: the *same* data movement
    expressed as one-sided transfers in a dependency graph cannot
    deadlock (§5.7.1).
    """
    if static_check:
        from repro.analysis import check

        report = check(schedule=per_proc_programs, rules=("deadlock",))
        if not report.ok:
            raise DeadlockError(
                "rendezvous-BSP schedule rejected statically at plan time "
                "(repro.analysis deadlock rule):\n"
                + "\n".join(d.message for d in report.errors)
            )
    n = len(per_proc_programs)
    mailbox = RendezvousMailbox(n)
    steps = [0] * n
    failures: list[RendezvousDeadlock] = []
    lock = threading.Lock()

    def rank_main(rank: int) -> None:
        try:
            for pc, op in enumerate(per_proc_programs[rank]):
                if op["kind"] == "compute":
                    steps[rank] += 1
                    continue
                mailbox.transact(rank, op["kind"], op["peer"], op["tag"], pc)
                steps[rank] += 1
        except RendezvousDeadlock as exc:
            with lock:
                failures.append(exc)
        finally:
            mailbox.finish(rank)

    threads = [
        threading.Thread(target=rank_main, args=(r,), name=f"bsp-rank-{r}")
        for r in range(n)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if failures:
        stuck = failures[0].stuck
        lines = [
            f"  p{s['rank']}@step{s['step']}: {s['kind']} tag={s['tag']!r} "
            f"peer=p{s['peer']}"
            for s in stuck
        ]
        raise DeadlockError(
            "rendezvous-BSP schedule deadlocked (paper fig. 6): every live "
            "rank is parked on an unmatched two-sided message.\n"
            "stuck operation-nodes:\n" + "\n".join(lines)
        )
    return sum(steps)
