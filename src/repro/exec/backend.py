"""Asynchronous flush executor and pluggable compute backends.

:class:`AsyncExecutor` drains a recorded
:class:`~repro.core.graph.DependencySystem` with genuine concurrency —
the wall-clock counterpart of ``repro.core.scheduler.run_schedule``:

* one :class:`~repro.exec.workers.Worker` thread per simulated process,
  each with a private comm-first ready queue;
* transfers go through a :mod:`~repro.exec.channels` discipline — the
  non-blocking :class:`AsyncChannel` progress engine delivers scratch
  buffers while compute runs, the :class:`BlockingChannel` reproduces the
  synchronous baseline on the worker's own clock;
* completion is sweep-based: a finished worker batch (or a channel
  future's done-callback) performs the refcount decrements
  (``deps.complete``) and dispatches newly-ready operations — the
  graph's ``on_ready`` hook delivers them straight to worker queues,
  no central scheduler loop.  Under the ``"batch"`` plan pass the
  sweep moves per-worker *lists* per lock round trip
  (``batch_dispatch=True``), amortizing the Python handoff overhead;
* the numerical result is bit-identical to the simulated executor's: the
  dependency system totally orders every pair of conflicting accesses, so
  any schedule that respects it interprets the payloads (shared
  ``repro.core.engine.execute_payload``) into the same block contents.

Deadlock is detected structurally, not by timeout: when nothing is in
flight and the dependency system still has pending operations, no future
can ever resolve — the executor raises
:class:`~repro.core.scheduler.DeadlockError` listing the stuck
operation-nodes.  :func:`run_rendezvous_bsp_async` applies the same
treatment to the paper's fig. 6 schedule executed with real threads and
two-sided rendezvous messaging.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import numpy as np

from repro.api.registry import get_backend, register_backend
from repro.core.engine import MapPayload, MatmulPayload, execute_payload, resolve_ref
from repro.core.graph import COMM, DependencySystem, OperationNode
from repro.core.scheduler import DeadlockError, format_stuck_ops
from repro.obs import collector as _obs

from .channels import RendezvousDeadlock, RendezvousMailbox, make_channel
from .futures import Future
from .stats import WaitStats
from .workers import Worker

__all__ = [
    "ComputeBackend",
    "NumpyBackend",
    "JaxBackend",
    "AutoBackend",
    "make_backend",
    "AsyncExecutor",
    "run_rendezvous_bsp_async",
]


# ---------------------------------------------------------------------------
# Compute backends
# ---------------------------------------------------------------------------


class ComputeBackend:
    """Executes operation payloads against the runtime's block storage."""

    name = "abstract"

    def __init__(self, storage: dict, scratch: dict):
        self.storage = storage
        self.scratch = scratch

    def execute(self, op: OperationNode) -> None:
        raise NotImplementedError


class NumpyBackend(ComputeBackend):
    """Eager NumPy interpretation — the reference backend (bit-identical
    to the simulated executor by construction)."""

    name = "numpy"

    def execute(self, op: OperationNode) -> None:
        execute_payload(op.payload, self.storage, self.scratch)


class JaxBackend(ComputeBackend):
    """jit-compiles block payloads with XLA.

    * Elementwise map payloads (including fused expression trees, via
      ``UFunc.tree``) are retraced with ``jax.numpy`` primitives and
      cached per (ufunc, signature).
    * Fused 5-point stencil payloads are routed through the Pallas
      ``stencil5_block`` kernel from ``repro.kernels.stencil`` (interpret
      mode on CPU, compiled on TPU).
    * Matmul payloads run through a jitted ``jnp.dot``.
    * Everything else (transfers, reductions, fills) falls back to the
      NumPy interpreter — those are memory movement, not FLOPs.

    Note: without ``jax_enable_x64`` the payloads compute in float32, so
    results are *numerically close*, not bit-identical, to the NumPy
    backend on float64 programs.
    """

    name = "jax"

    def __init__(self, storage: dict, scratch: dict):
        super().__init__(storage, scratch)
        import jax  # the container bakes in the jax toolchain
        import jax.numpy as jnp

        self._jax = jax
        self._jnp = jnp
        self._x64 = bool(jax.config.read("jax_enable_x64"))
        self._impls = {
            "identity": lambda x: x,
            "add": jnp.add,
            "subtract": jnp.subtract,
            "multiply": jnp.multiply,
            "divide": jnp.divide,
            "power": jnp.power,
            "negative": jnp.negative,
            "absolute": jnp.abs,
            "exp": jnp.exp,
            "log": jnp.log,
            "sqrt": jnp.sqrt,
            "square": jnp.square,
            "maximum": jnp.maximum,
            "minimum": jnp.minimum,
            # comparisons carry a real bool result dtype (UFunc.out_dtype),
            # matching NumPy — no float cast
            "greater": jnp.greater,
            "less": jnp.less,
            "where": jnp.where,
        }
        self._jit_cache: dict = {}
        self._untranslatable: set = set()  # (name, tree_key) with no jnp form
        # interpret the Pallas kernel everywhere but on a real TPU
        self._interpret = jax.default_backend() != "tpu"
        try:
            from repro.kernels.stencil import stencil5_block

            self._stencil5 = stencil5_block
        except Exception:  # pragma: no cover - kernels unavailable
            self._stencil5 = None

    # -- helpers ---------------------------------------------------------
    def _to_device(self, x):
        jnp = self._jnp
        if isinstance(x, np.ndarray) and not self._x64:
            if x.dtype == np.float64:
                return jnp.asarray(x, dtype=jnp.float32)
            if x.dtype == np.int64:
                return jnp.asarray(x, dtype=jnp.int32)
        return jnp.asarray(x)

    def _impl_of(self, u) -> Optional[object]:
        return self._impls.get(u.name)

    def _trace_ufunc(self, ufunc):
        """Build a jnp callable for a primitive or fused ufunc; None if a
        primitive inside has no jnp translation."""
        from repro.core.ufunc import eval_tree

        if ufunc.tree is not None:
            missing = []

            def impl(u):
                f = self._impl_of(u)
                if f is None:
                    missing.append(u.name)
                    return u.fn
                return f

            # dry-walk the tree for translatability (leaves unevaluated)
            def walk(spec):
                if spec[0] in ("leaf", "const"):
                    return
                f, subs = spec
                impl(f)
                for s in subs:
                    walk(s)

            walk(ufunc.tree)
            if missing:
                return None
            return lambda *arrays: eval_tree(ufunc.tree, arrays, self._impl_of)
        f = self._impl_of(ufunc)
        return None if f is None else (lambda *arrays: f(*arrays))

    @staticmethod
    def _stencil5_weight(tree) -> Optional[float]:
        """Match ``w * ((((x0+x1)+x2)+x3)+x4)`` — the fused 5-point
        stencil sweep — returning the weight, else None."""
        if not (isinstance(tree, tuple) and len(tree) == 2):
            return None
        f, subs = tree
        if getattr(f, "name", None) != "multiply" or len(subs) != 2:
            return None
        const, chain = subs
        if const[0] != "const":
            const, chain = chain, const
        if const[0] != "const":
            return None
        expect = 4
        while isinstance(chain, tuple) and len(chain) == 2 and getattr(
            chain[0], "name", None
        ) == "add":
            _, (left, right) = chain
            if right != ("leaf", expect):
                return None
            expect -= 1
            chain = left
        if chain != ("leaf", 0) or expect != 0:
            return None
        return float(const[1])

    # -- execution -------------------------------------------------------
    def execute(self, op: OperationNode) -> None:
        p = op.payload
        if isinstance(p, MapPayload):
            if self._exec_map(p):
                return
        elif isinstance(p, MatmulPayload):
            self._exec_matmul(p)
            return
        execute_payload(p, self.storage, self.scratch)

    def _exec_map(self, p: MapPayload) -> bool:
        ukey = (p.ufunc.name, self._tree_key(p.ufunc.tree))
        if ukey in self._untranslatable:
            return False  # known fallback: skip resolving refs twice
        args = [resolve_ref(r, self.storage, self.scratch) for r in p.args]
        arr_idx = [i for i, r in enumerate(p.args) if r[0] != "c"]
        # Pallas fast path: fused 5-point stencil block sweep
        if (
            self._stencil5 is not None
            and p.ufunc.tree is not None
            and len(arr_idx) == 5
            and all(getattr(args[i], "ndim", 0) == 2 for i in arr_idx)
            and len({args[i].shape for i in arr_idx}) == 1
        ):
            w = self._stencil5_weight(p.ufunc.tree)
            if w is not None:
                xs = [self._to_device(np.ascontiguousarray(args[i])) for i in arr_idx]
                res = self._stencil5(*xs, weight=w, interpret=self._interpret)
                self._store(p, np.asarray(res))
                return True
        fn = self._cached_jit(p, args, arr_idx)
        if fn is None:
            self._untranslatable.add(ukey)
            return False
        dev_args = list(args)
        for i in arr_idx:
            dev_args[i] = self._to_device(np.ascontiguousarray(args[i]))
        self._store(p, np.asarray(fn(*dev_args)))
        return True

    @staticmethod
    def _tree_key(spec):
        """Structural signature of an expression tree: two independently
        built but identical fused expressions must share one jit entry
        (keying on object identity would recompile per materialize and
        pin dead closures in the cache forever)."""
        if spec is None:
            return None
        tag = spec[0]
        if tag in ("leaf", "const"):
            return spec
        f, subs = spec
        return (f.name, tuple(JaxBackend._tree_key(s) for s in subs))

    def _cached_jit(self, p: MapPayload, args, arr_idx):
        sig = tuple(
            (args[i].shape, str(args[i].dtype)) if i in arr_idx else ("c",)
            for i in range(len(args))
        )
        key = (p.ufunc.name, self._tree_key(p.ufunc.tree), sig)
        fn = self._jit_cache.get(key)
        if fn is None and key not in self._jit_cache:
            traced = self._trace_ufunc(p.ufunc)
            fn = None if traced is None else self._jax.jit(traced)
            self._jit_cache[key] = fn
        return fn

    def _exec_matmul(self, p: MatmulPayload) -> None:
        jnp = self._jnp
        a = resolve_ref(p.a, self.storage, self.scratch)
        b = resolve_ref(p.b, self.storage, self.scratch)
        if p.trans_a:
            a = a.T
        if p.trans_b:
            b = b.T
        key = ("mm", a.shape, b.shape, str(a.dtype), str(b.dtype))
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jax.jit(lambda x, y: jnp.dot(x, y))
            self._jit_cache[key] = fn
        val = np.asarray(fn(self._to_device(np.ascontiguousarray(a)),
                            self._to_device(np.ascontiguousarray(b))))
        blk = self.storage[(p.out_base, p.out_frag.block)]
        if p.init:
            blk[p.out_frag.slices] = val
        else:
            blk[p.out_frag.slices] += val

    def _store(self, p: MapPayload, res: np.ndarray) -> None:
        blk = self.storage[(p.out_base, p.out_frag.block)]
        blk[p.out_frag.slices] = res


class AutoBackend(ComputeBackend):
    """Per-payload backend choice — the first registry client beyond the
    two reference backends (ROADMAP "backend autotuning").

    Small block payloads stay on the eager NumPy interpreter (XLA
    dispatch + host↔device staging costs more than the arithmetic);
    payloads whose estimated per-element work clears ``threshold`` go to
    the jit-compiling :class:`JaxBackend` (including its Pallas stencil
    fast path).  The score is ``out_elements × ufunc cost`` for maps and
    output elements for matmuls — the same per-element weights the
    timeline model uses, so the choice needs no calibration run.  The
    JAX backend is built lazily on the first heavy payload and the
    choice is a pure function of the payload, so repeated drains of the
    same graph route identically (results stay deterministic across
    channel disciplines).
    """

    name = "auto"

    # default: a 128×128 float64 block of cost-4 (transcendental) work
    # clears it, a cost-1 copy/add block does not
    DEFAULT_THRESHOLD = 48_000

    def __init__(self, storage: dict, scratch: dict, threshold: int = DEFAULT_THRESHOLD):
        super().__init__(storage, scratch)
        self.threshold = threshold
        self._numpy = NumpyBackend(storage, scratch)
        self._jax: Optional[JaxBackend] = None
        self._jax_unavailable = False
        self.n_numpy = 0
        self.n_jax = 0

    def _jax_backend(self) -> Optional[JaxBackend]:
        if self._jax is None and not self._jax_unavailable:
            try:
                self._jax = JaxBackend(self.storage, self.scratch)
            except ImportError as exc:  # no usable jax: degrade to NumPy
                self._jax_unavailable = True
                import warnings

                warnings.warn(
                    f"backend='auto': jax unavailable ({exc}); all payloads "
                    f"will run on the NumPy interpreter",
                    RuntimeWarning,
                    stacklevel=3,
                )
        return self._jax

    def _score(self, p) -> float:
        if isinstance(p, MapPayload):
            return p.out_frag.size * max(1.0, p.ufunc.cost)
        if isinstance(p, MatmulPayload):
            return float(p.out_frag.size)
        return 0.0  # transfers/reductions/fills: memory movement, stay eager

    def execute(self, op: OperationNode) -> None:
        if self._score(op.payload) >= self.threshold:
            jb = self._jax_backend()
            if jb is not None:
                self.n_jax += 1
                jb.execute(op)
                return
        self.n_numpy += 1
        self._numpy.execute(op)


register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)
register_backend("auto", AutoBackend)


def make_backend(name, storage: dict, scratch: dict) -> ComputeBackend:
    """Resolve a compute backend through the plugin registry (an
    already-built instance passes through)."""
    if isinstance(name, ComputeBackend):
        return name
    return get_backend(name)(storage, scratch)


# ---------------------------------------------------------------------------
# The asynchronous executor
# ---------------------------------------------------------------------------


class AsyncExecutor:
    """Drains DependencySystems on a persistent worker pool + transfer
    channels.

    The executor is *persistent*: :meth:`submit` hands it a recorded
    graph (typically one dependency cone of a demand-driven flush) and
    returns a :class:`~repro.exec.futures.Future` that resolves — from
    the completing worker/progress thread — with that drain's
    :class:`WaitStats` delta.  The submitting thread keeps running
    (recording more operations) while the drain proceeds; drains are
    serialized (one in flight at a time), and the worker threads park on
    their empty queues between drains instead of being torn down.
    :meth:`run` is the blocking convenience (``submit().result()``).

    With ``batch_dispatch=True`` (set by the ``"batch"`` plan pass) the
    completion sweep groups newly-ready compute ops per worker and
    pushes each group with one lock+notify, workers drain their whole
    queue per wakeup, and a finished batch is completed through a
    single dependency-system sweep — the handoff count drops from one
    per operation to one per batch (``WaitStats.n_handoffs``)."""

    def __init__(
        self,
        nworkers: int,
        storage: dict,
        scratch: dict,
        backend: str = "numpy",
        channel: str = "async",
        latency: float = 0.0,
        progress_threads: int = 2,
        batch_dispatch: bool = False,
    ):
        self.nworkers = nworkers
        self.backend = make_backend(backend, storage, scratch)
        # a channel instance may be shared across flushes (the owner closes
        # it); a name means this executor owns the channel's lifecycle
        self._owns_channel = isinstance(channel, str)
        self.channel = make_channel(
            channel, latency=latency, progress_threads=progress_threads
        )
        self.mode = "blocking-channel" if self.channel.blocking else "async"
        self.batch_dispatch = batch_dispatch
        self.workers = [
            Worker(r, self._run_batch, self._record_error, batch=batch_dispatch)
            for r in range(nworkers)
        ]
        self._glock = threading.Lock()  # guards deps + inflight accounting
        self._deps: Optional[DependencySystem] = None
        self._inflight = 0
        self._ready_batch: list[OperationNode] = []
        self._drain_fut: Optional[Future] = None
        self._prev_hook = None
        self._drain_tag = None  # flush id of the active drain (trace segment)
        self._t0 = 0.0
        self._snap: Optional[dict] = None
        self._error: Optional[BaseException] = None
        self._workers_started = False
        self._closed = False
        # lifetime totals; per-drain stats are deltas against a submit-time
        # snapshot
        self.comm_bytes = 0
        self.n_comm_ops = 0
        self.n_compute_ops = 0
        self.n_handoffs = 0

    # -- error path ------------------------------------------------------
    def _record_error(self, exc: BaseException) -> None:
        self._finish_drain(exc)

    # -- transfer execution (runs on progress threads / workers) ----------
    def _exec_comm(self, op: OperationNode) -> None:
        execute_payload(op.payload, self.backend.storage, self.backend.scratch)

    # -- dispatch ---------------------------------------------------------
    def _count_op(self, op: OperationNode) -> None:
        """Op accounting — call with _glock held (many threads dispatch)."""
        if op.kind == COMM:
            self.n_comm_ops += 1
            self.comm_bytes += op.nbytes
        else:
            self.n_compute_ops += 1

    def _dispatch_batch(self, ops: list[OperationNode]) -> None:
        """Route a sweep of ready ops.  COMM on the async channel is
        initiated immediately from the discovering thread in one batched
        post (aggressive initiation — invariant 2 holds even while the
        owner workers are mid-compute); everything else is grouped per
        owner and handed to the comm-first ready queues — one push per
        worker under batched dispatch, one per op otherwise."""
        if not ops:
            return
        async_comm: list[OperationNode] = []
        per_worker: dict[int, list[OperationNode]] = {}
        for op in ops:
            if op.kind == COMM and not self.channel.blocking:
                async_comm.append(op)
            else:
                per_worker.setdefault(op.procs[0] % self.nworkers, []).append(op)
        if async_comm:
            post_many = getattr(self.channel, "post_many", None)
            items = [(op, self._exec_comm) for op in async_comm]
            if post_many is not None:
                futs = post_many(items)
            else:  # channel plugin without batched posting
                futs = [self.channel.post(op, ex) for op, ex in items]
            for op, fut in zip(async_comm, futs):
                fut.add_done_callback(self._comm_callback(op))
        handoffs = 0
        for rank, group in per_worker.items():
            if self.batch_dispatch:
                self.workers[rank].push_batch(group)
                handoffs += 1
            else:
                for op in group:
                    self.workers[rank].push(op)
                    handoffs += 1
        if handoffs:
            with self._glock:
                self.n_handoffs += handoffs

    def _comm_callback(self, op: OperationNode):
        def cb(fut) -> None:
            exc = fut.exception()
            if exc is not None:
                self._record_error(exc)
            else:
                self._ops_done((op,))

        return cb

    def _run_batch(self, ops: list[OperationNode], worker: Worker) -> None:
        """Execute one worker batch (comm-first order already applied by
        the pop) and complete it through a single dependency sweep."""
        completed: list[OperationNode] = []
        col = _obs.CURRENT
        for op in ops:
            if op.kind == COMM:  # blocking channel only: inline transfer
                t0 = time.perf_counter()  # wall: the blocking IS the waiting
                if col is not None:
                    col.wait_start(worker.rank, "channel")
                fut = self.channel.post(op, self._exec_comm)
                try:
                    # wait for resolution: the built-in BlockingChannel
                    # resolves before post() returns, but a registered
                    # blocking transport may resolve from a delivery
                    # thread — the op must not complete before its data
                    fut.result()
                except BaseException as exc:
                    worker.stats.comm_busy += time.perf_counter() - t0
                    worker.stats.n_comm += 1
                    if col is not None:
                        col.wait_end(worker.rank, "channel", op.uid)
                    if completed:
                        self._ops_done(completed)
                    self._record_error(exc)
                    return
                worker.stats.comm_busy += time.perf_counter() - t0
                worker.stats.n_comm += 1
                if col is not None:
                    col.wait_end(worker.rank, "channel", op.uid)
                completed.append(op)
                continue
            # compute is accounted in per-thread CPU time: wall durations on
            # an oversubscribed machine include GIL/scheduler preemption,
            # which would inflate "busy" exactly when contention is worst
            if col is not None:
                col.compute_start(op.uid, worker.rank)
            t0 = time.thread_time()
            try:
                self.backend.execute(op)
            except BaseException as exc:
                if col is not None:
                    col.compute_end(op.uid, worker.rank)
                if completed:
                    self._ops_done(completed)
                self._record_error(exc)
                return
            worker.stats.compute_busy += time.thread_time() - t0
            worker.stats.n_compute += 1
            if col is not None:
                col.compute_end(op.uid, worker.rank)
            completed.append(op)
        self._ops_done(completed)

    # -- completion (worker batches and channel callbacks land here) -------
    def _ops_done(self, ops) -> None:
        # this runs on worker/progress threads (including as a future
        # done-callback): it must never raise, or the completing thread
        # dies and the drain hangs
        try:
            self._ops_done_inner(ops)
        except BaseException as internal:  # pragma: no cover - defensive
            self._record_error(internal)

    def _ops_done_inner(self, ops) -> None:
        finished = deadlocked = False
        col = _obs.CURRENT
        with self._glock:
            if self._deps is None:  # drain already finalized
                return
            deps = self._deps
            self._inflight -= len(ops)
            ready_pairs = [] if col is not None else None
            for op in ops:
                # complete() returns the ops this completion made ready —
                # the causality edge wait attribution charges waits along
                made_ready = deps.complete(op)  # on_ready -> _ready_batch
                if ready_pairs is not None:
                    for nxt in made_ready:
                        ready_pairs.append((nxt.uid, op.uid))
            if ready_pairs:
                col.ready_many(ready_pairs)
            newly, self._ready_batch = self._ready_batch, []
            self._inflight += len(newly)
            if col is not None:
                col.counter("ops-inflight", self._inflight)
            for nxt in newly:
                self._count_op(nxt)
            if self._inflight == 0:
                if deps.done:
                    finished = True
                else:
                    deadlocked = True
        self._dispatch_batch(newly)
        if finished:
            self._finish_drain()
        elif deadlocked:
            self._finish_drain(self._deadlock_error(deps))

    def _deadlock_error(self, deps: Optional[DependencySystem]) -> DeadlockError:
        stuck = deps.pending_ops() if deps is not None else []
        return DeadlockError(
            f"async flush stalled: {len(stuck)} operations pending, none in "
            f"flight — dependency cycle or lost completion.\nstuck operation-nodes:\n"
            + format_stuck_ops(stuck)
        )

    # -- per-drain accounting ---------------------------------------------
    def _snapshot(self) -> dict:
        return dict(
            workers=[w.stats.snapshot() for w in self.workers],
            comm_bytes=self.comm_bytes,
            n_comm_ops=self.n_comm_ops,
            n_compute_ops=self.n_compute_ops,
            n_handoffs=self.n_handoffs,
            n_posted=getattr(self.channel, "n_posted", 0),
        )

    def _stats_since(self, snap: dict, elapsed: float) -> WaitStats:
        procs = [w.stats.since(s) for w, s in zip(self.workers, snap["workers"])]
        return WaitStats(
            mode=self.mode,
            nworkers=self.nworkers,
            elapsed=elapsed,
            procs=procs,
            comm_bytes=self.comm_bytes - snap["comm_bytes"],
            n_comm_ops=self.n_comm_ops - snap["n_comm_ops"],
            n_compute_ops=self.n_compute_ops - snap["n_compute_ops"],
            seq_time=sum(p.compute_busy for p in procs),
            n_flushes=1,
            n_handoffs=self.n_handoffs - snap["n_handoffs"],
            n_messages=getattr(self.channel, "n_posted", 0) - snap["n_posted"],
        )

    def _finish_drain(self, exc: Optional[BaseException] = None) -> None:
        """Finalize the active drain exactly once: detach the graph,
        restore its hook, and resolve the drain future — with the
        measured WaitStats delta, or with ``exc``.  Runs on whichever
        thread completes (or kills) the last in-flight operation."""
        with self._glock:
            if self._drain_fut is None:  # no active drain (late error)
                if exc is not None and self._error is None:
                    self._error = exc
                return
            deps, self._deps = self._deps, None
            fut, self._drain_fut = self._drain_fut, None
            tag, self._drain_tag = self._drain_tag, None
            self._ready_batch = []
            # a failed drain may leave the erroring op (and friends)
            # uncounted; late completions of in-flight ops return early on
            # _deps None without decrementing, so zero the counter here or
            # the next drain on this executor could never reach 0
            self._inflight = 0
        if deps is not None:
            deps.on_ready = self._prev_hook
        col = _obs.CURRENT
        if col is not None:
            col.drain_end(tag)
        elapsed = time.perf_counter() - self._t0
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(self._stats_since(self._snap, elapsed))

    # -- main entry -------------------------------------------------------
    def submit(
        self,
        deps: DependencySystem,
        batch_dispatch: Optional[bool] = None,
        tag=None,
    ) -> Future:
        """Start draining ``deps`` and return a Future resolving to the
        drain's :class:`WaitStats` (or raising its failure).  Returns
        immediately; the caller keeps the main thread.  One drain may be
        in flight at a time — submit again only after the previous
        future resolved."""
        if self._closed:
            raise RuntimeError("AsyncExecutor is closed")
        if self._error is not None:
            raise self._error
        if self._drain_fut is not None:
            raise RuntimeError(
                "a drain is already in flight; wait on its future first"
            )
        if batch_dispatch is not None and batch_dispatch != self.batch_dispatch:
            self.batch_dispatch = batch_dispatch
            for w in self.workers:
                w.set_batch(batch_dispatch)
        fut = Future()
        self._prev_hook = deps.on_ready
        # late-bound: _ops_done swaps _ready_batch for a fresh list per sweep
        deps.on_ready = lambda op: self._ready_batch.append(op)
        self._snap = self._snapshot()
        self._t0 = time.perf_counter()
        with self._glock:
            self._deps = deps
            self._drain_fut = fut
            self._drain_tag = tag
        col = _obs.CURRENT
        if col is not None:
            col.drain_begin(tag, deps.n_pending, self.nworkers)
        if not self._workers_started:
            self._workers_started = True
            for w in self.workers:
                w.start()
        for w in self.workers:
            w.drain_started()  # parked-between-drains time is not idle
        # initial dispatch: everything recorded ready before we attached
        initial = []
        with self._glock:
            while True:
                op = deps.pop_ready()
                if op is None:
                    break
                initial.append(op)
                self._count_op(op)
            self._inflight += len(initial)
        if not initial:
            if deps.done:
                self._finish_drain()  # empty graph: resolve with empty delta
            else:
                self._finish_drain(self._deadlock_error(deps))
            return fut
        self._dispatch_batch(initial)
        return fut

    def run(self, deps: DependencySystem) -> WaitStats:
        """Drain ``deps`` to completion; returns the measured WaitStats
        for this flush (``submit`` + blocking wait).  The worker pool
        persists across calls until :meth:`close`."""
        return self.submit(deps).result()

    def close(self) -> None:
        """Stop the worker pool and (if owned) the channel.  Idempotent —
        a double close is a no-op."""
        if self._closed:
            return
        self._closed = True
        for w in self.workers:
            w.stop()
        if self._workers_started:
            for w in self.workers:
                w.join(timeout=5.0)
        if self._owns_channel:
            self.channel.close()


# ---------------------------------------------------------------------------
# Fig. 6 on real threads: naive BSP + two-sided rendezvous messaging
# ---------------------------------------------------------------------------


def run_rendezvous_bsp_async(per_proc_programs: list[list[dict]]) -> int:
    """Execute the paper's naive evaluation (fig. 6) with real threads:
    each rank walks its own operation list in order; sends and receives
    rendezvous through a :class:`RendezvousMailbox`.

    Well-ordered schedules complete and return the number of completed
    steps.  Schedules like fig. 6's deadlock — detected structurally (all
    live ranks parked on unmatched messages) and refused with a
    :class:`DeadlockError` listing the stuck operation-nodes.  This is the
    contrast the flush executor exists for: the *same* data movement
    expressed as one-sided transfers in a dependency graph cannot
    deadlock (§5.7.1).
    """
    n = len(per_proc_programs)
    mailbox = RendezvousMailbox(n)
    steps = [0] * n
    failures: list[RendezvousDeadlock] = []
    lock = threading.Lock()

    def rank_main(rank: int) -> None:
        try:
            for pc, op in enumerate(per_proc_programs[rank]):
                if op["kind"] == "compute":
                    steps[rank] += 1
                    continue
                mailbox.transact(rank, op["kind"], op["peer"], op["tag"], pc)
                steps[rank] += 1
        except RendezvousDeadlock as exc:
            with lock:
                failures.append(exc)
        finally:
            mailbox.finish(rank)

    threads = [
        threading.Thread(target=rank_main, args=(r,), name=f"bsp-rank-{r}")
        for r in range(n)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if failures:
        stuck = failures[0].stuck
        lines = [
            f"  p{s['rank']}@step{s['step']}: {s['kind']} tag={s['tag']!r} "
            f"peer=p{s['peer']}"
            for s in stuck
        ]
        raise DeadlockError(
            "rendezvous-BSP schedule deadlocked (paper fig. 6): every live "
            "rank is parked on an unmatched two-sided message.\n"
            "stuck operation-nodes:\n" + "\n".join(lines)
        )
    return sum(steps)
