"""Measured wait-for-communication statistics (wall-clock counterpart of
:class:`repro.core.timeline.TimelineResult`).

The discrete-event simulator *models* the paper's headline metric — the
fraction of CPU time each process spends waiting for communication.  The
asynchronous executor *measures* it: every worker thread accounts the
wall-clock time it spends executing compute payloads (busy), blocked
inside channel operations (comm wait), and idle with an empty ready
queue (dependency wait).  :class:`WaitStats` exposes the same properties
and ``summary()`` layout as ``TimelineResult`` so the two can be printed
side by side in the paper tables.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["WorkerStats", "WaitStats"]


@dataclass
class WorkerStats:
    """Per-worker accounting (mirrors ``ProcStats``).  ``compute_busy``
    is per-thread CPU time (GIL/scheduler preemption excluded);
    ``comm_busy`` and ``idle`` are wall-clock — being blocked is the
    thing measured."""

    compute_busy: float = 0.0  # executing compute payloads (CPU time)
    comm_busy: float = 0.0  # blocked inside channel ops (blocking mode)
    idle: float = 0.0  # ready queue empty, waiting on dependencies
    n_compute: int = 0
    n_comm: int = 0
    n_wakeups: int = 0  # queue pops (one per batch under batched dispatch)
    n_steals: int = 0  # successful steal attempts (batches taken)
    n_stolen: int = 0  # ops obtained by stealing from loaded peers

    def absorb(self, other: "WorkerStats") -> None:
        self.compute_busy += other.compute_busy
        self.comm_busy += other.comm_busy
        self.idle += other.idle
        self.n_compute += other.n_compute
        self.n_comm += other.n_comm
        self.n_wakeups += other.n_wakeups
        self.n_steals += other.n_steals
        self.n_stolen += other.n_stolen

    def snapshot(self) -> "WorkerStats":
        """Value copy, taken by the persistent executor at submit time so
        each drain's stats are a delta, not the lifetime totals."""
        return WorkerStats(
            compute_busy=self.compute_busy,
            comm_busy=self.comm_busy,
            idle=self.idle,
            n_compute=self.n_compute,
            n_comm=self.n_comm,
            n_wakeups=self.n_wakeups,
            n_steals=self.n_steals,
            n_stolen=self.n_stolen,
        )

    def since(self, base: "WorkerStats") -> "WorkerStats":
        """Per-drain delta: current totals minus a ``snapshot()``."""
        return WorkerStats(
            compute_busy=self.compute_busy - base.compute_busy,
            comm_busy=self.comm_busy - base.comm_busy,
            idle=self.idle - base.idle,
            n_compute=self.n_compute - base.n_compute,
            n_comm=self.n_comm - base.n_comm,
            n_wakeups=self.n_wakeups - base.n_wakeups,
            n_steals=self.n_steals - base.n_steals,
            n_stolen=self.n_stolen - base.n_stolen,
        )


@dataclass
class WaitStats:
    """Aggregated measured timeline of one (or several merged) flushes."""

    mode: str  # "async" | "blocking-channel"
    nworkers: int
    elapsed: float = 0.0  # wall-clock duration of the drain(s)
    procs: list[WorkerStats] = field(default_factory=list)
    comm_bytes: int = 0
    n_comm_ops: int = 0
    n_compute_ops: int = 0
    seq_time: float = 0.0  # Σ measured compute durations = 1-worker time
    n_flushes: int = 0
    # dispatch-overhead counters (plan-stage batching/coalescing wins)
    n_handoffs: int = 0  # producer→worker queue pushes (wakeup requests)
    n_messages: int = 0  # messages posted on the transfer channel

    def __post_init__(self):
        if not self.procs:
            self.procs = [WorkerStats() for _ in range(self.nworkers)]

    # -- paper metrics (same contract as TimelineResult) ------------------
    @property
    def makespan(self) -> float:
        return self.elapsed

    @property
    def total_compute(self) -> float:
        return sum(p.compute_busy for p in self.procs)

    @property
    def wait_fraction(self) -> float:
        """Measured fraction of worker time not spent computing.  Time
        blocked in synchronous channel calls counts as waiting, exactly as
        blocking communication does in the simulated metric."""
        if self.elapsed <= 0:
            return 0.0
        total = self.nworkers * self.elapsed
        return max(0.0, 1.0 - self.total_compute / total)

    @property
    def cpu_utilization(self) -> float:
        return 1.0 - self.wait_fraction

    @property
    def speedup(self) -> float:
        """Measured speedup vs. draining every compute payload on one
        worker (Σ compute durations / wall-clock)."""
        return self.seq_time / self.elapsed if self.elapsed > 0 else 0.0

    @property
    def comm_wait_fraction(self) -> float:
        """Share of worker time blocked specifically inside channel ops."""
        if self.elapsed <= 0:
            return 0.0
        return sum(p.comm_busy for p in self.procs) / (self.nworkers * self.elapsed)

    def merge(self, other: "WaitStats") -> "WaitStats":
        """Accumulate a later flush (flushes are serialized, so wall-clock
        durations add).

        Merging stats from runs with different worker counts pads
        ``procs`` to the wider of the two — ``zip`` would silently drop
        the extra workers' accounting (and misattribute rank i of one
        run to rank i of the other being the *same* thread, which they
        are not across runtimes; per-rank rows after a mixed merge are
        positional sums, the totals are exact)."""
        if other.nworkers > self.nworkers:
            self.procs.extend(
                WorkerStats() for _ in range(other.nworkers - self.nworkers)
            )
            self.nworkers = other.nworkers
        self.elapsed += other.elapsed
        self.comm_bytes += other.comm_bytes
        self.n_comm_ops += other.n_comm_ops
        self.n_compute_ops += other.n_compute_ops
        self.seq_time += other.seq_time
        self.n_flushes += max(1, other.n_flushes)
        self.n_handoffs += other.n_handoffs
        self.n_messages += other.n_messages
        for mine, theirs in zip(self.procs, other.procs):
            mine.absorb(theirs)
        return self

    @property
    def n_steals(self) -> int:
        """Successful work-steal batches across all workers."""
        return sum(p.n_steals for p in self.procs)

    @property
    def n_stolen(self) -> int:
        """Ops moved between workers by stealing."""
        return sum(p.n_stolen for p in self.procs)

    @property
    def ops_per_sec(self) -> float:
        """Measured dispatch throughput: operations drained per
        wall-clock second."""
        total = self.n_compute_ops + self.n_comm_ops
        return total / self.elapsed if self.elapsed > 0 else 0.0

    @property
    def handoffs_per_flush(self) -> float:
        """Worker-queue pushes per flush — the lock+event round trips
        that batched dispatch amortizes."""
        return self.n_handoffs / max(1, self.n_flushes)

    @property
    def messages_per_flush(self) -> float:
        """Messages posted on the transfer channel per flush — what
        transfer coalescing reduces."""
        return self.n_messages / max(1, self.n_flushes)

    def summary(self) -> str:
        return (
            f"[{self.mode:>14s}] makespan={self.elapsed * 1e3:9.3f} ms "
            f"wait={self.wait_fraction * 100:5.1f}% "
            f"speedup={self.speedup:6.2f} "
            f"comm={self.comm_bytes / 1e6:8.2f} MB "
            f"ops={self.n_compute_ops}c/{self.n_comm_ops}m "
            f"handoffs={self.n_handoffs} msgs={self.n_messages}"
        )

    def per_worker_table(self) -> str:
        lines = [f"{'worker':>6s} {'compute ms':>11s} {'comm-wait ms':>13s} "
                 f"{'idle ms':>9s} {'ops':>9s} {'wakeups':>8s}"]
        for i, p in enumerate(self.procs):
            lines.append(
                f"{i:6d} {p.compute_busy * 1e3:11.3f} {p.comm_busy * 1e3:13.3f} "
                f"{p.idle * 1e3:9.3f} {p.n_compute:4d}c/{p.n_comm:3d}m "
                f"{p.n_wakeups:8d}"
            )
        return "\n".join(lines)
