"""Sharded checkpoint store with async save, atomic commit and keep-N GC.

Scale-out design (1000+ hosts):

* **One file per host-shard** — every host serializes only the leaves (or
  leaf-slices) it owns; there is no single-writer bottleneck and restore
  is embarrassingly parallel.  On a real pod the ``shard_id`` is
  ``jax.process_index()``; the tests exercise multi-shard layouts in one
  process.
* **Atomic commit** — shards are written to ``step_N.tmp/``; a manifest
  (leaf treedef, shapes, dtypes, shard map, integrity checksums) is
  written last and the directory is atomically renamed to ``step_N/``.
  A crash mid-save never corrupts the latest valid checkpoint.
* **Async save** — serialization happens on a background thread from a
  host-side snapshot (``jax.device_get`` at call time), so the train loop
  resumes immediately (save latency hides behind compute — the paper's
  scheduling idea applied to I/O).
* **keep-N GC** — old steps are deleted after a successful commit.

Format: a tiny tagged binary per leaf (dtype/shape header + raw bytes) —
no external deps, zlib-crc verified.
"""
from __future__ import annotations

import json
import os
import shutil
import struct
import threading
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_checkpoint", "restore_latest"]

_MAGIC = b"RPRC\x01"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _write_leaf(fh, arr: np.ndarray) -> dict:
    data = np.ascontiguousarray(arr)
    raw = data.tobytes()
    crc = zlib.crc32(raw)
    hdr = json.dumps(
        {"dtype": str(data.dtype), "shape": list(data.shape), "crc": crc}
    ).encode()
    fh.write(_MAGIC)
    fh.write(struct.pack("<I", len(hdr)))
    fh.write(hdr)
    fh.write(struct.pack("<Q", len(raw)))
    fh.write(raw)
    return {"dtype": str(data.dtype), "shape": list(data.shape), "crc": crc}


def _read_leaf(fh) -> np.ndarray:
    magic = fh.read(5)
    if magic != _MAGIC:
        raise IOError(f"bad leaf magic {magic!r}")
    (hlen,) = struct.unpack("<I", fh.read(4))
    hdr = json.loads(fh.read(hlen))
    (rlen,) = struct.unpack("<Q", fh.read(8))
    raw = fh.read(rlen)
    if zlib.crc32(raw) != hdr["crc"]:
        raise IOError("checkpoint leaf CRC mismatch")
    return np.frombuffer(raw, dtype=np.dtype(hdr["dtype"])).reshape(hdr["shape"])


class CheckpointManager:
    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        shard_id: int = 0,
        n_shards: int = 1,
        is_primary: Optional[bool] = None,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.is_primary = (shard_id == 0) if is_primary is None else is_primary
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        """Snapshot ``tree`` (host transfer now) and serialize it
        asynchronously.  Raises any error from the *previous* async save."""
        self.wait()  # one in-flight save at a time; surfaces prior errors
        snapshot = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                self._write(step, snapshot)
            except BaseException as e:  # pragma: no cover
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _write(self, step: int, snapshot) -> None:
        tmp = self.dir / f"step_{step:012d}.tmp"
        final = self.dir / f"step_{step:012d}"
        tmp.mkdir(parents=True, exist_ok=True)
        leaves = _leaf_paths(snapshot)
        # this host writes its assigned leaves (round-robin by index)
        manifest = {"step": step, "n_shards": self.n_shards, "leaves": {}}
        with open(tmp / f"shard_{self.shard_id:05d}.bin", "wb") as fh:
            for i, (name, leaf) in enumerate(leaves):
                if i % self.n_shards != self.shard_id:
                    continue
                meta = _write_leaf(fh, leaf)
                manifest["leaves"][name] = {"index": i, **meta}
        with open(tmp / f"manifest_{self.shard_id:05d}.json", "w") as fh:
            json.dump(manifest, fh)
        # commit: all shards present (single-process tests write them all
        # into the same tmp dir; on a pod a barrier precedes the rename)
        done = len(list(tmp.glob("manifest_*.json")))
        if done >= self.n_shards and self.is_primary:
            os.replace(tmp, final)
            self._gc()

    def _gc(self) -> None:
        steps = sorted(self._steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- restore ------------------------------------------------------------
    def _steps(self) -> list[int]:
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        ]

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return max(steps) if steps else None

    def restore(self, tree_like, step: Optional[int] = None):
        """Restore into the structure of ``tree_like``; returns (tree, step).
        Reads every shard file (each host needs only its own leaves on a
        real pod; here we reassemble the full tree)."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:012d}"
        names = [name for name, _ in _leaf_paths(tree_like)]
        by_name: dict[str, np.ndarray] = {}
        for mf in sorted(d.glob("manifest_*.json")):
            manifest = json.loads(mf.read_text())
            shard = mf.name.replace("manifest", "shard").replace(".json", ".bin")
            with open(d / shard, "rb") as fh:
                for name in sorted(
                    manifest["leaves"], key=lambda n: manifest["leaves"][n]["index"]
                ):
                    by_name[name] = _read_leaf(fh)
        missing = [n for n in names if n not in by_name]
        if missing:
            raise IOError(f"checkpoint {d} missing leaves: {missing[:5]}...")
        flat, tdef = jax.tree.flatten(tree_like)
        restored = [
            np.asarray(by_name[n]).astype(l.dtype).reshape(l.shape)
            if hasattr(l, "dtype")
            else by_name[n]
            for n, l in zip(names, flat)
        ]
        return jax.tree.unflatten(tdef, restored), step


def save_checkpoint(directory, step: int, tree, **kw) -> None:
    CheckpointManager(directory, **kw).save(step, tree, blocking=True)


def restore_latest(directory, tree_like, **kw):
    return CheckpointManager(directory, **kw).restore(tree_like)
