"""repro.checkpoint — sharded, async, fault-tolerant checkpoints."""
from .store import CheckpointManager, restore_latest, save_checkpoint

__all__ = ["CheckpointManager", "save_checkpoint", "restore_latest"]
