"""RWKV6 "Finch" block — data-dependent decay linear recurrence, pure jnp.

Time-mix (wkv) is computed with a *chunked* algorithm: intra-chunk
contributions are dense einsums (MXU-friendly), the [hd_k, hd_v] state is
carried across chunks by a short ``lax.scan`` — same structure as the SSD
scan and the jnp twin of ``repro.kernels.rwkv6_wkv``.

Per head (head size N), with per-channel data-dependent decay w_t ∈ (0,1):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Faithful-to-Finch details kept: token-shift ddlerp with low-rank (LoRA)
data-dependent mixes for r/k/v/w/g, decay w = exp(-exp(w0 + lora(x_w))),
per-head bonus u, per-head group-norm on the wkv output, silu gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = [
    "rwkv6_init",
    "rwkv6_apply",
    "rwkv6_step",
    "init_rwkv6_state",
    "wkv_chunked",
    "wkv_step",
]

LORA_R = 32  # low-rank dim of the ddlerp / decay LoRAs


def rwkv6_init(key, cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    N = cfg.rwkv_head_size
    H = D // N
    ks = jax.random.split(key, 16)
    dt = cfg.jparam_dtype
    r = LORA_R
    return {
        # time-mix
        "mu_x": jnp.full((5, D), 0.5, dt),  # base lerp for r,k,v,w,g probes
        "lora_A": dense_init(ks[0], (5, D, r), dtype=dt),
        "lora_B": dense_init(ks[1], (5, r, D), dtype=dt),
        "w0": jnp.full((D,), -0.6, jnp.float32),  # decay bias (w ≈ 0.58)
        "wA": dense_init(ks[2], (D, r), dtype=dt),
        "wB": dense_init(ks[3], (r, D), dtype=dt),
        "u": dense_init(ks[4], (H, N), scale=0.5, dtype=jnp.float32),
        "Wr": dense_init(ks[5], (D, D), dtype=dt),
        "Wk": dense_init(ks[6], (D, D), dtype=dt),
        "Wv": dense_init(ks[7], (D, D), dtype=dt),
        "Wg": dense_init(ks[8], (D, D), dtype=dt),
        "Wo": dense_init(ks[9], (D, D), dtype=dt),
        "ln_g": jnp.ones((D,), dt),
        "ln_b": jnp.zeros((D,), dt),
        # channel-mix
        "cm_mu": jnp.full((2, D), 0.5, dt),  # k, r mixes
        "Wck": dense_init(ks[10], (D, F), dtype=dt),
        "Wcv": dense_init(ks[11], (F, D), dtype=dt),
        "Wcr": dense_init(ks[12], (D, D), dtype=dt),
        # pre-norms (RWKV uses LayerNorm before each mixer)
        "ln1_g": jnp.ones((D,), dt),
        "ln1_b": jnp.zeros((D,), dt),
        "ln2_g": jnp.ones((D,), dt),
        "ln2_b": jnp.zeros((D,), dt),
    }


def init_rwkv6_state(cfg, batch: int, n_layers: int):
    D = cfg.d_model
    N = cfg.rwkv_head_size
    H = D // N
    return {
        "shift_tm": jnp.zeros((n_layers, batch, D), cfg.jdtype),
        "shift_cm": jnp.zeros((n_layers, batch, D), cfg.jdtype),
        "wkv": jnp.zeros((n_layers, batch, H, N, N), jnp.float32),
    }


# ---------------------------------------------------------------------------
# chunked wkv
# ---------------------------------------------------------------------------

def wkv_chunked(r, k, v, w, u, *, chunk: int, init_state=None):
    """r,k,v: [B,T,H,N]; w: [B,T,H,N] decay in (0,1); u: [H,N] bonus.
    Returns (y [B,T,H,N], final_state [B,H,N,N])."""
    B, T, H, N = r.shape
    pad = (-T) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    S = r.shape[1]
    nc = S // chunk
    f32 = lambda a: a.astype(jnp.float32)
    rc = f32(r).reshape(B, nc, chunk, H, N)
    kc = f32(k).reshape(B, nc, chunk, H, N)
    vc = f32(v).reshape(B, nc, chunk, H, N)
    logw = jnp.log(jnp.maximum(f32(w), 1e-12)).reshape(B, nc, chunk, H, N)
    cum = jnp.cumsum(logw, axis=2)  # Π_{τ<=t} w_τ, log-space (<= 0)
    cumprev = cum - logw  # exclusive: Π_{τ<t} w_τ (y_t sees S_{t-1})

    # intra-chunk: y_t += Σ_{j<t} Σ_i r_t[i]·decay(t,j)[i]·k_j[i]·v_j
    # decay(t, j) applies w_{j+1..t-1} = exp(cumprev_t - cum_j)
    dec = jnp.exp(
        jnp.clip(cumprev[:, :, :, None, :, :] - cum[:, :, None, :, :, :], -60.0, 0.0)
    )  # [B,nc,t,j,H,N]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)  # strict j < t
    att = jnp.einsum("bzthn,bztjhn,bzjhn->bztjh", rc, dec, kc)
    att = att * tri[None, None, :, :, None]
    y = jnp.einsum("bztjh,bzjhn->bzthn", att, vc)
    # diagonal (j == t) with bonus u
    diag = jnp.einsum("bzthn,hn,bzthn->bzth", rc, u, kc)
    y = y + diag[..., None] * vc

    # chunk-final states: S_chunk = diag(exp(cum_C)) S_prev
    #                      + Σ_j (k_j ⊙ exp(cum_C - cum_j)) v_jᵀ
    k_dec = kc * jnp.exp(jnp.clip(cum[:, :, -1:, :, :] - cum, -60.0, 0.0))
    s_local = jnp.einsum("bzjhn,bzjhm->bzhnm", k_dec, vc)  # [B,nc,H,N,N]
    chunk_dec = jnp.exp(jnp.clip(cum[:, :, -1, :, :], -60.0, 0.0))  # [B,nc,H,N]

    s0 = (
        jnp.zeros((B, H, N, N), jnp.float32)
        if init_state is None
        else f32(init_state)
    )

    def body(carry, inp):
        sl, cd = inp
        new = carry * cd[..., None] + sl
        return new, carry

    fin, prev = jax.lax.scan(
        body, s0, (s_local.transpose(1, 0, 2, 3, 4), chunk_dec.transpose(1, 0, 2, 3))
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # state entering each chunk

    # inter-chunk: y_t += (r_t ⊙ exp(cumprev_t)) · S_prev — the pre-chunk
    # state reaching step t has decayed by w_{1..t-1}
    r_dec = rc * jnp.exp(jnp.clip(cumprev, -60.0, 0.0))
    y = y + jnp.einsum("bzthn,bzhnm->bzthm", r_dec, prev)

    y = y.reshape(B, S, H, N)
    if pad:
        y = y[:, :T]
    return y.astype(r.dtype), fin


def wkv_step(state, r_t, k_t, v_t, w_t, u):
    """One token.  state: [B,H,N,N]; r/k/v/w_t: [B,H,N]; u: [H,N]."""
    f32 = lambda a: a.astype(jnp.float32)
    r_t, k_t, v_t, w_t = map(f32, (r_t, k_t, v_t, w_t))
    kv = jnp.einsum("bhn,bhm->bhnm", k_t, v_t)
    y = jnp.einsum("bhn,bhnm->bhm", r_t, state + u[None, :, :, None] * kv)
    new = state * w_t[..., None] + kv
    return y, new


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------

def _group_norm(y, g, b, H, N, eps=64e-5):
    """Per-head LayerNorm (RWKV 'ln_x'), y: [..., H*N]."""
    shp = y.shape
    y = y.reshape(*shp[:-1], H, N).astype(jnp.float32)
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*shp)
    return y * g.astype(jnp.float32) + b.astype(jnp.float32)


def _ddlerp(p, x, xx):
    """Data-dependent lerp producing the 5 probe inputs [5, B, T, D].
    xx = shifted(x) - x."""
    base = x + xx * p["mu_x"][:, None, None, :]  # [5, B, T, D] via broadcast
    lo = jnp.tanh(jnp.einsum("sbtd,sdr->sbtr", base, p["lora_A"].astype(x.dtype)))
    mix = p["mu_x"][:, None, None, :] + jnp.einsum(
        "sbtr,srd->sbtd", lo, p["lora_B"].astype(x.dtype)
    )
    return x[None] + xx[None] * mix


def _time_mix(cfg, p, x, shifted, wkv_state, *, chunk=None):
    B, T, D = x.shape
    N = cfg.rwkv_head_size
    H = D // N
    xx = shifted - x
    probes = _ddlerp(p, x, xx)  # [5(r,k,v,w,g), B, T, D]
    xr, xk, xv, xw, xg = probes
    r = (xr @ p["Wr"].astype(x.dtype)).reshape(B, T, H, N)
    k = (xk @ p["Wk"].astype(x.dtype)).reshape(B, T, H, N)
    v = (xv @ p["Wv"].astype(x.dtype)).reshape(B, T, H, N)
    g = jax.nn.silu(xg @ p["Wg"].astype(x.dtype))
    ww = p["w0"] + jnp.einsum(
        "btr,rd->btd", jnp.tanh(xw @ p["wA"].astype(x.dtype)), p["wB"].astype(x.dtype)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, T, H, N)  # decay ∈ (0,1)

    if T == 1 and wkv_state is not None:
        y, new_state = wkv_step(
            wkv_state, r[:, 0], k[:, 0], v[:, 0], w[:, 0], p["u"]
        )
        y = y[:, None]
    else:
        y, new_state = wkv_chunked(
            r, k, v, w, p["u"], chunk=chunk or 64, init_state=wkv_state
        )
    y = _group_norm(y.reshape(B, T, D), p["ln_g"], p["ln_b"], H, N)
    out = (y * g.astype(jnp.float32)).astype(x.dtype) @ p["Wo"].astype(x.dtype)
    return out, new_state


def _channel_mix(p, x, shifted):
    xx = shifted - x
    xk = x + xx * p["cm_mu"][0].astype(x.dtype)
    xr = x + xx * p["cm_mu"][1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["Wck"].astype(x.dtype)))
    return jax.nn.sigmoid(xr @ p["Wcr"].astype(x.dtype)) * (kk @ p["Wcv"].astype(x.dtype))


def _shift(x, last):
    """shifted[t] = x[t-1]; shifted[0] = last (carried state)."""
    return jnp.concatenate([last[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def rwkv6_apply(cfg, p, x, *, state=None):
    """Full block (pre-LN → time-mix → residual → pre-LN → channel-mix →
    residual).  x: [B, T, D] → (y, new_state{shift_tm, shift_cm, wkv}).
    The shift states hold the *normed* last token (mixers see LN'd input)."""
    from .layers import layernorm

    B, T, D = x.shape
    if state is None:
        last_tm = jnp.zeros((B, D), x.dtype)
        last_cm = jnp.zeros((B, D), x.dtype)
        wkv0 = None
    else:
        last_tm, last_cm, wkv0 = state["shift_tm"], state["shift_cm"], state["wkv"]
    a = layernorm(x, p["ln1_g"], p["ln1_b"])
    tm, new_wkv = _time_mix(cfg, p, a, _shift(a, last_tm), wkv0, chunk=cfg.ssm_chunk)
    x = x + tm
    b = layernorm(x, p["ln2_g"], p["ln2_b"])
    cm = _channel_mix(p, b, _shift(b, last_cm))
    y = x + cm
    new_state = {
        "shift_tm": a[:, -1, :],
        "shift_cm": b[:, -1, :],
        "wkv": new_wkv,
    }
    return y, new_state


def rwkv6_step(cfg, p, x_t, state):
    """Single token.  x_t: [B, 1, D]."""
    from .layers import layernorm

    a = layernorm(x_t, p["ln1_g"], p["ln1_b"])
    tm, new_wkv = _time_mix(
        cfg, p, a, state["shift_tm"][:, None, :].astype(x_t.dtype), state["wkv"]
    )
    h = x_t + tm
    b = layernorm(h, p["ln2_g"], p["ln2_b"])
    cm = _channel_mix(p, b, state["shift_cm"][:, None, :].astype(x_t.dtype))
    y = h + cm
    new_state = {
        "shift_tm": a[:, -1, :],
        "shift_cm": b[:, -1, :],
        "wkv": new_wkv,
    }
    return y, new_state
