"""Attention: GQA (+ sliding window) and MLA (DeepSeek latent), with
KV caches for decode.

The S×S score matrix is never materialized: ``chunked_attention`` runs an
online-softmax over KV chunks (lax.scan), keeping live memory at
O(S·chunk) — this is the jnp twin of the Pallas flash kernel in
``repro.kernels.flash_attention`` and is the path used for dry-run
lowering (Pallas targets real TPUs; XLA fuses this path on any backend).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, dense_init, linear

__all__ = [
    "attn_init",
    "attention",
    "chunked_attention",
    "init_kv_cache",
    "mla_init",
    "mla_attention",
    "init_mla_cache",
]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core: online-softmax attention over KV chunks
# ---------------------------------------------------------------------------

def chunked_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, KV, hd]
    v: jax.Array,  # [B, Sk, KV, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,  # scalar or [B] — global position of q[0]
    kv_len=None,  # scalar or [B] — #valid cache entries (None = Sk)
    k_positions=None,  # [B, Sk] explicit global key positions (ring caches);
    # overrides the linear arange — entries < 0 are masked out.
    chunk: int = 1024,
    scale: Optional[float] = None,
    unroll: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hdv = v.shape[-1]  # value head dim may differ (MLA)
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale

    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_positions is not None:
            k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (Sk + pad) // chunk

    q_offset = jnp.asarray(q_offset)
    kv_len = jnp.asarray(Sk if kv_len is None else kv_len)
    q_pos = q_offset[..., None] + jnp.arange(Sq)  # [B?, Sq]
    q_pos = jnp.broadcast_to(q_pos, (B, Sq))

    qr = (q.reshape(B, Sq, KV, G, hd) * scale).astype(jnp.float32)
    ks = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, chunk, KV, hdv).transpose(1, 0, 2, 3, 4)
    if k_positions is not None:
        kp = k_positions.reshape(B, n_chunks, chunk).transpose(1, 0, 2)  # [nc, B, C]

    def body(carry, inp):
        m, l, acc = carry
        if k_positions is not None:
            c_idx, kc, vc, kpc = inp
        else:
            c_idx, kc, vc = inp
        # scores: [B, KV, G, Sq, C]
        s = jnp.einsum("bqkgd,bckd->bkgqc", qr, kc.astype(jnp.float32))
        if k_positions is not None:
            k_pos = kpc  # [B, C] explicit global positions
            ok = k_pos[:, None, :] >= 0  # [B, 1(Sq), C]
        else:
            k_pos = jnp.broadcast_to(c_idx * chunk + jnp.arange(chunk), (B, chunk))
            valid = k_pos < jnp.broadcast_to(kv_len, (B,))[:, None]  # [B, C]
            ok = valid[:, None, :]  # [B, 1(Sq), C]
        if causal:
            ok = ok & (q_pos[:, :, None] >= k_pos[:, None, :])
        if window is not None:
            ok = ok & (q_pos[:, :, None] - k_pos[:, None, :] < window)
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hdv), jnp.float32)
    xs = (
        (jnp.arange(n_chunks), ks, vs, kp)
        if k_positions is not None
        else (jnp.arange(n_chunks), ks, vs)
    )
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs, unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------

def attn_init(key, cfg, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.jparam_dtype
    return {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dt),
        "wk": dense_init(ks[1], (D, KV * hd), dtype=dt),
        "wv": dense_init(ks[2], (D, KV * hd), dtype=dt),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dt),
    }


def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int, stacked=True):
    KV, hd = cfg.n_kv_heads, cfg.hd
    shape = (n_layers, batch, max_len, KV, hd) if stacked else (batch, max_len, KV, hd)
    return {
        "k": jnp.zeros(shape, cfg.jdtype),
        "v": jnp.zeros(shape, cfg.jdtype),
    }


def attention(
    cfg,
    p: dict,
    x: jax.Array,  # [B, S, D]
    *,
    positions=None,  # [B, S] or None -> arange
    causal: bool = True,
    window: Optional[int] = None,
    rope: bool = True,
    kv_from: Optional[jax.Array] = None,  # cross-attention source [B, Se, D]
    cache: Optional[dict] = None,  # {"k","v"} [B, L_max, KV, hd]
    cache_pos=None,  # [B] write offset for this step
):
    """Returns (out [B,S,D], new_cache or None)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_from is None else kv_from
    q = linear(x, p["wq"]).reshape(B, S, H, hd)
    k = linear(src, p["wk"]).reshape(B, src.shape[1], KV, hd)
    v = linear(src, p["wv"]).reshape(B, src.shape[1], KV, hd)

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if rope and kv_from is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # scatter this step's K/V at cache_pos (decode: S == 1)
        def put(buf, new):
            return jax.vmap(
                lambda b, n, i: jax.lax.dynamic_update_slice_in_dim(b, n, i, axis=0)
            )(buf, new, cache_pos)

        ck, cv = put(cache["k"], k), put(cache["v"], v)
        new_cache = {"k": ck, "v": cv}
        kv_len = cache_pos + S
        out = chunked_attention(
            q, ck, cv,
            causal=causal, window=window,
            q_offset=cache_pos, kv_len=kv_len, chunk=cfg.attn_chunk,
            unroll=cfg.unroll_scans,
        )
    else:
        out = chunked_attention(
            q, k, v,
            causal=causal and kv_from is None, window=window,
            q_offset=positions[:, 0] * 0 if kv_from is not None else 0,
            chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
        )
    return linear(out.reshape(B, S, H * hd), p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    dt = cfg.jparam_dtype
    return {
        "wq": dense_init(ks[0], (D, H * (dn + dr)), dtype=dt),
        "wdkv": dense_init(ks[1], (D, r + dr), dtype=dt),  # c_kv + shared k_rope
        "wuk": dense_init(ks[2], (r, H * dn), dtype=dt),
        "wuv": dense_init(ks[3], (r, H * dv), dtype=dt),
        "wo": dense_init(ks[4], (H * dv, D), dtype=dt),
    }


def init_mla_cache(cfg, batch: int, max_len: int, n_layers: int):
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    return {"ckv": jnp.zeros((n_layers, batch, max_len, r + dr), cfg.jdtype)}


def mla_attention(cfg, p, x, *, positions=None, cache=None, cache_pos=None):
    """MLA forward.  Prefill/train: expand the latent to per-head K/V and
    run chunked attention.  Decode (cache path): *absorbed* form — queries
    are projected into the latent space so the per-token cache stays
    ``r + rope_dim`` wide and is never expanded (the MLA contribution)."""
    B, S, D = x.shape
    H = cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    q = linear(x, p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = linear(x, p["wdkv"])  # [B, S, r + dr]
    ckv, k_rope = dkv[..., :r], dkv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    scale = (dn + dr) ** -0.5

    if cache is None:
        k_nope = linear(ckv, p["wuk"]).reshape(B, S, H, dn)
        vv = linear(ckv, p["wuv"]).reshape(B, S, H, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1
        )
        qc = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(
            qc, k, vv, causal=True, chunk=cfg.attn_chunk, scale=scale,
            unroll=cfg.unroll_scans,
        )
        return linear(out.reshape(B, S, H * dv), p["wo"]), None

    # --- absorbed decode path ------------------------------------------------
    new = jnp.concatenate([ckv, k_rope], axis=-1)  # [B, S, r+dr]
    buf = jax.vmap(
        lambda b, n, i: jax.lax.dynamic_update_slice_in_dim(b, n, i, axis=0)
    )(cache["ckv"], new, cache_pos)
    kv_len = cache_pos + S
    L = buf.shape[1]
    c_all, kr_all = buf[..., :r], buf[..., r:]

    # absorb W_uk into q:  q_lat[b,s,h,r] = q_nope · W_uk[·,h,·]
    wuk = p["wuk"].reshape(r, H, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32), wuk.astype(jnp.float32))
    s_lat = jnp.einsum("bshr,blr->bhsl", q_lat, c_all.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,bld->bhsl", q_rope.astype(jnp.float32), kr_all.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    k_pos = jnp.arange(L)
    q_pos = cache_pos[:, None] + jnp.arange(S)
    ok = (k_pos[None, None, :] < kv_len[:, None, None]) & (
        q_pos[:, :, None] >= k_pos[None, None, :]
    )
    s = jnp.where(ok[:, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhsl,blr->bshr", w, c_all.astype(jnp.float32))  # [B,S,H,r]
    wuv = p["wuv"].reshape(r, H, dv)
    out = jnp.einsum("bshr,rhd->bshd", o_lat, wuv.astype(jnp.float32)).astype(x.dtype)
    return linear(out.reshape(B, S, H * dv), p["wo"]), {"ckv": buf}
