"""Mixture-of-Experts FFN: top-k token-choice routing with capacity
(GShard-style dense dispatch einsums — shardable under GSPMD with experts
on the "model" axis), plus DeepSeek-style always-on shared experts.

Latency-hiding tie-in (paper §5.4): the routed path's dispatch einsum is
the *communication* (it lowers to all-to-all / collective matmuls when
experts are sharded); the shared-expert branch is pure local compute with
no dependency on the dispatch — emitted between dispatch and combine so
XLA overlaps it with the in-flight collective, exactly the paper's
"compute local sub-view-blocks while remote blocks are in transfer".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, linear, mlp_init, mlp_apply

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg) -> dict:
    D = cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    dt = cfg.jparam_dtype
    p = {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), dtype=dt),
        "w_in": dense_init(ks[2], (E, D, F), dtype=dt),
        "w_out": dense_init(ks[3], (E, F, D), dtype=dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], D, cfg.n_shared_experts * F, "silu", dt)
    return p


def _route(cfg, router_w, xf):
    """Top-k routing.  Returns (weights [T,K], expert_idx [T,K], aux_loss)."""
    logits = (xf.astype(jnp.float32) @ router_w).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)  # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch/GShard load-balance loss: E * Σ_e fraction_e · prob_e
    E = cfg.n_experts
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    f = onehot.mean(0)
    pmean = probs.mean(0)
    aux = E * jnp.sum(f * pmean)
    return gate, idx, aux


def _dispatch_group(cfg, p, xg, gate, idx):
    """One token group through the routed experts.

    xg: [T, D]; gate/idx: [T, K].  Capacity per expert
    C = ceil(T·K/E · capacity_factor); overflow drops (standard).
    """
    T, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(T * K / E * cfg.capacity_factor))

    # position of each (token, choice) within its expert's capacity buffer
    eo = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # [T, K, E]
    flat = eo.reshape(T * K, E)
    pos = jnp.cumsum(flat, axis=0) - 1  # [T*K, E]
    pos = (pos * flat).sum(-1).reshape(T, K)  # [T, K] position in expert
    keep = pos < C
    gate = gate * keep

    # dispatch one-hots: [T, K, E] expert and [T, K, C] slot
    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=xg.dtype)  # OOB → all-zero row
    eoh = eo.astype(xg.dtype)
    # COMM: build expert inputs [E, C, D] (lowers to a2a/collective matmul
    # when E is model-sharded and T is data-sharded)
    xe = jnp.einsum("tke,tkc,td->ecd", eoh, slot, xg)
    # expert FFN (runs where the experts live)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xg.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(xg.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(xg.dtype))
    # COMM: combine back to token order, weighted by the gate
    comb = jnp.einsum("tke,tkc,tk->tec", eoh, slot, gate.astype(xg.dtype))
    return jnp.einsum("tec,ecd->td", comb, ye)


def moe_apply(cfg, p, x, *, group_size=None):
    """x: [B, S, D] → (y, aux_loss)."""
    B, S, D = x.shape
    if group_size is None:
        group_size = cfg.moe_group_size
    xf = x.reshape(B * S, D)
    T = xf.shape[0]
    gate, idx, aux = _route(cfg, p["router"], xf)

    g = min(group_size, T)
    n_groups = (T + g - 1) // g
    pad = n_groups * g - T
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        gate = jnp.pad(gate, ((0, pad), (0, 0)))
        idx = jnp.pad(idx, ((0, pad), (0, 0)))

    if n_groups == 1:
        routed = _dispatch_group(cfg, p, xf, gate, idx)
    else:
        xs = xf.reshape(n_groups, g, D)
        gs = gate.reshape(n_groups, g, -1)
        ids = idx.reshape(n_groups, g, -1)
        if cfg.unroll_scans:
            # cost-pass: unrolled so the compiled artifact counts every
            # group's dispatch (lax.map bodies are costed once by XLA)
            routed = jnp.concatenate(
                [_dispatch_group(cfg, p, xs[i], gs[i], ids[i]) for i in range(n_groups)]
            )
        else:
            routed = jax.lax.map(
                lambda a: _dispatch_group(cfg, p, a[0], a[1], a[2]), (xs, gs, ids)
            ).reshape(n_groups * g, D)

    # local branch — independent of the dispatched collective, so XLA can
    # overlap it with the routed path (paper §5.4)
    if "shared" in p:
        routed = routed[:T] + mlp_apply(p["shared"], x.reshape(T, D), "silu")
    else:
        routed = routed[:T]
    return routed.reshape(B, S, D), aux
