"""Model assembly: every assigned architecture is one ``ModelConfig``.

The layer pattern (``cfg.pattern``) is compiled into *segments* so the HLO
stays small and scan-friendly:

* If the pattern is periodic (``body × reps``, e.g. zamba2's ``MMMMMH``×9)
  the whole trunk is ONE ``lax.scan`` over reps whose body runs the
  period's blocks in order (params stacked ``[reps, ...]``).
* Otherwise maximal same-letter runs become segments (deepseek-v2:
  ``D``×1 then ``E``×26 — the 26 MoE layers are one scan).

Block letters:  ``A``/``D`` attention+MLP · ``E`` attention+MoE ·
``M`` mamba2 · ``R`` rwkv6 · ``H`` zamba2 hybrid (one *shared* attention
block applied before the layer's own mamba mixer).

Decode state mirrors the segment structure: ``state["segs"][i]`` is the
pytree for segment ``i`` with leading dims ``[reps]``(+body position).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    attention,
    attn_init,
    chunked_attention,
    mla_attention,
    mla_init,
)
from .layers import dense_init, layernorm, linear, mlp_apply, mlp_init, rmsnorm
from .mamba2 import init_mamba2_state, mamba2_apply, mamba2_init, mamba2_step
from .moe import moe_apply, moe_init
from .hints import shard_hint
from .rwkv6 import init_rwkv6_state, rwkv6_apply, rwkv6_init, rwkv6_step

_MAMBA_STATE_KEYS = ("conv_x", "conv_B", "conv_C", "ssm")

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "make_decode_state",
    "plan_segments",
    "Segment",
]


# ---------------------------------------------------------------------------
# pattern → segments
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    body: str  # block letters executed per rep, in order
    reps: int  # leading axis of the stacked params / scan length
    scan: bool  # lax.scan over reps (False: reps == 1, run inline)


def plan_segments(cfg) -> tuple[Segment, ...]:
    pat = cfg.pattern
    n = len(pat)
    # smallest period p with pat == pat[:p] * (n // p)
    for p in range(1, n + 1):
        if n % p == 0 and pat == pat[:p] * (n // p):
            break
    if n // p > 1:
        return (Segment(pat[:p], n // p, cfg.scan_layers),)
    # fall back to maximal same-letter runs
    segs = []
    i = 0
    while i < n:
        j = i
        while j < n and pat[j] == pat[i]:
            j += 1
        segs.append(Segment(pat[i], j - i, cfg.scan_layers and (j - i) > 1))
        i = j
    return tuple(segs)


# ---------------------------------------------------------------------------
# per-letter block init / apply
# ---------------------------------------------------------------------------


def _block_init(cfg, letter: str, key) -> dict:
    dt = cfg.jparam_dtype
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    if letter in ("A", "D", "E"):
        attn_p = (
            mla_init(ks[0], cfg) if cfg.attn_impl == "mla" else attn_init(ks[0], cfg)
        )
        p = {"ln1": jnp.ones((D,), dt), "attn": attn_p, "ln2": jnp.ones((D,), dt)}
        if letter == "E":
            p["moe"] = moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], D, cfg.d_ff, cfg.act, dt)
        if cfg.enc_dec:  # decoder cross-attention
            p["lnx"] = jnp.ones((D,), dt)
            p["xattn"] = attn_init(ks[2], cfg, cross=True)
        return p
    if letter == "M":
        return {"ln": jnp.ones((D,), dt), "mamba": mamba2_init(ks[0], cfg)}
    if letter == "H":
        return {"ln": jnp.ones((D,), dt), "mamba": mamba2_init(ks[0], cfg)}
    if letter == "R":
        return rwkv6_init(ks[0], cfg)
    raise ValueError(f"unknown block letter {letter!r}")


def _block_state(cfg, letter: str, batch: int, max_len: int):
    """Decode-state template for ONE block (no leading reps axis)."""
    if letter in ("A", "D", "E", "H"):
        KV, hd = cfg.n_kv_heads, cfg.hd
        if cfg.attn_impl == "mla":
            att = {
                "ckv": jnp.zeros(
                    (batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                    cfg.jdtype,
                )
            }
        else:
            L = max_len
            if cfg.swa_window is not None:
                L = min(max_len, cfg.swa_window)  # ring buffer
            att = {
                "k": jnp.zeros((batch, L, KV, hd), cfg.jdtype),
                "v": jnp.zeros((batch, L, KV, hd), cfg.jdtype),
            }
        if letter == "H":
            m = init_mamba2_state(cfg, batch, 1)
            return {"att": att, **{k: m[k][0] for k in _MAMBA_STATE_KEYS}}
        return {"att": att}
    if letter == "M":
        m = init_mamba2_state(cfg, batch, 1)
        return {k: m[k][0] for k in _MAMBA_STATE_KEYS}
    if letter == "R":
        r = init_rwkv6_state(cfg, batch, 1)
        return {k: v[0] for k, v in r.items()}
    raise ValueError(letter)


def _attn_block(cfg, p, x, *, pos, cache, shared=None, enc_out=None, window=None):
    """Pre-norm attention(+cross)+FFN block.  Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["ln1"])
    cache_pos = None if cache is None else cache.get("pos")
    if cfg.attn_impl == "mla":
        a, new_att = mla_attention(
            cfg, p["attn"], h,
            positions=pos,
            cache=None if cache is None else cache["att"],
            cache_pos=cache_pos,
        )
    else:
        # ring iff the cache was allocated at window size (static — the
        # allocation in _block_state is min(max_len, window))
        ring = (
            cache is not None
            and cfg.swa_window is not None
            and cache["att"]["k"].shape[1] == cfg.swa_window
        )
        a, new_att = _gqa(
            cfg, p["attn"], h,
            pos=pos, cache=None if cache is None else cache["att"],
            cache_pos=cache_pos, window=window, ring=ring,
        )
    x = x + a
    if cfg.enc_dec and enc_out is not None:
        hx = rmsnorm(x, p["lnx"])
        c, _ = attention(cfg, p["xattn"], hx, causal=False, rope=False, kv_from=enc_out)
        x = x + c
    h2 = rmsnorm(x, p["ln2"])
    if "moe" in p:
        m, aux = moe_apply(cfg, p["moe"], h2)
    else:
        hint = (
            (lambda h: shard_hint(h, "dp", None, "model"))
            if cfg.act_sharding
            else None
        )
        m = mlp_apply(p["mlp"], h2, cfg.act, hint=hint)
    return x + m, new_att, aux


def _gqa(cfg, p, x, *, pos, cache, cache_pos, window, ring):
    """GQA attention with optional ring-buffer KV cache (SWA decode)."""
    from .layers import apply_rope

    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(x, p["wq"]).reshape(B, S, H, hd)
    k = linear(x, p["wk"]).reshape(B, S, KV, hd)
    v = linear(x, p["wv"]).reshape(B, S, KV, hd)
    if cfg.act_sharding:
        q = shard_hint(q, "dp", None, "model", None)
        k = shard_hint(k, "dp", None, "model", None)
        v = shard_hint(v, "dp", None, "model", None)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    if cache is None:
        out = chunked_attention(
            q, k, v, causal=True, window=window, chunk=cfg.attn_chunk,
            unroll=cfg.unroll_scans,
        )
        return linear(out.reshape(B, S, H * hd), p["wo"]), None

    L = cache["k"].shape[1]
    if ring:
        # ring-buffer cache (SWA): global position p lives at slot p % L.
        if S > 1:
            # prefill into a ring (cache assumed empty, cache_pos == 0):
            # attend the full fresh K/V, cache only the last L tokens.
            out = chunked_attention(
                q, k, v, causal=True, window=window,
                q_offset=cache_pos, chunk=cfg.attn_chunk,
                unroll=cfg.unroll_scans,
            )
            tail = min(S, L)
            kt, vt = k[:, -tail:], v[:, -tail:]
            slots = (cache_pos[:, None] + S - tail + jnp.arange(tail)[None, :]) % L
            scatter = lambda buf, new: jax.vmap(
                lambda b, n, i: b.at[i].set(n)
            )(buf, new, slots)
            ck, cv = scatter(cache["k"], kt), scatter(cache["v"], vt)
        else:
            slot = cache_pos % L  # [B]
            write = lambda buf, new: jax.vmap(
                lambda b, n, i: jax.lax.dynamic_update_slice_in_dim(b, n, i, axis=0)
            )(buf, new, slot)
            ck, cv = write(cache["k"], k), write(cache["v"], v)
            idx = jnp.arange(L)
            k_pos = cache_pos[:, None] - (cache_pos[:, None] - idx[None, :]) % L
            out = chunked_attention(
                q, ck, cv, causal=True, window=window,
                q_offset=cache_pos, k_positions=k_pos, chunk=cfg.attn_chunk,
                unroll=cfg.unroll_scans,
            )
    else:
        write = lambda buf, new: jax.vmap(
            lambda b, n, i: jax.lax.dynamic_update_slice_in_dim(b, n, i, axis=0)
        )(buf, new, cache_pos)
        ck, cv = write(cache["k"], k), write(cache["v"], v)
        out = chunked_attention(
            q, ck, cv, causal=True, window=window,
            q_offset=cache_pos, kv_len=cache_pos + S, chunk=cfg.attn_chunk,
            unroll=cfg.unroll_scans,
        )
    new_cache = {"k": ck, "v": cv}
    return linear(out.reshape(B, S, H * hd), p["wo"]), new_cache


def _apply_block(cfg, letter, p, x, *, pos, st, shared, enc_out):
    """Run one block.  ``st``: None (train) or this block's decode state
    (with st["pos"]/st["max_len"] injected).  Returns (x, new_st, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if letter in ("A", "D", "E"):
        cache = None
        if st is not None:
            cache = {"att": st["att"], "pos": st["pos"]}
        x, new_att, aux = _attn_block(
            cfg, p, x, pos=pos, cache=cache, enc_out=enc_out, window=cfg.swa_window
        )
        return x, ({"att": new_att} if st is not None else None), aux
    if letter == "M":
        h = rmsnorm(x, p["ln"])
        if st is None:
            m, _ = mamba2_apply(cfg, p["mamba"], h)
            return x + m, None, aux
        if x.shape[1] == 1:
            m, new = mamba2_step(cfg, p["mamba"], h, {k: st[k] for k in _MAMBA_STATE_KEYS})
        else:
            m, new = mamba2_apply(cfg, p["mamba"], h, init_state={k: st[k] for k in _MAMBA_STATE_KEYS})
        return x + m, new, aux
    if letter == "H":
        # shared attention block first (zamba2), then own mamba mixer
        cache = None
        if st is not None:
            cache = {"att": st["att"], "pos": st["pos"]}
        x, new_att, aux = _attn_block(
            cfg, shared, x, pos=pos, cache=cache, window=cfg.swa_window
        )
        h = rmsnorm(x, p["ln"])
        if st is None:
            m, _ = mamba2_apply(cfg, p["mamba"], h)
            return x + m, None, aux
        if x.shape[1] == 1:
            m, new = mamba2_step(cfg, p["mamba"], h, {k: st[k] for k in _MAMBA_STATE_KEYS})
        else:
            m, new = mamba2_apply(cfg, p["mamba"], h, init_state={k: st[k] for k in _MAMBA_STATE_KEYS})
        return x + m, {"att": new_att, **new}, aux
    if letter == "R":
        if st is None:
            y, _ = rwkv6_apply(cfg, p, x)
            return y, None, aux
        if x.shape[1] == 1:
            y, new = rwkv6_step(cfg, p, x, st)
        else:
            y, new = rwkv6_apply(cfg, p, x, state=st)
        return y, new, aux
    raise ValueError(letter)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg, key) -> dict:
    ks = jax.random.split(key, 8)
    dt = cfg.jparam_dtype
    D, V = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {
        "embed": dense_init(ks[0], (V, D), scale=0.02, dtype=dt),
        "final_norm": jnp.ones((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[1], (D, V), dtype=dt)

    segs = plan_segments(cfg)
    seg_params = []
    for si, seg in enumerate(segs):
        kseg = jax.random.fold_in(ks[2], si)

        def body_init(k):
            kb = jax.random.split(k, len(seg.body))
            return {
                f"{j}{letter}": _block_init(cfg, letter, kb[j])
                for j, letter in enumerate(seg.body)
            }

        if seg.reps == 1:
            seg_params.append(body_init(kseg))
        else:
            seg_params.append(jax.vmap(body_init)(jax.random.split(kseg, seg.reps)))
    params["segs"] = seg_params

    if "H" in cfg.pattern:  # zamba2's single shared attention+MLP block
        params["shared_attn"] = _block_init(cfg.replace(enc_dec=False), "A", ks[3])
    if cfg.enc_dec:
        enc_cfg = cfg.replace(enc_dec=False, n_layers=cfg.n_enc_layers, layer_pattern="A")

        def enc_init(k):
            return _block_init(enc_cfg, "A", k)

        params["encoder"] = {
            "blocks": jax.vmap(enc_init)(jax.random.split(ks[4], cfg.n_enc_layers)),
            "norm": jnp.ones((D,), dt),
        }
    if cfg.n_img_tokens:
        params["img_norm"] = jnp.ones((D,), dt)  # VLM stub: normalize patch embs
    return params


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _sinusoid(S, D):
    pos = np.arange(S)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / D)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32
    )


def _encode(cfg, params, frames):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend per assignment).  frames: [B, Se, D]."""
    x = frames.astype(cfg.jdtype) + _sinusoid(frames.shape[1], cfg.d_model).astype(
        cfg.jdtype
    )
    enc_cfg = cfg.replace(enc_dec=False)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, p):
        h = rmsnorm(x, p["ln1"])
        a, _ = attention(enc_cfg, p["attn"], h, causal=False, rope=False)
        x = x + a
        h2 = rmsnorm(x, p["ln2"])
        return x + mlp_apply(p["mlp"], h2, cfg.act), None

    if cfg.scan_layers and not cfg.unroll_scans:
        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(lambda c, p: fn(c, p), x, params["encoder"]["blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            p_i = jax.tree.map(lambda a: a[i], params["encoder"]["blocks"])
            x, _ = body(x, p_i)
    return rmsnorm(x, params["encoder"]["norm"])


def _trunk(cfg, params, x, *, pos, state=None, enc_out=None):
    """Run all segments.  state: None or {"segs": [...], "pos": [B],
    "max_len": int}.  Returns (x, new_state, aux_total)."""
    segs = plan_segments(cfg)
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    new_seg_states = []

    for si, seg in enumerate(segs):
        p_seg = params["segs"][si]
        st_seg = None if state is None else state["segs"][si]

        def body(carry, inp):
            x, aux = carry
            p_rep, st_rep = inp
            new_st_rep = {} if st_rep is not None else None
            for j, letter in enumerate(seg.body):
                key = f"{j}{letter}"
                st_b = None
                if st_rep is not None:
                    st_b = dict(st_rep[key])
                    st_b["pos"] = state["pos"]
                x, new_b, aux_b = _apply_block(
                    cfg, letter, p_rep[key], x,
                    pos=pos, st=st_b, shared=shared, enc_out=enc_out,
                )
                if cfg.act_sharding:
                    x = shard_hint(x, "dp", None, None)
                aux = aux + aux_b
                if st_rep is not None:
                    new_st_rep[key] = new_b
            return (x, aux), new_st_rep

        if seg.reps == 1:
            (x, aux_total), new_st = body((x, aux_total), (p_seg, st_seg))
        elif seg.scan and not cfg.unroll_scans:
            fn = body
            if cfg.remat and state is None:
                fn = jax.checkpoint(body)
            (x, aux_total), new_st = jax.lax.scan(
                fn, (x, aux_total), (p_seg, st_seg)
            )
        else:
            new_st_list = []
            fn = body
            if cfg.remat and state is None:
                fn = jax.checkpoint(body)
            for r in range(seg.reps):
                p_r = jax.tree.map(lambda a: a[r], p_seg)
                st_r = None if st_seg is None else jax.tree.map(lambda a: a[r], st_seg)
                (x, aux_total), new_r = fn((x, aux_total), (p_r, st_r))
                new_st_list.append(new_r)
            new_st = (
                jax.tree.map(lambda *a: jnp.stack(a), *new_st_list)
                if st_seg is not None
                else None
            )
        new_seg_states.append(new_st)

    new_state = None
    if state is not None:
        new_state = {
            "segs": new_seg_states,
            "pos": state["pos"] + x.shape[1],
        }
    return x, new_state, aux_total


def _embed_inputs(cfg, params, batch, *, pos_offset=0):
    """tokens (+ modality stub embeddings) → (x, positions)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"].astype(cfg.jdtype)[tokens]
    if cfg.n_img_tokens and "img_emb" in batch:
        img = rmsnorm(batch["img_emb"].astype(cfg.jdtype), params["img_norm"])
        x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
    if isinstance(pos_offset, int) and pos_offset == 0:
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    else:
        pos = jnp.asarray(pos_offset)[:, None] + jnp.arange(S)[None, :]
    return x, pos


def forward(cfg, params, batch):
    """Training/prefill forward (no state).  Returns (logits, aux)."""
    x, pos = _embed_inputs(cfg, params, batch)
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(cfg, params, batch["enc_frames"])
    x, _, aux = _trunk(cfg, params, x, pos=pos, enc_out=enc_out)
    x = rmsnorm(x, params["final_norm"])
    if cfg.n_img_tokens and "img_emb" in batch:
        x = x[:, batch["img_emb"].shape[1] :]  # logits for text positions only
    un = (
        params["embed"].astype(cfg.jdtype).T
        if cfg.tie_embeddings
        else params["unembed"].astype(cfg.jdtype)
    )
    return x @ un, aux


def loss_fn(cfg, params, batch):
    """Next-token cross-entropy (+ MoE aux).  Returns (loss, metrics).

    With ``cfg.vocab_parallel_loss`` the gold logit is extracted by a
    one-hot masked sum and logsumexp is built from per-shard max/sum —
    both reduce the model-sharded vocab dim to per-token scalars, so
    GSPMD emits tiny [B,S] all-reduces instead of materializing a full
    replicated f32 logits tensor (a ~13 GB/device all-reduce at the
    granite train_4k cell — §Perf iteration 1)."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    if cfg.vocab_parallel_loss:
        lf = shard_hint(lf, "dp", None, "model")
        m = jax.lax.stop_gradient(lf.max(axis=-1))
        logz = m + jnp.log(jnp.exp(lf - m[..., None]).sum(axis=-1))
        onehot = (
            jnp.arange(cfg.vocab_size, dtype=labels.dtype)[None, None, :]
            == labels[..., None]
        )
        gold = jnp.where(onehot, lf, 0.0).sum(axis=-1)
    else:
        logz = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    nll = ((logz - gold) * mask).sum() / denom
    loss = nll + cfg.router_aux_weight * aux
    return loss, {"nll": nll, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def make_decode_state(cfg, batch_size: int, max_len: int, *, start_pos=None):
    """Empty decode state for ``serve_step`` (and the decode dry-runs):
    per-segment caches shaped [reps(+body), ...]."""
    segs = plan_segments(cfg)
    seg_states = []
    for seg in segs:
        body_state = {
            f"{j}{letter}": _block_state(cfg, letter, batch_size, max_len)
            for j, letter in enumerate(seg.body)
        }
        if seg.reps > 1:
            body_state = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.reps, *a.shape)), body_state
            )
        seg_states.append(body_state)
    pos = (
        jnp.zeros((batch_size,), jnp.int32)
        if start_pos is None
        else jnp.asarray(start_pos, jnp.int32)
    )
    state = {"segs": seg_states, "pos": pos}
    if cfg.enc_dec:
        state["enc_out"] = jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model), cfg.jdtype)
    return state


def prefill(cfg, params, batch, max_len: int):
    """Run the prompt through the model filling caches.
    Returns (last_logits [B, V], state).  ``max_len`` is the total cache
    capacity; modality prefixes (VLM image tokens) count toward it."""
    B, S = batch["tokens"].shape
    x, pos = _embed_inputs(cfg, params, batch)
    state = make_decode_state(cfg, B, max(max_len, x.shape[1]))
    enc_out = _encode(cfg, params, batch["enc_frames"]) if cfg.enc_dec else None
    x, state, _ = _trunk(cfg, params, x, pos=pos, state=state, enc_out=enc_out)
    x = rmsnorm(x[:, -1:, :], params["final_norm"])
    un = (
        params["embed"].astype(cfg.jdtype).T
        if cfg.tie_embeddings
        else params["unembed"].astype(cfg.jdtype)
    )
    if cfg.enc_dec:
        state["enc_out"] = enc_out
    return (x @ un)[:, 0], state


def decode_step(cfg, params, tokens, state):
    """One decode step.  tokens: [B] int32 → (logits [B, V], new state)."""
    B = tokens.shape[0]
    x = params["embed"].astype(cfg.jdtype)[tokens][:, None, :]
    pos = state["pos"][:, None]
    enc_out = state.get("enc_out")
    x, new_state, _ = _trunk(cfg, params, x, pos=pos, state=state, enc_out=enc_out)
    if enc_out is not None:
        new_state["enc_out"] = enc_out
    x = rmsnorm(x, params["final_norm"])
    un = (
        params["embed"].astype(cfg.jdtype).T
        if cfg.tie_embeddings
        else params["unembed"].astype(cfg.jdtype)
    )
    return (x @ un)[:, 0], new_state
