"""repro.models — the LM substrate every assigned architecture runs on.

Pure-functional JAX: parameters are pytrees of ``jnp`` arrays built by
``init_params(cfg, key)``; the forward passes are plain functions of
``(cfg, params, inputs)``.  Distribution is applied from the outside by
``repro.launch.sharding`` (pjit in_shardings over the param tree) — the
model code itself is single-program and mesh-agnostic, except where it
deliberately calls the paper's overlapped collectives.
"""
from .model import (
    decode_step,
    init_params,
    loss_fn,
    make_decode_state,
    prefill,
    forward,
)

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "make_decode_state",
]
