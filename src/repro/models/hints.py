"""Activation-sharding hints (cfg.act_sharding — §Perf optimization).

``shard_hint(x, "dp", None, "model")`` pins a traced activation to the
named mesh axes via ``with_sharding_constraint`` — resolved against the
AMBIENT abstract mesh at trace time, with divisibility fallback, and a
silent no-op outside a mesh context (keeps every non-distributed call
site working unchanged).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["shard_hint"]


def shard_hint(x, *spec):
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = tuple(mesh.axis_names)
    except Exception:
        return x
    if not names:
        return x
    resolved = [None] * len(spec)
    used = set()
    for dim, s in enumerate(spec):
        if s is None:
            continue
        if s == "dp":
            axes = tuple(a for a in ("pod", "data") if a in names and a not in used)
        else:
            axes = (s,) if (s in names and s not in used) else ()
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if not axes or n == 0 or x.shape[dim] % n or x.shape[dim] < n:
            continue
        used.update(axes)
        resolved[dim] = axes[0] if len(axes) == 1 else axes
    # fallback: if "model" was requested but its dim didn't divide (e.g.
    # yi-34b's 56 heads on a 16-way axis), try the NEXT dim to the right
    # (the per-head feature dim) so TP still applies.
    if "model" in [s for s in spec] and "model" not in used and "model" in names:
        want = list(spec).index("model")
        m = mesh.shape["model"]
        for dim in list(range(want + 1, len(spec))) + list(range(want - 1, 0, -1)):
            if resolved[dim] is None and x.shape[dim] % m == 0 and x.shape[dim] >= m:
                resolved[dim] = "model"
                break
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x
