"""Common neural-net layers (pure jnp, params as dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "rmsnorm",
    "layernorm",
    "linear",
    "mlp_init",
    "mlp_apply",
    "rope_freqs",
    "apply_rope",
]


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


def rmsnorm(x, gamma, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layernorm(x, gamma, beta, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def linear(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def mlp_init(key, d_model, d_ff, act, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }
    if act == "silu":  # gated (SwiGLU)
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp_apply(p, x, act="silu", hint=None):
    if act == "silu":
        h = jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_in"])
    else:
        h = jax.nn.gelu(linear(x, p["w_in"]))
    if hint is not None:
        h = hint(h)
    return linear(h, p["w_out"])


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta=1e4):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)
