"""Mamba2 (SSD) mixer — chunked state-space-dual scan, pure jnp.

Used by zamba2 (hybrid 'M'/'H' layers).  The chunked SSD algorithm
(Dao & Gu 2024) splits the sequence into chunks: intra-chunk outputs are
dense matmuls (MXU-friendly), inter-chunk state is carried by a short
``lax.scan`` over chunks — this is the jnp twin of the Pallas kernel in
``repro.kernels.mamba2_scan``.

TP note: the projections are kept *separate* (w_z/w_x/w_B/w_C/w_dt
rather than one fused in_proj) so the head dimension (``d_in``) shards
cleanly over the "model" mesh axis — per-head SSD states never cross
shards, B/C (state projections, shared across heads) stay replicated,
and the only TP collective is the out-projection psum.  This is the
hardware adaptation of the paper's "aligned arrays need no
communication" observation.

Latency-hiding tie-in: under sequence-parallel execution the chunk-final
states are the only cross-shard dependency; ``repro.comm`` ships them via
a ppermute ring while each shard's intra-chunk matmuls (the bulk of the
FLOPs) proceed locally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm

__all__ = [
    "mamba2_init",
    "mamba2_apply",
    "mamba2_step",
    "init_mamba2_state",
    "ssd_chunked",
    "ssd_step",
]


def mamba2_init(key, cfg) -> dict:
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    ks = jax.random.split(key, 9)
    dt = cfg.jparam_dtype
    K = cfg.ssm_conv
    return {
        "w_z": dense_init(ks[0], (D, d_in), dtype=dt),
        "w_x": dense_init(ks[1], (D, d_in), dtype=dt),
        "w_B": dense_init(ks[2], (D, n), dtype=dt),
        "w_C": dense_init(ks[3], (D, n), dtype=dt),
        "w_dt": dense_init(ks[4], (D, nh), dtype=dt),
        "conv_x": dense_init(ks[5], (K, d_in), dtype=dt),
        "conv_B": dense_init(ks[6], (K, n), dtype=dt),
        "conv_C": dense_init(ks[7], (K, n), dtype=dt),
        "conv_x_b": jnp.zeros((d_in,), dt),
        "conv_B_b": jnp.zeros((n,), dt),
        "conv_C_b": jnp.zeros((n,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_g": jnp.ones((d_in,), dt),
        "w_out": dense_init(ks[8], (d_in, D), dtype=dt),
    }


def _segsum(x):
    """Stable 'segment sum' producing the lower-triangular decay matrix:
    out[i, j] = sum_{k=j+1..i} x[k]  (for j < i), -inf above diagonal."""
    T = x.shape[-1]
    x = jnp.broadcast_to(x[..., None], (*x.shape, T))  # [..., i, j] = x[i]
    mask = jnp.tril(jnp.ones((T, T), bool), -1)
    x = jnp.where(mask, x, 0)
    x_seg = jnp.cumsum(x, axis=-2)
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, x_seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int, init_state=None):
    """Chunked SSD scan.

    x:  [b, s, h, p]   (inputs, p = head dim)
    dt: [b, s, h]      (softplus'd step sizes, >0)
    A:  [h]            (negative decay rates)
    B:  [b, s, n]      (input projection, shared across heads; ngroups=1)
    C:  [b, s, n]      (output projection)
    init_state: [b, h, p, n] or None.
    Returns (y [b, s, h, p], final_state [b, h, p, n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = x.shape[1]
    nc = S // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]  # [b, nc, c, h] (negative)
    dA_cum = jnp.cumsum(dA, axis=2)

    # ---- intra-chunk (dense, MXU): Y_diag = (L ⊙ C Bᵀ) · (dt x)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # [b, nc, h, c, c]
    scores = jnp.einsum("bzcn,bzln->bzcl", Cc, Bc)  # [b, nc, c(l_q), c(l_k)]
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # [b, nc, c, h, p]
    y_diag = jnp.einsum("bzhcl,bzcl,bzlhp->bzchp", L, scores, xdt)

    # ---- chunk-final states: states_z = Σ_l exp(dA_cum[-1]-dA_cum[l]) B_l x_l
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # [b, nc, c, h]
    states = jnp.einsum("bzln,bzlh,bzlhp->bzhpn", Bc, decay_states * dtc, xc.astype(jnp.float32))

    # ---- inter-chunk recurrence over nc (short scan)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # [b, nc, h]
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(carry, inp):
        st_z, dec_z = inp  # [b,h,p,n], [b,h]
        new = carry * dec_z[..., None, None] + st_z
        return new, carry  # emit the state *entering* this chunk

    fin, prev_states = jax.lax.scan(
        body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b, nc, h, p, n]

    # ---- inter-chunk output: y_off = C_l · (decay_in[l] * prev_state)
    state_decay_in = jnp.exp(dA_cum)  # [b, nc, c, h]
    y_off = jnp.einsum("bzcn,bzhpn,bzch->bzchp", Cc, prev_states, state_decay_in)

    y = (y_diag + y_off).reshape(b, S, h, p)
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), fin


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """One recurrent step.  state: [b,h,p,n]; x_t: [b,h,p]; dt_t: [b,h];
    B_t, C_t: [b,n].  Returns (y_t [b,h,p], new_state)."""
    dA = jnp.exp(dt_t.astype(jnp.float32) * A)  # [b, h]
    dBx = jnp.einsum(
        "bn,bh,bhp->bhpn", B_t.astype(jnp.float32), dt_t.astype(jnp.float32), x_t.astype(jnp.float32)
    )
    new = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new


def init_mamba2_state(cfg, batch: int, n_layers: int):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    K = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((n_layers, batch, K - 1, d_in), cfg.jdtype),
        "conv_B": jnp.zeros((n_layers, batch, K - 1, n), cfg.jdtype),
        "conv_C": jnp.zeros((n_layers, batch, K - 1, n), cfg.jdtype),
        "ssm": jnp.zeros((n_layers, batch, nh, cfg.ssm_head_dim, n), jnp.float32),
    }


def _causal_conv(x, w, b, hist):
    """Depthwise causal conv.  x: [B, S, C]; w: [K, C]; hist: [B, K-1, C]
    (zeros for fresh sequences).  Returns (y [B, S, C], new_hist)."""
    K = w.shape[0]
    S = x.shape[1]
    padded = jnp.concatenate([hist.astype(x.dtype), x], axis=1)  # [B, S+K-1, C]
    y = sum(padded[:, k : k + S, :] * w[k] for k in range(K)) + b
    return jax.nn.silu(y), padded[:, -(K - 1) :, :]


def mamba2_apply(cfg, p, x, *, init_state=None):
    """Full-sequence forward.  x: [B, S, D] → (y [B, S, D], final state)."""
    Bsz, S, D = x.shape
    d_in = cfg.ssm_expand * D
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    K = cfg.ssm_conv

    z = x @ p["w_z"].astype(x.dtype)
    xs = x @ p["w_x"].astype(x.dtype)
    Bm = x @ p["w_B"].astype(x.dtype)
    Cm = x @ p["w_C"].astype(x.dtype)
    dt = x @ p["w_dt"].astype(x.dtype)

    zeros = lambda c: jnp.zeros((Bsz, K - 1, c), x.dtype)
    hx = zeros(d_in) if init_state is None else init_state["conv_x"]
    hB = zeros(n) if init_state is None else init_state["conv_B"]
    hC = zeros(n) if init_state is None else init_state["conv_C"]
    xs, new_hx = _causal_conv(xs, p["conv_x"].astype(x.dtype), p["conv_x_b"].astype(x.dtype), hx)
    Bm, new_hB = _causal_conv(Bm, p["conv_B"].astype(x.dtype), p["conv_B_b"].astype(x.dtype), hB)
    Cm, new_hC = _causal_conv(Cm, p["conv_C"].astype(x.dtype), p["conv_C_b"].astype(x.dtype), hC)

    xs = xs.reshape(Bsz, S, nh, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, S, nh]
    A = -jnp.exp(p["A_log"])  # [nh]

    y, fin = ssd_chunked(
        xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
        init_state=None if init_state is None else init_state["ssm"],
    )
    y = y + xs * p["D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"])
    out = y @ p["w_out"].astype(y.dtype)
    state = {"conv_x": new_hx, "conv_B": new_hB, "conv_C": new_hC, "ssm": fin}
    return out, state


def mamba2_step(cfg, p, x_t, state):
    """Single-token decode.  x_t: [B, 1, D]."""
    Bsz = x_t.shape[0]
    D = x_t.shape[-1]
    d_in = cfg.ssm_expand * D
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    xt = x_t[:, 0, :]

    z = xt @ p["w_z"].astype(xt.dtype)
    xs = xt @ p["w_x"].astype(xt.dtype)
    Bm = xt @ p["w_B"].astype(xt.dtype)
    Cm = xt @ p["w_C"].astype(xt.dtype)
    dt = xt @ p["w_dt"].astype(xt.dtype)

    def conv1(v, w, b, hist):
        window = jnp.concatenate([hist, v[:, None, :].astype(hist.dtype)], axis=1)  # [B, K, C]
        y = jnp.einsum("bkc,kc->bc", window, w.astype(window.dtype)) + b.astype(window.dtype)
        return jax.nn.silu(y), window[:, 1:, :]

    xs, new_hx = conv1(xs, p["conv_x"], p["conv_x_b"], state["conv_x"])
    Bm, new_hB = conv1(Bm, p["conv_B"], p["conv_B_b"], state["conv_B"])
    Cm, new_hC = conv1(Cm, p["conv_C"], p["conv_C_b"], state["conv_C"])

    xs = xs.reshape(Bsz, nh, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, nh]
    A = -jnp.exp(p["A_log"])

    y, new_ssm = ssd_step(state["ssm"], xs, dt, A, Bm, Cm)
    y = y + xs * p["D"].astype(xs.dtype)[None, :, None]
    y = y.reshape(Bsz, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"])
    out = (y @ p["w_out"].astype(y.dtype))[:, None, :]
    return out, {"conv_x": new_hx, "conv_B": new_hB, "conv_C": new_hC, "ssm": new_ssm}
