"""Unified statistics rendering for simulated and measured runs.

``Runtime.stats()`` returns a
:class:`~repro.core.timeline.TimelineResult` (discrete-event model) or a
:class:`~repro.exec.stats.WaitStats` (wall-clock measurement).  Both
expose the same metric properties, but their ad-hoc ``summary()``
strings drifted apart; :func:`format_stats` renders any mix of the two
as one table with identical columns, units, and labels, tagging each
row ``simulated`` or ``measured`` — the single renderer used by the
benchmark driver's real-overlap section and the stencil example.
"""
from __future__ import annotations

__all__ = ["format_stats"]

_HEADER = (
    f"{'variant':<26s} {'source':>9s} {'makespan ms':>12s} {'wait%':>7s} "
    f"{'speedup':>8s} {'comm MB':>8s} {'ops c/m':>12s}"
)


def _source_of(stats) -> str:
    from repro.exec.stats import WaitStats

    # serve.TenantStats wraps a WaitStats in .wait (plus a latency
    # histogram); it renders as a measured row
    inner = getattr(stats, "wait", stats)
    return "measured" if isinstance(inner, WaitStats) else "simulated"


def format_stats(
    rows, header: bool = True, dispatch: bool = True, per_worker: bool = False
) -> str:
    """Render stats as an aligned table.

    ``rows`` is an iterable of ``(label, stats)`` pairs (a single pair
    also works), where each ``stats`` is a ``TimelineResult`` or a
    ``WaitStats``.  Columns: makespan in ms, waiting-on-communication
    share in %, speedup vs. sequential, communicated MB, and
    compute/comm operation counts — the paper's two metrics plus the
    volume columns, identical for both sources.

    With ``dispatch=True`` (default) a ``dispatch:`` line per row shows
    the dispatch-overhead counters: drained ops per second, ops drained
    per flush (= per readback under demand-driven sync, where every
    readback is one cone flush), worker handoffs per flush, and channel
    messages per flush — measured rows only carry the last two (the
    simulator has no worker queues), shown as ``-`` otherwise.

    With ``per_worker=True``, each measured row is followed by an
    indented per-worker breakdown (compute / comm-wait / idle per rank)
    so skew between workers is visible without a full trace; simulated
    rows have no worker threads and are skipped.
    """
    if isinstance(rows, tuple) and len(rows) == 2 and isinstance(rows[0], str):
        rows = [rows]
    rows = list(rows)
    lines = [_HEADER] if header else []
    for label, st in rows:
        lines.append(
            f"{label:<26s} {_source_of(st):>9s} {st.makespan * 1e3:12.1f} "
            f"{st.wait_fraction * 100:6.1f}% {st.speedup:8.2f} "
            f"{st.comm_bytes / 1e6:8.2f} "
            f"{st.n_compute_ops:>7d}/{st.n_comm_ops:<4d}"
        )
    if dispatch:
        for label, st in rows:
            # the stats objects own the arithmetic; the simulator has no
            # worker queues or channel, so those columns render as "-"
            ops_s = f"{st.ops_per_sec:,.0f}" if st.makespan > 0 else "-"
            nfl = getattr(st, "n_flushes", 0)
            opf = (
                f"{(st.n_compute_ops + st.n_comm_ops) / nfl:,.0f}"
                if nfl else "-"
            )
            nh = getattr(st, "handoffs_per_flush", None)
            nm = getattr(st, "messages_per_flush", None)
            hand = "-" if nh is None else f"{nh:,.0f}"
            msgs = "-" if nm is None else f"{nm:,.0f}"
            lines.append(
                f"dispatch: {label:<26s} ops/s={ops_s:>12s} "
                f"ops/flush={opf:>9s} "
                f"handoffs/flush={hand:>8s} msgs/flush={msgs:>8s}"
            )
    # request-latency quantiles: rows carrying a latency histogram
    # (serve.TenantStats) get a latency: line with p50/p95/p99 and the
    # admission counters — absent for plain stats objects
    for label, st in rows:
        hist = getattr(st, "latency", None)
        if hist is None or not getattr(hist, "count", 0):
            continue
        extra = ""
        n_rej = getattr(st, "n_rejected", 0)
        n_fail = getattr(st, "n_failed", 0)
        if n_rej or n_fail:
            extra = f" rejected={n_rej} failed={n_fail}"
        lines.append(
            f"latency:  {label:<26s} n={hist.count:<7d} "
            f"p50={hist.p50 * 1e3:8.2f}ms p95={hist.p95 * 1e3:8.2f}ms "
            f"p99={hist.p99 * 1e3:8.2f}ms max={hist.max * 1e3:8.2f}ms"
            + extra
        )
    if per_worker:
        for label, st in rows:
            table = getattr(st, "per_worker_table", None)
            if table is None:  # simulated stats: no worker threads
                continue
            lines.append(f"per-worker: {label}")
            lines.extend("  " + ln for ln in table().splitlines())
    return "\n".join(lines)
