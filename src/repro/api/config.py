"""Declarative runtime configuration: frozen config objects replacing the
``Runtime.__init__`` kwarg soup.

Two orthogonal objects describe a run:

* :class:`RuntimeConfig` — *what the arrays look like*: virtual process
  count, distribution block size, fusion, flush threshold.  These shape
  the recorded dependency graphs.
* :class:`ExecutionPolicy` — *how the graphs are drained*: the flush
  scheduler mode, simulated vs. measured flush backend, the compute
  backend / transfer channel (resolved through
  :mod:`repro.api.registry`), injected wire latency, and the modeled
  :class:`~repro.core.timeline.ClusterSpec`.

Both are frozen dataclasses validated at construction, with a
``.replace()`` that re-validates — so benchmarks and examples sweep
policies declaratively::

    base = ExecutionPolicy(flush="async", channel="async", latency=10e-3)
    for policy in (base, base.replace(channel="blocking")):
        with repro.runtime(policy=policy) as rt:
            ...

:func:`runtime` is the one-call entry point: keyword overrides are
routed to the right config object by field name.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Union

from repro.core.timeline import ClusterSpec

from . import registry

__all__ = ["RuntimeConfig", "ExecutionPolicy", "ServeConfig", "runtime"]


class _Replaceable:
    """``.replace()`` with validation: construction re-runs
    ``__post_init__``, so an invalid override fails loudly at the call
    site instead of at first flush."""

    def replace(self, **overrides):
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class RuntimeConfig(_Replaceable):
    """Array layout and recording behaviour (graph-shaping knobs)."""

    nprocs: int = 4
    block_size: Union[int, tuple] = 128
    fusion: bool = False
    flush_threshold: int = 200_000
    execute: bool = True

    def __post_init__(self):
        if self.nprocs < 1:
            raise ValueError(f"nprocs must be >= 1, got {self.nprocs}")
        if self.flush_threshold < 1:
            raise ValueError(
                f"flush_threshold must be >= 1, got {self.flush_threshold}"
            )
        bs = self.block_size
        sizes = (bs,) if isinstance(bs, int) else tuple(bs)
        if not sizes or any((not isinstance(s, int)) or s < 1 for s in sizes):
            raise ValueError(f"block_size must be positive int(s), got {bs!r}")


@dataclass(frozen=True)
class ExecutionPolicy(_Replaceable):
    """How recorded graphs are drained (schedule-shaping knobs).

    Names resolve through the plugin registries — a newly registered
    backend/channel/scheduler is immediately valid here.
    """

    scheduler: str = "latency_hiding"
    flush: str = "sim"  # "sim" (discrete-event model) | "async" (measured)
    backend: str = "numpy"  # compute backend (async flush only)
    channel: Optional[str] = None  # transfer channel; default follows scheduler
    latency: Union[float, str] = 0.0  # seconds per message, or "alpha"
    progress_threads: int = 2
    cluster: Optional[ClusterSpec] = None
    # plan-stage pass pipeline: "auto" (default pipeline under the async
    # flush, none under the simulator), a comma-separated string, or a
    # tuple of registered pass names (repro.register_pass)
    passes: Union[str, tuple] = "auto"
    # readback discipline: "demand" drains only the dependency cone of
    # the array being read (futures surface: repro.evaluate / gather /
    # wait), "barrier" drains the whole recorded graph on every readback
    # (the paper's §5.6 semantics — the escape hatch that keeps old
    # programs and all paper figures bit-identical).  "auto" = demand
    # under flush="async", barrier under the simulator.
    sync: str = "auto"
    # lifecycle tracing (repro.obs): False disables (the default — a true
    # no-op), True collects into a ring buffer inspectable via
    # ``Runtime.tracer``, a string additionally exports Chrome-trace JSON
    # to that path when the runtime closes.  REPRO_TRACE=1 (or =path)
    # enables it from the environment without touching the policy.
    trace: Union[bool, str] = False
    # static verification (repro.analysis): "off" trusts the pass
    # pipeline, "plan" proves every flush's planned op list preserves
    # the recorded happens-before order (§5.7) before it executes,
    # "full" additionally runs the region-level race oracle over
    # in-flight concurrent drains.  An error-severity finding raises
    # repro.analysis.VerificationError and aborts the flush.
    # REPRO_VERIFY=plan|full enables it from the environment.
    verify: str = "off"
    # work stealing on the async executor's worker pool (arXiv 1805.01768
    # regime): an idle worker steals from the longest peer queue holding
    # at least ``steal_threshold`` ops, and only when the expected work
    # moved (ops x measured task grain) exceeds ``steal_latency`` — the
    # round-trip cost of a steal.  Disable for strictly owner-computes
    # placement studies.
    steal: bool = True
    steal_threshold: int = 4
    steal_latency: float = 1e-4
    # plan-shape cache (repro.core.plan_cache): replay the recorded
    # rewrite recipe on cones whose canonical structure was planned (and
    # verified) before, skipping the pass pipeline and re-verification.
    # None defers to the REPRO_PLAN_CACHE env var (unset/1 = on,
    # 0/false/off = off); the cache only engages on demand-driven cone
    # flushes with a non-empty pass pipeline.
    plan_cache: Optional[bool] = None
    # cross-tenant cone batching: merge small, mutually non-conflicting
    # planned cones arriving from concurrent submitter threads into one
    # executor submission (one global-lock round and one dispatch sweep
    # for the whole group).  Async flush only.
    batch_cones: bool = False

    def __post_init__(self):
        if self.scheduler not in registry.SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r} "
                f"(registered: {', '.join(registry.available_schedulers())})"
            )
        if self.flush not in ("sim", "async"):
            raise ValueError(f"unknown flush {self.flush!r} (sim|async)")
        if self.backend not in registry.BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r} "
                f"(registered: {', '.join(registry.available_backends())})"
            )
        if self.channel is not None and self.channel not in registry.CHANNELS:
            raise ValueError(
                f"unknown channel {self.channel!r} "
                f"(registered: {', '.join(registry.available_channels())})"
            )
        if self.sync not in ("auto", "demand", "barrier"):
            raise ValueError(
                f"unknown sync {self.sync!r} (auto|demand|barrier)"
            )
        if self.verify not in ("off", "plan", "full"):
            raise ValueError(
                f"unknown verify {self.verify!r} (off|plan|full)"
            )
        if isinstance(self.latency, str) and self.latency != "alpha":
            raise ValueError(
                f"latency must be seconds or 'alpha', got {self.latency!r}"
            )
        if self.progress_threads < 1:
            raise ValueError(
                f"progress_threads must be >= 1, got {self.progress_threads}"
            )
        if self.steal_threshold < 2:
            raise ValueError(
                f"steal_threshold must be >= 2 (a victim keeps at least "
                f"one op), got {self.steal_threshold}"
            )
        if self.steal_latency < 0:
            raise ValueError(
                f"steal_latency must be >= 0 seconds, got {self.steal_latency}"
            )
        if not isinstance(self.trace, (bool, str)):
            raise ValueError(
                f"trace must be False, True, or an export path, got "
                f"{self.trace!r}"
            )
        if self.plan_cache not in (None, True, False):
            raise ValueError(
                f"plan_cache must be None (env default), True, or False, "
                f"got {self.plan_cache!r}"
            )
        if not isinstance(self.batch_cones, bool):
            raise ValueError(
                f"batch_cones must be a bool, got {self.batch_cones!r}"
            )
        p = self.passes
        if isinstance(p, (list, tuple)):
            p = tuple(p)
            object.__setattr__(self, "passes", p)  # normalize for hashing
        elif not isinstance(p, str):
            raise ValueError(
                f"passes must be 'auto', a comma-separated string or a "
                f"tuple of pass names, got {p!r}"
            )
        # one parser/validator for pipeline specs: the plan module's
        # (raises ValueError listing the registered passes on a typo)
        from repro.core.plan import resolve_pipeline

        resolve_pipeline(p, self.flush)

    @property
    def resolved_passes(self) -> tuple:
        """The concrete pass pipeline after resolving ``"auto"`` against
        the flush backend (the measured executor gets the default
        coalesce/fuse/batch pipeline, the simulator none)."""
        from repro.core.plan import resolve_pipeline

        return resolve_pipeline(self.passes, self.flush)

    @property
    def resolved_sync(self) -> str:
        """The readback discipline after resolving ``"auto"``: demand-
        driven cone flushes under the measured async backend, the
        paper's whole-graph barrier under the simulator."""
        if self.sync != "auto":
            return self.sync
        return "demand" if self.flush == "async" else "barrier"

    @property
    def resolved_channel(self) -> str:
        """The channel discipline after applying the scheduler default:
        latency-hiding uses the non-blocking progress engine, everything
        else the synchronous baseline."""
        if self.channel is not None:
            return self.channel
        return "async" if self.scheduler == "latency_hiding" else "blocking"


@dataclass(frozen=True)
class ServeConfig(_Replaceable):
    """Admission control for the multi-tenant serving runtime
    (:class:`repro.serve.Server`).

    ``max_inflight`` bounds the number of request cones draining
    concurrently on the shared worker pool; ``max_queue`` bounds the
    admission queue — a request arriving with the queue full is shed
    immediately with :class:`repro.serve.AdmissionError` (the clear
    rejection signal; clients retry with backoff).  ``admission_timeout``
    (seconds, ``None`` = wait forever) bounds how long an admitted-queue
    request may wait for an in-flight slot before it too is rejected."""

    max_inflight: int = 8
    max_queue: int = 64
    admission_timeout: Optional[float] = None

    def __post_init__(self):
        if self.max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {self.max_inflight}"
            )
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")
        if self.admission_timeout is not None and self.admission_timeout <= 0:
            raise ValueError(
                f"admission_timeout must be positive seconds or None, "
                f"got {self.admission_timeout}"
            )


_CONFIG_FIELDS = {f.name for f in dataclasses.fields(RuntimeConfig)}
_POLICY_FIELDS = {f.name for f in dataclasses.fields(ExecutionPolicy)}


def runtime(
    config: Optional[RuntimeConfig] = None,
    policy: Optional[ExecutionPolicy] = None,
    **overrides,
):
    """Build a :class:`~repro.core.engine.Runtime` from config objects —
    the ``with repro.runtime(...):`` entry point.

    Keyword overrides are routed by field name (``nprocs=8`` patches the
    :class:`RuntimeConfig`, ``backend="auto"`` the
    :class:`ExecutionPolicy`); an unknown name raises immediately with
    the valid fields listed.  The returned ``Runtime`` is a context
    manager; entering it activates it as the thread's current runtime.
    """
    from repro.core.engine import Runtime

    cfg_kw = {k: v for k, v in overrides.items() if k in _CONFIG_FIELDS}
    pol_kw = {k: v for k, v in overrides.items() if k in _POLICY_FIELDS}
    unknown = set(overrides) - _CONFIG_FIELDS - _POLICY_FIELDS
    if unknown:
        raise TypeError(
            f"unknown runtime option(s) {sorted(unknown)} — "
            f"RuntimeConfig fields: {sorted(_CONFIG_FIELDS)}, "
            f"ExecutionPolicy fields: {sorted(_POLICY_FIELDS)}"
        )
    config = (config or RuntimeConfig()).replace(**cfg_kw)
    policy = (policy or ExecutionPolicy()).replace(**pol_kw)
    return Runtime.from_config(config, policy)
