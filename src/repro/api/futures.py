"""Demand-driven evaluation surface: futures over distributed arrays.

The paper drains the lazily recorded graph only when a wait state is
unavoidable; this module makes that contract *explicit* in the public
API, JAX-style:

* :func:`evaluate` — start draining the dependency cone of one or more
  arrays **without blocking**: returns :class:`ArrayFuture` handles
  (wrapping the executor's :class:`repro.exec.futures.Future` via the
  runtime's :class:`~repro.core.engine.FlushTicket`), while the main
  thread keeps recording.
* :func:`gather` — block until an array's cone has drained and return
  the host ``np.ndarray`` (the explicit spelling of ``np.asarray``).
* :func:`wait` — block until the given arrays/futures are ready without
  transferring data back (``DistArray.block_until_ready()`` is the
  method spelling).

Under ``ExecutionPolicy(sync="demand")`` a readback forces only the
transitive producer cone of its base; ``sync="barrier"`` restores the
paper's whole-graph flush for every call here, so the two surfaces stay
interchangeable.
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

__all__ = ["ArrayFuture", "evaluate", "gather", "wait"]


class ArrayFuture:
    """Handle on the asynchronous evaluation of one DistArray.

    Holds a strong reference to the array (so its base blocks cannot be
    garbage-collected out from under the pending readback) and the
    :class:`~repro.core.engine.FlushTicket` of the cone flush that is
    materializing it.  ``result()`` blocks and returns the host
    ndarray; ``block_until_ready()`` blocks without transferring.
    """

    __slots__ = ("_array", "_ticket")

    def __init__(self, array, ticket):
        self._array = array
        self._ticket = ticket

    @property
    def array(self):
        """The underlying DistArray (metadata is always available)."""
        return self._array

    @property
    def shape(self):
        return self._array.shape

    @property
    def dtype(self):
        return self._array.dtype

    def done(self) -> bool:
        """True once the cone drain submitted by ``evaluate`` finished.
        Operations recorded *after* the evaluate call are not covered —
        ``result()`` picks them up with a fresh cone flush."""
        return self._ticket is None or self._ticket.done()

    def block_until_ready(self):
        """Join the cone drain (JAX idiom); returns the DistArray."""
        if self._ticket is not None:
            self._ticket.wait()
        return self._array

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until ready and gather the host ndarray.

        ``timeout`` bounds the wait on *this future's* drain only; if
        operations were recorded on the array after ``evaluate``, the
        gather below forces their cone with a fresh (unbounded, like
        every readback) flush."""
        if self._ticket is not None:
            self._ticket.wait(timeout)
        # readback through the normal demand path: any operation recorded
        # since the evaluate() call is forced by its own cone flush here
        return np.asarray(self._array)

    def __array__(self, dtype=None, copy=None):
        arr = self.result()
        return arr.astype(dtype) if dtype is not None else arr

    def __repr__(self):
        state = "ready" if self.done() else "pending"
        return (
            f"ArrayFuture(shape={self._array.shape}, "
            f"dtype={self._array.dtype}, {state})"
        )


def _as_array(x, rt):
    """Coerce one evaluate/wait operand to a DistArray (materializing
    lazy Expr trees); pass ArrayFutures through unchanged."""
    from repro.core.darray import DistArray, Expr

    if isinstance(x, ArrayFuture):
        return x
    if isinstance(x, Expr):
        return x.materialize()
    if isinstance(x, DistArray):
        return x
    raise TypeError(
        f"evaluate/wait expects DistArrays, Exprs or ArrayFutures, "
        f"got {type(x).__name__}"
    )


def evaluate(*arrays) -> Union[ArrayFuture, tuple]:
    """Start evaluating ``arrays`` without blocking.

    Submits ONE non-blocking flush of the joint dependency cone of all
    requested arrays (their transitive producer closure — nothing else)
    and returns an :class:`ArrayFuture` per array, all sharing the
    in-flight :class:`~repro.core.engine.FlushTicket`.  With a single
    argument the future is returned bare, else as a tuple.

    Recording continues on the calling thread while workers drain; under
    the simulated backend (or ``sync="barrier"``, which flushes the
    whole graph to preserve the paper's semantics) the returned futures
    are already completed.
    """
    from repro.core.engine import current_runtime

    rt = current_runtime()
    if not arrays:
        raise TypeError("evaluate() needs at least one array")
    coerced = [_as_array(x, rt) for x in arrays]
    plain = [c.array if isinstance(c, ArrayFuture) else c for c in coerced]
    if rt.sync_mode == "barrier":
        ticket = rt.flush(wait=False)
    else:
        # DistArray targets resolve to the block keys their views touch,
        # so evaluating a sub-view forces only its sub-cone
        ticket = rt.flush(wait=False, targets=plain)
    # every returned future wraps the NEW ticket — an ArrayFuture passed
    # in is rewrapped, so waiting on the result covers the drain this
    # call just submitted (which includes any operation recorded on the
    # array since the older future was created)
    futures = tuple(ArrayFuture(a, ticket) for a in plain)
    return futures[0] if len(futures) == 1 else futures


def gather(x) -> np.ndarray:
    """Block until ``x`` is evaluated and return the host ndarray.

    Accepts a DistArray, a lazy Expr, or an :class:`ArrayFuture`; host
    ndarrays pass through.  This is the explicit spelling of
    ``np.asarray(x)`` — under ``sync="demand"`` it forces only ``x``'s
    dependency cone, blocking until that cone has drained (like every
    readback).  Raises ``RuntimeError`` when no runtime is active.
    """
    from repro.core.engine import current_runtime

    if isinstance(x, ArrayFuture):
        return x.result()
    if isinstance(x, np.ndarray):
        return x
    rt = current_runtime()
    arr = _as_array(x, rt)
    return np.asarray(arr)


def wait(*xs):
    """Block until every argument is evaluated, without gathering.

    Accepts DistArrays, Exprs and ArrayFutures; returns the arguments
    (single argument bare, else a tuple) so calls chain:
    ``c = repro.wait(repro.evaluate(c))``.  The JAX-style method
    spelling is ``DistArray.block_until_ready()``.
    """
    if not xs:
        raise TypeError("wait() needs at least one array or future")
    plain = [x for x in xs if not isinstance(x, ArrayFuture)]
    if plain:
        evaluated = evaluate(*plain)
        futs = (evaluated,) if isinstance(evaluated, ArrayFuture) else evaluated
        for f in futs:
            f.block_until_ready()
    for x in xs:
        if isinstance(x, ArrayFuture):
            x.block_until_ready()
    return xs[0] if len(xs) == 1 else xs
