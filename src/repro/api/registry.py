"""String-keyed plugin registries for the runtime's pluggable pieces.

Four registries, one per extension point:

* **backends** — compute backends executing operation payloads against
  block storage (``repro.exec.backend``: ``"numpy"``, ``"jax"``,
  ``"auto"``).  An entry is a factory ``fn(storage, scratch) ->
  ComputeBackend``.
* **channels** — transfer-channel disciplines (``repro.exec.channels``:
  ``"async"``, ``"blocking"``).  An entry is a factory ``fn(*,
  latency, progress_threads) -> channel``.
* **schedulers** — flush scheduling modes for the discrete-event
  simulator (``repro.core.scheduler``: ``"latency_hiding"``,
  ``"blocking"``).  An entry is a callable ``fn(deps, cluster,
  executor=None) -> TimelineResult``.
* **passes** — plan-stage graph passes run over the recorded operation
  list before scheduling (``repro.core.plan``: ``"coalesce"``,
  ``"batch"``; ``repro.core.fusion``: ``"fuse"``).  An entry is a
  callable ``fn(ctx: PlanContext) -> None`` that rewrites ``ctx.ops``
  in place and/or sets executor hints — see ``docs/architecture.md``
  for the contract (a pass must preserve the relative program order of
  every pair of conflicting accesses it keeps).
* **rules** — static-analysis rules run by :func:`repro.analysis.check`
  over recorded/planned graphs (``repro.analysis.rules``: ``"plan"``,
  ``"races"``, ``"deadlock"``).  An entry is a callable ``fn(ctx:
  AnalysisContext) -> None`` that appends
  :class:`~repro.analysis.Diagnostic` objects to ``ctx.diagnostics``.

Registration replaces the old ``make_backend`` / ``make_channel``
if-else ladders: a new transport or an autotuned backend plugs in with
one ``register_*`` call and is immediately selectable by name from
:class:`~repro.api.config.ExecutionPolicy`, ``Runtime(...)`` kwargs,
and the benchmark drivers — no factory code changes.

This module imports nothing from the rest of the package (it sits at
the bottom of the import graph); the built-in entries register
themselves when their defining modules import, and ``get_*`` /
``available_*`` lazily import those modules so lookups never depend on
import order.
"""
from __future__ import annotations

import importlib
from typing import Callable, Iterator, Optional

__all__ = [
    "Registry",
    "register_backend",
    "get_backend",
    "available_backends",
    "register_channel",
    "get_channel",
    "available_channels",
    "register_scheduler",
    "get_scheduler",
    "available_schedulers",
    "register_pass",
    "get_pass",
    "available_passes",
    "register_rule",
    "get_rule",
    "available_rules",
]


class Registry:
    """A named string-keyed plugin table with lazy default population."""

    def __init__(self, kind: str, default_modules: tuple[str, ...] = ()):
        self.kind = kind
        self._entries: dict[str, object] = {}
        # modules that register the built-in entries on import
        self._default_modules = default_modules
        self._loaded_defaults = False

    def _ensure_defaults(self) -> None:
        if self._loaded_defaults:
            return
        self._loaded_defaults = True
        for mod in self._default_modules:
            importlib.import_module(mod)

    def register(
        self, name: str, obj: Optional[object] = None, *, overwrite: bool = False
    ):
        """Register ``obj`` under ``name``; usable as a decorator.

        ``register(name)`` returns a decorator; ``register(name, obj)``
        registers directly and returns ``obj``.  Re-registering an
        existing name requires ``overwrite=True`` (guards against two
        plugins silently shadowing each other).
        """
        if obj is None:
            return lambda f: self.register(name, f, overwrite=overwrite)
        # load the built-ins first so the duplicate check sees them: a user
        # registering a built-in name before any lookup must fail HERE, not
        # later inside the defaults import (which would poison the registry)
        self._ensure_defaults()
        if not overwrite and name in self._entries and self._entries[name] is not obj:
            raise ValueError(
                f"{self.kind} {name!r} is already registered "
                f"(pass overwrite=True to replace it)"
            )
        self._entries[name] = obj
        return obj

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    def get(self, name: str) -> object:
        self._ensure_defaults()
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r} "
                f"(registered: {', '.join(self.available()) or 'none'})"
            ) from None

    def __contains__(self, name: str) -> bool:
        self._ensure_defaults()
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        self._ensure_defaults()
        return iter(sorted(self._entries))

    def available(self) -> list[str]:
        self._ensure_defaults()
        return sorted(self._entries)


BACKENDS = Registry("backend", ("repro.exec.backend",))
CHANNELS = Registry("channel", ("repro.exec.channels",))
SCHEDULERS = Registry("scheduler", ("repro.core.scheduler",))
PASSES = Registry("pass", ("repro.core.plan", "repro.core.fusion"))
RULES = Registry("rule", ("repro.analysis.rules",))


def register_backend(name: str, factory: Optional[Callable] = None, **kw):
    """Register a compute backend: ``factory(storage, scratch) ->
    ComputeBackend``."""
    return BACKENDS.register(name, factory, **kw)


def get_backend(name: str) -> Callable:
    return BACKENDS.get(name)


def available_backends() -> list[str]:
    return BACKENDS.available()


def register_channel(name: str, factory: Optional[Callable] = None, **kw):
    """Register a transfer channel: ``factory(*, latency,
    progress_threads) -> channel``."""
    return CHANNELS.register(name, factory, **kw)


def get_channel(name: str) -> Callable:
    return CHANNELS.get(name)


def available_channels() -> list[str]:
    return CHANNELS.available()


def register_pass(name: str, fn: Optional[Callable] = None, **kw):
    """Register a plan-stage graph pass: ``fn(ctx: PlanContext) ->
    None``.  The pass may rewrite ``ctx.ops`` (setting ``ctx.dirty``)
    and/or set executor hints in ``ctx.hints``; it must preserve the
    relative order of every pair of conflicting accesses it keeps."""
    return PASSES.register(name, fn, **kw)


def get_pass(name: str) -> Callable:
    return PASSES.get(name)


def available_passes() -> list[str]:
    return PASSES.available()


def register_rule(name: str, fn: Optional[Callable] = None, **kw):
    """Register a static-analysis rule: ``fn(ctx: AnalysisContext) ->
    None``.  The rule inspects the context's pre-/post-plan snapshots
    (or cone footprints, or the cross-rank message schedule) and
    appends :class:`~repro.analysis.Diagnostic` objects to
    ``ctx.diagnostics``; a rule must no-op when its inputs are absent
    so ``repro.analysis.check`` can run any subset."""
    return RULES.register(name, fn, **kw)


def get_rule(name: str) -> Callable:
    return RULES.get(name)


def available_rules() -> list[str]:
    return RULES.available()


def register_scheduler(name: str, fn: Optional[Callable] = None, **kw):
    """Register a simulator flush scheduler: ``fn(deps, cluster,
    executor=None) -> TimelineResult``."""
    return SCHEDULERS.register(name, fn, **kw)


def get_scheduler(name: str) -> Callable:
    return SCHEDULERS.get(name)


def available_schedulers() -> list[str]:
    return SCHEDULERS.available()
