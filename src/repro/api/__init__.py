"""repro.api — the unified public front-end.

One import surface for the paper's promise (*sequential NumPy programs,
unmodified*) and the runtime knobs around it:

* **Evaluation** — demand-driven futures: :func:`evaluate` starts
  draining an array's dependency cone without blocking (returns
  :class:`ArrayFuture`), :func:`gather` blocks and returns the host
  ndarray, :func:`wait` / ``DistArray.block_until_ready()`` give
  JAX-style explicit sync.  ``ExecutionPolicy(sync="barrier")`` is the
  escape hatch back to the paper's whole-graph readback barrier.
* **Config objects** — :class:`RuntimeConfig` / :class:`ExecutionPolicy`
  frozen dataclasses and the :func:`runtime` context-manager helper
  replace the ``Runtime(...)`` kwarg soup.
* **Registries** — ``register_backend`` / ``register_channel`` /
  ``register_scheduler`` / ``register_pass`` plug new compute backends,
  transports, flush schedulers, and plan-stage graph passes in by name
  (``"auto"`` backend, multi-host channels, transfer coalescing, …)
  without touching factory code.
* **Arrays** — :class:`~repro.core.darray.DistArray` creation routines;
  operations on the arrays themselves go through the NumPy namespace
  (``np.add``, ``np.sum``, ``np.matmul``, …) via the array-protocol
  dispatch implemented in ``repro.core.darray``.
* **Reporting** — :func:`format_stats` renders simulated and measured
  run statistics as one table.

Typical program::

    import numpy as np
    import repro

    with repro.runtime(nprocs=16, block_size=64) as rt:
        a = repro.array(np.linspace(0.0, 1.0, 65536).reshape(256, 256))
        c = np.sqrt(a * a + 1.0) / 2.0          # recorded lazily
        result = np.asarray(np.sum(c, axis=0))  # readback flushes
        print(repro.format_stats([("run", rt.stats())]))

The array/engine names are re-exported lazily (PEP 562): the core
modules register their plugins with :mod:`repro.api.registry` at import
time, so the registry layer must stay importable from inside
``repro.core`` without cycling back through the array layer.
"""
from .config import ExecutionPolicy, RuntimeConfig, ServeConfig, runtime
from .futures import ArrayFuture, evaluate, gather, wait
from .registry import (
    available_backends,
    available_channels,
    available_passes,
    available_rules,
    available_schedulers,
    get_backend,
    get_channel,
    get_pass,
    get_rule,
    get_scheduler,
    register_backend,
    register_channel,
    register_pass,
    register_rule,
    register_scheduler,
)
from .reporting import format_stats

# lazily re-exported from repro.core (avoids import cycles: core modules
# import repro.api.registry at module level)
_CORE_EXPORTS = {
    "DistArray": "repro.core.darray",
    "array": "repro.core.darray",
    "empty": "repro.core.darray",
    "zeros": "repro.core.darray",
    "ones": "repro.core.darray",
    "full": "repro.core.darray",
    "arange": "repro.core.darray",
    "random": "repro.core.darray",
    "matmul": "repro.core.darray",
    "roll": "repro.core.darray",
    "Runtime": "repro.core.engine",
    "FlushTicket": "repro.core.engine",
    "current_runtime": "repro.core.engine",
    "ClusterSpec": "repro.core.timeline",
    "GIGE_2012": "repro.core.timeline",
    "TPU_V5E_ICI": "repro.core.timeline",
    # observability (repro.obs): lifecycle tracing, Perfetto export,
    # wait attribution
    "trace": "repro.obs",
    "TraceCollector": "repro.obs",
    "export_trace": "repro.obs",
    "validate_trace": "repro.obs",
    "attribution": "repro.obs",
    "AttributionReport": "repro.obs",
    # static analysis (repro.analysis): plan verifier, race oracle,
    # deadlock detection — ExecutionPolicy(verify=...) runs it per flush
    "check": "repro.analysis",
    "Diagnostic": "repro.analysis",
    "AnalysisReport": "repro.analysis",
    "VerificationError": "repro.analysis",
    "VerifyStats": "repro.analysis",
    # multi-tenant serving runtime (repro.serve): one shared Runtime,
    # concurrent per-request cone drains, admission control
    "Server": "repro.serve",
    "Session": "repro.serve",
    "Request": "repro.serve",
    "TenantStats": "repro.serve",
    "AdmissionError": "repro.serve",
    "LatencyHistogram": "repro.serve",
}

__all__ = [
    # config objects + entry point
    "runtime",
    "RuntimeConfig",
    "ExecutionPolicy",
    "ServeConfig",
    # demand-driven evaluation (futures surface)
    "ArrayFuture",
    "evaluate",
    "gather",
    "wait",
    # registries
    "register_backend",
    "get_backend",
    "available_backends",
    "register_channel",
    "get_channel",
    "available_channels",
    "register_scheduler",
    "get_scheduler",
    "available_schedulers",
    "register_pass",
    "get_pass",
    "available_passes",
    "register_rule",
    "get_rule",
    "available_rules",
    # reporting
    "format_stats",
    # lazy core re-exports
    *sorted(_CORE_EXPORTS),
]


def __getattr__(name):
    mod = _CORE_EXPORTS.get(name)
    if mod is not None:
        import importlib

        value = getattr(importlib.import_module(mod), name)
        globals()[name] = value  # cache for subsequent lookups
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
