"""Plan-shape cache (repro.core.plan_cache): hit/miss behaviour,
signature sensitivity, replay bit-identity, policy/env knobs,
uncacheable pipelines, on-demand re-verification, and the executor's
merged-group ``submit_many`` path that cross-tenant batching rides."""
import numpy as np
import pytest

import repro
from repro.api.config import ExecutionPolicy
from repro.api.registry import PASSES
from repro.core.plan_cache import PlanCache


def _demand_rt(**kw):
    kw.setdefault("nprocs", 2)
    kw.setdefault("block_size", 8)
    kw.setdefault("flush", "async")
    kw.setdefault("sync", "demand")
    return repro.runtime(**kw)


# ---------------------------------------------------------------------------
# hit/miss + bit-identity
# ---------------------------------------------------------------------------


def test_repeated_shape_hits_and_results_bit_identical():
    host = np.arange(32.0)
    exp = np.roll(host, 1, axis=0) * 2.0 + host

    def run_once():
        a = repro.array(host)
        out = np.roll(a, 1, axis=0) * 2.0 + a
        return np.asarray(out)

    with _demand_rt(plan_cache=True) as rt:
        cold = run_once()
        for _ in range(3):
            warm = run_once()
            np.testing.assert_array_equal(warm, cold)
        np.testing.assert_array_equal(cold, exp)
        cache = rt._plan_cache
        assert cache is not None
        assert cache.hits >= 3
        assert cache.misses >= 1
        assert cache.n_uncacheable == 0
        assert cache.hit_rate > 0.5
        assert "PlanCache(" in repr(cache)

    # the same program with the cache off is bit-identical
    with _demand_rt(plan_cache=False) as rt:
        assert rt._plan_cache is None
        np.testing.assert_array_equal(run_once(), exp)


def test_hit_replays_same_plan_stats_and_hints():
    host = np.arange(64.0).reshape(8, 8)

    def run_once(rt):
        a = repro.array(host)
        out = np.roll(a, 1, axis=0) + a  # transfer-bearing: coalesce fires
        np.testing.assert_array_equal(
            np.asarray(out), np.roll(host, 1, axis=0) + host
        )

    with _demand_rt(nprocs=2, block_size=4, plan_cache=True) as rt:
        run_once(rt)
        cold = (rt.plan_stats.n_ops_in, rt.plan_stats.n_ops_out,
                rt.plan_stats.n_transfers_coalesced)
        run_once(rt)
        warm = (rt.plan_stats.n_ops_in, rt.plan_stats.n_ops_out,
                rt.plan_stats.n_transfers_coalesced)
        assert rt._plan_cache.hits >= 1
        # replay folded the insert-time plan's stats again: counters
        # doubled, meaning the cached recipe reports the same rewrites
        assert warm == tuple(2 * c for c in cold)


# ---------------------------------------------------------------------------
# signature sensitivity
# ---------------------------------------------------------------------------


def test_different_constant_is_a_different_shape():
    """Constants fold into payload signatures — ``a * 2`` and ``a * 3``
    plan differently under const folding, so they must never share an
    entry."""
    host = np.arange(16.0)
    with _demand_rt(plan_cache=True) as rt:
        for k in range(4):
            a = repro.array(host)
            np.testing.assert_array_equal(
                np.asarray(a * float(k + 1)), host * float(k + 1)
            )
        assert rt._plan_cache.hits == 0
        assert rt._plan_cache.misses == 4

        # ...but repeating one of them now hits
        a = repro.array(host)
        np.testing.assert_array_equal(np.asarray(a * 2.0), host * 2.0)
        assert rt._plan_cache.hits == 1


def test_different_structure_is_a_different_shape():
    host = np.arange(16.0)
    with _demand_rt(plan_cache=True) as rt:
        a = repro.array(host)
        np.asarray(a + 1.0)
        b = repro.array(host)
        np.asarray(b + 1.0 + b)  # extra op: different canonical shape
        assert rt._plan_cache.hits == 0
        assert rt._plan_cache.misses == 2
        assert len(rt._plan_cache) == 2


# ---------------------------------------------------------------------------
# knobs: policy field, env var, pipeline gating
# ---------------------------------------------------------------------------


def test_policy_plan_cache_knob_validated():
    assert ExecutionPolicy(plan_cache=True).plan_cache is True
    assert ExecutionPolicy().plan_cache is None
    with pytest.raises(ValueError, match="plan_cache"):
        ExecutionPolicy(plan_cache="yes")
    with pytest.raises(ValueError, match="batch_cones"):
        ExecutionPolicy(batch_cones="yes")


def test_env_var_disables_cache(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", "0")
    with _demand_rt() as rt:  # plan_cache=None defers to the env
        assert rt._plan_cache is None
    monkeypatch.setenv("REPRO_PLAN_CACHE", "1")
    with _demand_rt() as rt:
        assert rt._plan_cache is not None
    # the kwarg wins over the env
    monkeypatch.setenv("REPRO_PLAN_CACHE", "0")
    with _demand_rt(plan_cache=True) as rt:
        assert rt._plan_cache is not None


def test_no_pipeline_means_no_cache():
    with _demand_rt(passes=()) as rt:
        assert rt._plan_cache is None  # nothing to cache: plan is a no-op
        host = np.arange(16.0)
        a = repro.array(host)
        np.testing.assert_array_equal(np.asarray(a + 1.0), host + 1.0)


def test_unknown_pass_makes_cones_uncacheable():
    """A pipeline with a pass the recipe language cannot express must
    run cold every time — counted, never cached, still correct."""
    def nop(ctx):
        pass

    PASSES.register("opaque-nop", nop)
    try:
        host = np.arange(16.0)
        with _demand_rt(passes=("coalesce", "opaque-nop"),
                        plan_cache=True) as rt:
            for _ in range(3):
                a = repro.array(host)
                np.testing.assert_array_equal(np.asarray(a * 2.0), host * 2.0)
            assert rt._plan_cache.hits == 0
            assert rt._plan_cache.misses == 0
            assert rt._plan_cache.n_uncacheable >= 3
            assert len(rt._plan_cache) == 0
    finally:
        PASSES.unregister("opaque-nop")


def test_lru_eviction_bounds_residency():
    host = np.arange(16.0)
    with _demand_rt(plan_cache=True) as rt:
        rt._plan_cache.maxsize = 2
        for k in range(4):  # 4 distinct shapes through a 2-entry cache
            a = repro.array(host)
            np.asarray(a * float(k + 1))
        assert len(rt._plan_cache) == 2
        assert rt._plan_cache.misses == 4


# ---------------------------------------------------------------------------
# cached plans stay verifiable
# ---------------------------------------------------------------------------


def test_verify_cached_plans_clean_after_hits():
    host = np.arange(64.0).reshape(8, 8)
    with _demand_rt(nprocs=2, block_size=4, plan_cache=True,
                    verify="plan") as rt:
        for _ in range(3):
            a = repro.array(host)
            np.testing.assert_array_equal(
                np.asarray(np.roll(a, 1, axis=0) + a),
                np.roll(host, 1, axis=0) + host,
            )
        assert rt._plan_cache.hits >= 1
        reports = rt.verify_cached_plans()
        assert len(reports) == len(rt._plan_cache)
        for rep in reports:
            assert not rep.diagnostics, rep.diagnostics
            rep.raise_if_errors()  # must not raise


def test_verify_cached_plans_without_cache_is_empty():
    with _demand_rt(plan_cache=False) as rt:
        assert rt.verify_cached_plans() == []


# ---------------------------------------------------------------------------
# executor submit_many: the merged-group submit batching rides on
# ---------------------------------------------------------------------------


def test_executor_submit_many_drains_group_correctly():
    with _demand_rt(latency=1e-3) as rt:
        host_a = np.arange(16.0)
        host_b = np.arange(16.0) * 3.0
        a = repro.array(host_a) + 1.0
        b = repro.array(host_b) * 2.0
        ha = rt.extract_cone([a])
        hb = rt.extract_cone([b])
        deps_a, _ = rt._plan_cone(ha)
        deps_b, _ = rt._plan_cone(hb)
        ex = rt._ensure_executor()
        futs = ex.submit_many(
            [(deps_a, ha.ticket._tag), (deps_b, hb.ticket._tag)]
        )
        assert len(futs) == 2
        ha.ticket._bind(futs[0])
        hb.ticket._bind(futs[1])
        ha.ticket.wait()
        hb.ticket.wait()
        np.testing.assert_array_equal(np.asarray(a), host_a + 1.0)
        np.testing.assert_array_equal(np.asarray(b), host_b * 2.0)


def test_plan_cache_standalone_lru_and_repr():
    c = PlanCache(maxsize=4)
    assert len(c) == 0
    assert c.hit_rate == 0.0
    assert c.lookup(("nope",)) is None
    assert c.misses == 1
    c.clear()
    assert "hits=0" in repr(c)
