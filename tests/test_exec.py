"""repro.exec tests: executor equivalence with the simulated schedule,
channel disciplines, measured WaitStats, and deadlock refusal.

The equivalence invariant is the strong form of the paper's §5.7
correctness argument: the dependency system totally orders every pair of
conflicting accesses, so ANY executor that respects it — the discrete
event simulator or the threaded async executor — must produce
bit-identical block contents.
"""
import numpy as np
import pytest

from repro.core import DependencySystem, OperationNode, AccessNode, COMM, COMPUTE
from repro.core.scheduler import DeadlockError
from repro.exec import (
    AsyncExecutor,
    WaitStats,
    WorkerStats,
    run_rendezvous_bsp_async,
)

from benchmarks.paper_apps import APPS, run_app

# small enough for CI, large enough to fragment across blocks and force
# scratch-buffer transfers at nprocs=4
SMALL = dict(
    fractal=dict(n=128, iters=4),
    black_scholes=dict(n=50_000, iters=3),
    nbody=dict(n=192, steps=2),
    knn=dict(n=512, d=16),
    lbm2d=dict(h=128, w=128, steps=2),
    lbm3d=dict(d=16, h=16, w=16, steps=2),
    jacobi=dict(n=256, nrhs=256, iters=3),
    jacobi_stencil=dict(n=256, iters=3),
)
SMALL_BLOCKS = dict(
    fractal=32, black_scholes=8192, nbody=64, knn=128,
    lbm2d=32, lbm3d=8, jacobi=64, jacobi_stencil=64,
)


# ---------------------------------------------------------------------------
# executor equivalence: async == simulated, bit for bit, for all 8 apps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("app", list(APPS))
def test_async_executor_matches_simulated(app):
    _, ref = run_app(app, mode="latency_hiding", nprocs=4,
                     block_size=SMALL_BLOCKS[app], **SMALL[app])
    st, got = run_app(app, mode="latency_hiding", nprocs=4,
                      block_size=SMALL_BLOCKS[app], flush_backend="async",
                      **SMALL[app])
    assert np.array_equal(np.asarray(ref), np.asarray(got), equal_nan=True)
    assert isinstance(st, WaitStats)
    assert st.elapsed > 0
    assert st.n_compute_ops > 0
    assert 0.0 <= st.wait_fraction <= 1.0


def test_blocking_channel_matches_too():
    app = "jacobi_stencil"
    _, ref = run_app(app, mode="latency_hiding", nprocs=4,
                     block_size=64, **SMALL[app])
    st, got = run_app(app, mode="blocking", nprocs=4, block_size=64,
                      flush_backend="async", **SMALL[app])
    assert st.mode == "blocking-channel"
    assert np.array_equal(np.asarray(ref), np.asarray(got))


def test_fusion_through_async_executor():
    app = "jacobi_stencil"
    _, ref = run_app(app, mode="latency_hiding", nprocs=4,
                     block_size=64, **SMALL[app])
    _, got = run_app(app, mode="latency_hiding", nprocs=4, block_size=64,
                     flush_backend="async", fusion=True, **SMALL[app])
    assert np.allclose(np.asarray(ref), np.asarray(got))


def test_jax_backend_close_to_numpy():
    """The jax backend computes in float32 (no x64 here), so results are
    close, not bit-identical."""
    app = "jacobi_stencil"
    _, ref = run_app(app, mode="latency_hiding", nprocs=4,
                     block_size=64, **SMALL[app])
    _, got = run_app(app, mode="latency_hiding", nprocs=4, block_size=64,
                     flush_backend="async", exec_backend="jax", **SMALL[app])
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=1e-4, atol=1e-5)


def test_jax_backend_pallas_stencil_path():
    """Fused stencil sweeps route through the Pallas stencil5_block
    kernel and still match the interpreter."""
    app = "jacobi_stencil"
    _, ref = run_app(app, mode="latency_hiding", nprocs=4,
                     block_size=64, fusion=True, **SMALL[app])
    _, got = run_app(app, mode="latency_hiding", nprocs=4, block_size=64,
                     flush_backend="async", exec_backend="jax", fusion=True,
                     **SMALL[app])
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# measured overlap: async channel hides injected wire latency
# ---------------------------------------------------------------------------


def test_async_channel_hides_latency():
    """With real per-message latency injected, the blocking channel
    exposes it on worker clocks while the progress engine hides it —
    measured wait% must be lower with overlap on."""
    # 5 ms/message decisively dominates the ~0.1 ms/op dispatch overhead,
    # keeping the ordering assertion stable on loaded CI machines.  The
    # plan-stage passes are pinned OFF: coalescing shrinks the message
    # count and with it the blocking-channel penalty this test relies on
    # (the channel-discipline ordering under passes is covered in
    # tests/test_plan.py at full margins).
    kw = dict(n=192, iters=4, passes=())
    st_async, r_async = run_app(
        "jacobi_stencil", nprocs=4, block_size=48, flush_backend="async",
        exec_channel="async", exec_latency=5e-3, **kw)
    st_block, r_block = run_app(
        "jacobi_stencil", nprocs=4, block_size=48, flush_backend="async",
        exec_channel="blocking", exec_latency=5e-3, **kw)
    assert np.array_equal(np.asarray(r_async), np.asarray(r_block))
    assert st_async.n_comm_ops == st_block.n_comm_ops > 0
    # every blocked millisecond lands on a worker clock in blocking mode
    assert st_block.comm_wait_fraction > st_async.comm_wait_fraction
    assert st_block.makespan > st_async.makespan
    assert st_async.wait_fraction < st_block.wait_fraction


# ---------------------------------------------------------------------------
# deadlock refusal with diagnostics
# ---------------------------------------------------------------------------


def test_bsp_rendezvous_deadlock_refused_fig6():
    p0 = [{"kind": "recv", "tag": "x", "peer": 1},
          {"kind": "send", "tag": "y", "peer": 1}]
    p1 = [{"kind": "recv", "tag": "y", "peer": 0},
          {"kind": "send", "tag": "x", "peer": 0}]
    with pytest.raises(DeadlockError) as ei:
        run_rendezvous_bsp_async([p0, p1])
    msg = str(ei.value)
    # the diagnostic lists each stuck operation-node
    assert "stuck operation-nodes" in msg
    assert "p0@step0" in msg and "p1@step0" in msg
    assert "recv tag='x'" in msg


def test_bsp_rendezvous_well_ordered_completes():
    p0 = [{"kind": "send", "tag": "y", "peer": 1},
          {"kind": "recv", "tag": "x", "peer": 1}]
    p1 = [{"kind": "recv", "tag": "y", "peer": 0},
          {"kind": "send", "tag": "x", "peer": 0}]
    assert run_rendezvous_bsp_async([p0, p1]) == 4


def test_graph_drain_deadlock_diagnostic():
    """A dependency system whose ready queue was lost can never drain;
    the executor must refuse with the stuck operation-nodes, not hang."""
    deps = DependencySystem()
    a = OperationNode(COMPUTE, None, procs=(0,), label="map:first")
    a.add_access(AccessNode(("b", 0), None, write=True))
    b = OperationNode(COMPUTE, None, procs=(0,), label="map:second")
    b.add_access(AccessNode(("b", 0), None, write=True))
    deps.insert(a)
    deps.insert(b)
    deps.ready.clear()  # simulate a lost completion
    ex = AsyncExecutor(nworkers=2, storage={}, scratch={})
    try:
        with pytest.raises(DeadlockError) as ei:
            ex.run(deps)
    finally:
        ex.close()
    msg = str(ei.value)
    assert "map:first" in msg and "map:second" in msg
    assert "2 operations pending" in msg


# ---------------------------------------------------------------------------
# graph hooks + stats plumbing
# ---------------------------------------------------------------------------


def test_on_ready_hook_replaces_queue():
    deps = DependencySystem()
    got = []
    deps.on_ready = got.append
    op = OperationNode(COMPUTE, None, procs=(0,))
    op.add_access(AccessNode(("b", 0), None, write=True))
    deps.insert(op)
    assert got == [op]
    assert not deps.ready  # delivered to the hook, not the deque
    op2 = OperationNode(COMPUTE, None, procs=(0,))
    op2.add_access(AccessNode(("b", 0), None, write=True))
    deps.insert(op2)
    assert got == [op]  # blocked on op
    deps.complete(op)
    assert got == [op, op2]


def test_pending_ops_diagnostics():
    deps = DependencySystem()
    ops = []
    for i in range(3):
        op = OperationNode(COMPUTE, None, procs=(0,), label=f"op{i}")
        op.add_access(AccessNode(("b", 0), None, write=True))
        deps.insert(op)
        ops.append(op)
    assert [o.label for o in deps.pending_ops()] == ["op0", "op1", "op2"]
    deps.complete(ops[0])
    assert [o.label for o in deps.pending_ops()] == ["op1", "op2"]


def test_waitstats_merge_and_summary():
    a = WaitStats(mode="async", nworkers=2, elapsed=1.0, comm_bytes=100,
                  n_comm_ops=1, n_compute_ops=2, seq_time=1.5)
    a.procs[0].compute_busy = 1.0
    a.procs[1].compute_busy = 0.5
    b = WaitStats(mode="async", nworkers=2, elapsed=1.0, comm_bytes=50,
                  n_comm_ops=1, n_compute_ops=1, seq_time=0.5)
    b.procs[0].compute_busy = 0.5
    a.merge(b)
    assert a.elapsed == 2.0
    assert a.comm_bytes == 150
    assert a.total_compute == pytest.approx(2.0)
    assert a.wait_fraction == pytest.approx(1.0 - 2.0 / 4.0)
    assert a.speedup == pytest.approx(1.0)
    s = a.summary()
    assert "wait=" in s and "makespan=" in s and "ops=3c/2m" in s
    assert "worker" in a.per_worker_table()


def test_waitstats_merge_differing_nworkers():
    # regression: merging stats from runs with different worker counts
    # used to zip procs positionally and silently drop (or crash on) the
    # extra workers' accounting — merge now pads to the wider run
    a = WaitStats(mode="async", nworkers=2, elapsed=1.0, n_compute_ops=2)
    a.procs[0].compute_busy = 1.0
    a.procs[1].compute_busy = 0.5
    wide = WaitStats(mode="async", nworkers=4, elapsed=1.0, n_compute_ops=4)
    for i in range(4):
        wide.procs[i].compute_busy = 0.25
    a.merge(wide)
    assert a.nworkers == 4
    assert len(a.procs) == 4
    assert a.total_compute == pytest.approx(1.0 + 0.5 + 1.0)
    assert a.procs[2].compute_busy == pytest.approx(0.25)
    # narrower other: extra self workers keep their time untouched
    narrow = WaitStats(mode="async", nworkers=1, elapsed=0.5)
    narrow.procs[0].compute_busy = 0.1
    a.merge(narrow)
    assert a.nworkers == 4 and len(a.procs) == 4
    assert a.procs[0].compute_busy == pytest.approx(1.0 + 0.25 + 0.1)
    assert a.procs[3].compute_busy == pytest.approx(0.25)
    assert a.elapsed == pytest.approx(2.5)


def test_runtime_stats_returns_waitstats():
    from repro.core import Runtime
    from repro.core import darray as dnp

    with Runtime(nprocs=2, block_size=8, flush_backend="async") as rt:
        x = dnp.ones((16, 16))
        y = x + 1.0
        _ = np.asarray(y)
        st = rt.stats()
    assert isinstance(st, WaitStats)
    assert st.n_flushes >= 1
    assert st.n_compute_ops > 0


def test_async_requires_execute():
    from repro.core import Runtime

    with pytest.raises(ValueError):
        Runtime(nprocs=2, flush_backend="async", execute=False)
    with pytest.raises(ValueError):
        Runtime(nprocs=2, flush_backend="nope")
