"""repro.serve tests: admission control, per-tenant stats isolation,
work-stealing correctness, and the serving lifecycle.

The load-level acceptance gates (>=1.5x concurrent throughput, p99
budget at 200 clients) live in ``benchmarks.serve_load``; these tests
cover the mechanisms at unit scale.
"""
import math
import random
import threading
import time

import numpy as np
import pytest

import repro
from repro.serve import (
    AdmissionController,
    AdmissionError,
    LatencyHistogram,
    Server,
)


# ---------------------------------------------------------------------------
# latency histogram
# ---------------------------------------------------------------------------


def test_histogram_quantiles_and_merge():
    h = LatencyHistogram()
    assert h.count == 0 and h.p99 == 0.0 and h.mean == 0.0
    for ms in range(1, 101):  # 1..100 ms, uniform
        h.record(ms * 1e-3)
    assert h.count == 100
    assert h.max == pytest.approx(0.1)
    # log-spaced buckets: quantiles accurate to the bucket ratio (~12%)
    assert h.p50 == pytest.approx(0.050, rel=0.15)
    assert h.p99 == pytest.approx(0.100, rel=0.15)
    assert h.p50 <= h.p95 <= h.p99 <= h.max
    other = LatencyHistogram()
    other.record(1.0)  # a 1 s outlier
    h.merge(other)
    assert h.count == 101
    assert h.max == pytest.approx(1.0)
    assert h.quantile(1.0) == pytest.approx(1.0)


def test_histogram_clamps_out_of_range():
    h = LatencyHistogram()
    h.record(-1.0)  # negative -> 0
    h.record(float("nan"))
    h.record(1e-9)  # below the grid
    h.record(1e4)  # above the grid: exact max still honest
    assert h.count == 4
    assert h.max == pytest.approx(1e4)
    assert h.quantile(1.0) == pytest.approx(1e4)


def test_histogram_inf_clamps_to_overflow_edge():
    """Regression: one +inf sample used to poison ``max`` — and with it
    every quantile (quantile() clamps its answer to ``max``) and the
    running ``sum``/``mean``, forever."""
    h = LatencyHistogram()
    h.record(float("inf"))
    h.record(5e-3)
    assert h.count == 2
    assert math.isfinite(h.max) and math.isfinite(h.sum)
    assert h.max == pytest.approx(100.0)  # the overflow-bucket edge
    for q in (0.5, 0.99, 1.0):
        assert math.isfinite(h.quantile(q))
    assert math.isfinite(h.mean)
    other = LatencyHistogram()
    other.record(2e-3)
    other.merge(h)  # merging an inf-touched histogram stays finite
    assert math.isfinite(other.max) and math.isfinite(other.p99)


# ---------------------------------------------------------------------------
# admission controller
# ---------------------------------------------------------------------------


def test_admission_queue_full_rejects_immediately():
    adm = AdmissionController(max_inflight=1, max_queue=0)
    adm.admit()
    t0 = time.perf_counter()
    with pytest.raises(AdmissionError) as ei:
        adm.admit()
    assert ei.value.reason == "queue-full"
    assert time.perf_counter() - t0 < 0.5  # shed, not queued
    assert adm.n_admitted == 1 and adm.n_rejected == 1
    adm.release()
    adm.admit()  # slot freed: admissible again
    assert adm.n_admitted == 2


def test_admission_timeout_rejects_queued_request():
    adm = AdmissionController(max_inflight=1, max_queue=4,
                              admission_timeout=0.05)
    adm.admit()
    with pytest.raises(AdmissionError) as ei:
        adm.admit()
    assert ei.value.reason == "timeout"
    assert adm.queued == 0  # the waiter un-queued itself


def test_admission_release_unblocks_queued_waiter():
    adm = AdmissionController(max_inflight=1, max_queue=4)
    adm.admit()
    admitted = threading.Event()

    def waiter():
        adm.admit()
        admitted.set()

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert not admitted.is_set() and adm.queued == 1
    adm.release()
    assert admitted.wait(5.0)
    t.join()
    assert adm.peak_queued == 1 and adm.peak_inflight == 1


def test_admission_close_rejects_queued_and_future():
    adm = AdmissionController(max_inflight=1, max_queue=4)
    adm.admit()
    errors = []

    def waiter():
        try:
            adm.admit()
        except AdmissionError as e:
            errors.append(e.reason)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    adm.close()
    t.join(5.0)
    assert errors == ["closed"]
    with pytest.raises(AdmissionError, match="closed"):
        adm.admit()


def test_admission_release_never_lost_with_two_queued_waiters():
    """Regression (lost wakeup): a queued waiter that consumes a
    ``release()`` notify and then sheds itself (deadline passed) used to
    let the notify die with it, stranding the *other* queued waiter even
    though a slot was free.  Race a release against the first waiter's
    deadline, many rounds: the patient (no-deadline) waiter must always
    come through promptly."""
    for round_ in range(15):
        adm = AdmissionController(max_inflight=1, max_queue=4,
                                  admission_timeout=0.03)
        adm.admit()  # slot taken
        results = {}

        def timed():
            try:
                adm.admit()
                results["timed"] = "admitted"
            except AdmissionError as e:
                results["timed"] = e.reason

        def patient():
            try:
                adm.admit()
                results["patient"] = "admitted"
            except AdmissionError as e:
                results["patient"] = e.reason

        ta = threading.Thread(target=timed)
        ta.start()
        time.sleep(0.005)  # "timed" queued first (deadline ~0.03 out)
        adm.admission_timeout = None  # read per-admit(): "patient" waits forever
        tb = threading.Thread(target=patient)
        tb.start()
        time.sleep(0.005)
        # release as close to the timed waiter's deadline as this round
        # lands — across rounds the notify falls on both sides of it
        time.sleep(0.02 + round_ * 0.002)
        adm.release()
        ta.join(5.0)
        tb.join(10.0)
        assert not tb.is_alive(), (
            f"round {round_}: patient waiter stranded — release notify "
            f"was lost ({results})"
        )
        # exactly one waiter got the freed slot; the other either also
        # admitted (never possible here: one slot) or timed out
        admitted = [k for k, v in results.items() if v == "admitted"]
        assert len(admitted) == 1, (round_, results)
        assert adm.inflight == 1


def test_admission_release_overrelease_clamped_and_counted():
    adm = AdmissionController(max_inflight=2, max_queue=0)
    adm.admit()
    adm.release()
    adm.release()  # over-release: clamped, counted, never negative
    adm.release()
    assert adm.inflight == 0
    assert adm.n_over_released == 2
    # the clamp keeps the window intact: exactly max_inflight admits fit
    adm.admit()
    adm.admit()
    with pytest.raises(AdmissionError, match="queue full"):
        adm.admit()
    assert adm.inflight == 2


def test_admission_stress_window_and_no_starvation():
    """Satellite stress: hammer admit/release from many threads with a
    generous deadline — the in-flight count must never exceed
    ``max_inflight``, no waiter may starve past its deadline, and the
    counters must balance."""
    adm = AdmissionController(max_inflight=4, max_queue=64,
                              admission_timeout=10.0)
    peak_violation = []
    outcomes = []
    lock = threading.Lock()

    def client(seed):
        rng = random.Random(seed)
        for _ in range(25):
            try:
                adm.admit()
            except AdmissionError as e:
                with lock:
                    outcomes.append(e.reason)
                continue
            if adm.inflight > adm.max_inflight:
                with lock:
                    peak_violation.append(adm.inflight)
            time.sleep(rng.random() * 0.002)
            adm.release()
            with lock:
                outcomes.append("ok")

    threads = [threading.Thread(target=client, args=(i,)) for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60.0)
    assert not any(t.is_alive() for t in threads), "a waiter starved"
    assert not peak_violation, f"window exceeded: {peak_violation}"
    assert outcomes.count("timeout") == 0, "deadline was generous; a timeout means a lost wakeup"
    assert adm.peak_inflight <= adm.max_inflight
    assert adm.inflight == 0 and adm.queued == 0
    assert adm.n_admitted == outcomes.count("ok")


def test_serve_config_validation():
    from repro.api.config import ServeConfig

    cfg = ServeConfig()
    assert cfg.max_inflight == 8 and cfg.max_queue == 64
    assert cfg.replace(max_inflight=2).max_inflight == 2
    with pytest.raises(ValueError, match="max_inflight"):
        ServeConfig(max_inflight=0)
    with pytest.raises(ValueError, match="max_queue"):
        ServeConfig(max_queue=-1)
    with pytest.raises(ValueError, match="admission_timeout"):
        ServeConfig(admission_timeout=0.0)


# ---------------------------------------------------------------------------
# server lifecycle and config surface
# ---------------------------------------------------------------------------


def test_server_requires_async_flush_and_demand_sync():
    from repro.api.config import ExecutionPolicy

    with pytest.raises(ValueError, match="flush='async'"):
        Server(policy=ExecutionPolicy(flush="sim"))
    with pytest.raises(ValueError, match="demand"):
        Server(policy=ExecutionPolicy(flush="async", sync="barrier"))
    with pytest.raises(TypeError, match="unknown server option"):
        Server(bogus_knob=1)


def test_server_rejects_requests_after_close_and_double_close():
    srv = Server(nprocs=2, block_size=8)
    sess = srv.session("t")
    host = np.arange(16.0)

    def fn():
        a = repro.array(host)
        return a + 1.0

    got = sess.request(fn).result()
    np.testing.assert_array_equal(got, host + 1.0)
    srv.close()
    srv.close()  # no-op
    with pytest.raises(AdmissionError, match="closed"):
        sess.request(fn)
    assert sess.stats.n_rejected == 1
    with pytest.raises(AdmissionError, match="closed"):
        srv.session("new-tenant")


def test_request_function_error_releases_admission_slot():
    with Server(nprocs=2, block_size=8, max_inflight=1) as srv:
        sess = srv.session("t")
        with pytest.raises(ValueError, match="boom"):
            sess.request(lambda: (_ for _ in ()).throw(ValueError("boom")))
        assert sess.stats.n_failed == 1
        assert srv.admission.inflight == 0  # permit released
        host = np.arange(16.0)
        got = sess.request(lambda: repro.array(host) * 2.0).result()
        np.testing.assert_array_equal(got, host * 2.0)


def test_request_fn_must_return_arrays():
    with Server(nprocs=2, block_size=8) as srv:
        sess = srv.session("t")
        with pytest.raises(TypeError, match="must return DistArrays"):
            sess.request(lambda: 42)
        assert srv.admission.inflight == 0


# ---------------------------------------------------------------------------
# admission under real load + per-tenant stats isolation
# ---------------------------------------------------------------------------


def test_server_sheds_when_queue_full_under_slow_drain():
    host = np.arange(64.0).reshape(8, 8)
    with Server(nprocs=2, block_size=4, latency=20e-3,
                max_inflight=1, max_queue=0) as srv:
        sess = srv.session("t")

        def fn():
            a = repro.array(host)
            return np.roll(a, 1, axis=0) + a

        r1 = sess.request(fn)  # slow drain (injected wire latency)
        with pytest.raises(AdmissionError) as ei:
            sess.request(fn)
        assert ei.value.reason == "queue-full"
        np.testing.assert_array_equal(
            r1.result(), np.roll(host, 1, axis=0) + host
        )
        assert sess.stats.n_rejected == 1
        assert srv.admission.n_rejected == 1


def test_per_tenant_stats_isolation():
    with Server(nprocs=2, block_size=8) as srv:
        sa, sb = srv.session("a"), srv.session("b")
        ha, hb = np.arange(16.0), np.arange(16.0) * 3.0
        for _ in range(3):
            sa.request(lambda: repro.array(ha) + 1.0).result()
        sb.request(lambda: repro.array(hb) * 2.0).result()
        assert sa.stats.n_requests == 3 and sa.stats.latency.count == 3
        assert sb.stats.n_requests == 1 and sb.stats.latency.count == 1
        assert sa.stats.n_failed == 0 and sb.stats.n_failed == 0
        # each tenant's WaitStats folded only its own drained cones
        assert sa.stats.n_flushes == 3
        assert sb.stats.n_flushes == 1
        assert sa.stats.wait.n_compute_ops > sb.stats.wait.n_compute_ops
        stats = srv.stats()
        assert list(stats) == ["a", "b"]
        rendered = srv.format_stats()
        assert "latency:" in rendered and "a" in rendered


def test_concurrent_tenants_bit_identical_under_threads():
    results = {}
    with Server(nprocs=4, block_size=16, latency=1e-3,
                max_inflight=8, max_queue=64) as srv:
        def client(name, seed):
            rng = np.random.default_rng(seed)
            h = rng.standard_normal((32, 32))
            sess = srv.session(name)

            def fn():
                a = repro.array(h)
                return np.roll(a, 1, axis=1) * 3.0 - a

            got = [sess.request(fn).result() for _ in range(3)]
            exp = np.roll(h, 1, axis=1) * 3.0 - h
            results[name] = all(np.array_equal(g, exp) for g in got)

        threads = [
            threading.Thread(target=client, args=(f"c{i}", i))
            for i in range(6)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(results.values()), results
        assert srv.admission.peak_inflight >= 2  # cones actually overlapped


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------


def test_steal_rebalances_single_owner_skew():
    """Many independent single-block chains all owned by worker 0 land
    in its queue while it is provably busy with another cone's slow op:
    idle workers must steal from that queue (the latency-aware threshold
    permits it — plenty of queued work), and results stay exact.

    The busy op is essential for determinism: when the owner is parked,
    it races the thieves for its own freshly-pushed batch and usually
    wins (a whole-queue pop is one lock acquisition), so steals would be
    a coin flip.  Pinning the owner inside a long payload leaves the
    skewed queue exposed for the full sleep."""
    from repro.core.ufunc import UFunc

    slow = UFunc(
        name="slow_for_steal_test",
        fn=lambda x: (time.sleep(0.25), x + 1.0)[1],
        nin=1,
    )
    with repro.runtime(nprocs=4, block_size=8, flush="async") as rt:
        busy = repro.ones((8,))  # single-block: owned by worker 0
        rt.record_map(slow, (busy._base, busy._view),
                      [(busy._base, busy._view)])
        t_busy = rt.flush(wait=False, targets=[busy])
        # worker 0 is now inside the 250 ms payload; every chain below is
        # also owned by worker 0, so this flush piles 96 ready fills onto
        # its queue and wakes the (empty-queue) thieves
        arrs = [repro.ones((8,)) for _ in range(96)]
        for _ in range(4):
            for a in arrs:
                a += 1.0
        t_chains = rt.flush(wait=False, targets=list(arrs))
        t_chains.wait()
        t_busy.wait()
        st = rt.stats()
        assert st.n_stolen > 0, (
            "no ops were stolen from the overloaded owner's queue"
        )
        np.testing.assert_array_equal(np.asarray(busy), np.full((8,), 2.0))
        for a in arrs:
            np.testing.assert_array_equal(np.asarray(a), np.full((8,), 5.0))


def test_steal_disabled_is_bit_identical_and_never_steals():
    def run(steal):
        with repro.runtime(nprocs=4, block_size=8, flush="async",
                           steal=steal) as rt:
            arrs = [repro.ones((8,)) + float(i) for i in range(64)]
            rt.flush()
            st = rt.stats()
            return [np.asarray(a).copy() for a in arrs], st

    with_steal, st_on = run(True)
    without, st_off = run(False)
    assert st_off.n_stolen == 0 and st_off.n_steals == 0
    for x, y in zip(with_steal, without):
        np.testing.assert_array_equal(x, y)


def test_steal_preserves_comm_first_stencil_results():
    """A comm-heavy stencil under steal=True vs steal=False: stolen
    batches are re-sorted comm-first, and any interleaving of
    simultaneously-ready ops is bit-identical by the cone invariant."""
    host = np.arange(4096.0).reshape(64, 64)

    def run(steal):
        with repro.runtime(nprocs=4, block_size=16, flush="async",
                           steal=steal, steal_threshold=2):
            a = repro.array(host)
            b = (np.roll(a, 1, axis=0) + np.roll(a, -1, axis=0)) * 0.5
            c = (np.roll(b, 1, axis=1) + np.roll(b, -1, axis=1)) * 0.5
            return np.asarray(c).copy()

    np.testing.assert_array_equal(run(True), run(False))


def test_policy_steal_knobs_validated():
    from repro.api.config import ExecutionPolicy

    with pytest.raises(ValueError, match="steal_threshold"):
        ExecutionPolicy(steal_threshold=1)
    with pytest.raises(ValueError, match="steal_latency"):
        ExecutionPolicy(steal_latency=-1.0)
    p = ExecutionPolicy(steal=False, steal_threshold=8, steal_latency=1e-3)
    assert not p.steal and p.steal_threshold == 8


# ---------------------------------------------------------------------------
# concurrent cone drains at the engine level (serve's substrate)
# ---------------------------------------------------------------------------


def test_disjoint_cones_drain_concurrently():
    with repro.runtime(nprocs=2, block_size=8, flush="async",
                       latency=20e-3) as rt:
        a = repro.ones((16,)) + 1.0
        b = repro.ones((16,)) + 2.0
        ta = rt.flush(wait=False, targets=[a])
        tb = rt.flush(wait=False, targets=[b])
        # both slow drains in flight at once: disjoint cones NOT joined
        assert rt._exec_executor_obj.n_active_drains == 2
        ta.wait()
        tb.wait()
        np.testing.assert_array_equal(np.asarray(a), np.full((16,), 2.0))
        np.testing.assert_array_equal(np.asarray(b), np.full((16,), 3.0))


def test_conflicting_cone_joins_inflight_writer():
    with repro.runtime(nprocs=2, block_size=8, flush="async",
                       latency=10e-3) as rt:
        a = repro.ones((16,)) + 1.0
        t1 = rt.flush(wait=False, targets=[a])
        a += 5.0  # second cone writes the same base: conflicts with t1
        t2 = rt.flush(wait=False, targets=[a])
        assert t1.done()  # the conflicting flush joined it first
        t2.wait()
        np.testing.assert_array_equal(np.asarray(a), np.full((16,), 7.0))


# ---------------------------------------------------------------------------
# planning off the record lock: lock-hold accounting, plan-shape cache,
# cross-tenant cone batching
# ---------------------------------------------------------------------------


def test_request_lock_hold_histogram_populated():
    """The record lock is held only for recording + cone extraction; the
    server measures each hold and the histogram must fill up."""
    with Server(nprocs=2, block_size=8) as srv:
        sess = srv.session("t")
        h = np.arange(16.0)
        for _ in range(4):
            sess.request(lambda: repro.array(h) * 2.0).result()
        assert srv.lock_hold.count == 4
        assert srv.lock_hold.max < 10.0  # sane seconds, not garbage
        assert srv.lock_hold.quantile(0.5) > 0.0


def test_server_repeated_shape_hits_plan_cache():
    with Server(nprocs=2, block_size=8, plan_cache=True) as srv:
        sess = srv.session("t")
        h = np.arange(32.0)

        def fn():
            a = repro.array(h)
            return np.roll(a, 1, axis=0) + a * 2.0

        exp = np.roll(h, 1, axis=0) + h * 2.0
        for _ in range(5):
            np.testing.assert_array_equal(sess.request(fn).result(), exp)
        cache = srv.runtime._plan_cache
        assert cache is not None
        assert cache.hits >= 3  # identical shape after warmup
        assert cache.misses >= 1


def test_server_batch_cones_end_to_end_correct():
    results = {}
    with Server(nprocs=4, block_size=16, latency=1e-3,
                batch_cones=True, max_inflight=8, max_queue=64) as srv:
        def client(name, seed):
            rng = np.random.default_rng(seed)
            h = rng.standard_normal((32, 32))
            sess = srv.session(name)

            def fn():
                a = repro.array(h)
                return np.roll(a, 1, axis=1) * 3.0 - a

            got = [sess.request(fn).result() for _ in range(4)]
            exp = np.roll(h, 1, axis=1) * 3.0 - h
            results[name] = all(np.array_equal(g, exp) for g in got)

        threads = [
            threading.Thread(target=client, args=(f"c{i}", i))
            for i in range(6)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(results.values()), results
        batcher = srv.runtime._batcher
        assert batcher is not None
        assert batcher.n_batches >= 1


def test_submit_failure_fails_ticket_and_releases_admission():
    """A cone that fails verification *after* the record lock is
    released must still fail the request future and hand the admission
    slot back."""
    with Server(nprocs=2, block_size=8, max_inflight=1,
                verify="full") as srv:
        sess = srv.session("t")
        h = np.arange(16.0)
        got = sess.request(lambda: repro.array(h) + 1.0).result()
        np.testing.assert_array_equal(got, h + 1.0)
        assert srv.admission.inflight == 0


def test_engine_ticket_wait_before_bind_blocks_then_resolves():
    """A ticket returned while its cone is still being planned parks
    wait() until the executor future is bound, then yields stats."""
    with repro.runtime(nprocs=2, block_size=8, flush="async",
                       latency=5e-3) as rt:
        a = repro.ones((16,)) * 2.0
        t = rt.flush(wait=False, targets=[a])
        res = t.wait()
        assert t.done()
        assert res is not None
        np.testing.assert_array_equal(np.asarray(a), np.full((16,), 2.0))
