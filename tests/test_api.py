"""repro.api: config objects, the runtime() entry point, the plugin
registries (backend/channel/scheduler), the auto backend, unified stats
rendering, and the deprecated legacy shims."""
import numpy as np
import pytest

import repro
from repro.api import ExecutionPolicy, RuntimeConfig, format_stats
from repro.api.registry import BACKENDS, CHANNELS, SCHEDULERS


# ---------------------------------------------------------------------------
# config objects
# ---------------------------------------------------------------------------


def test_configs_are_frozen_and_validated():
    cfg = RuntimeConfig(nprocs=8, block_size=32)
    with pytest.raises(Exception):  # frozen dataclass
        cfg.nprocs = 2
    with pytest.raises(ValueError):
        RuntimeConfig(nprocs=0)
    with pytest.raises(ValueError):
        RuntimeConfig(block_size=0)
    with pytest.raises(ValueError):
        ExecutionPolicy(flush="nope")
    with pytest.raises(ValueError):
        ExecutionPolicy(backend="nope")
    with pytest.raises(ValueError):
        ExecutionPolicy(channel="nope")
    with pytest.raises(ValueError):
        ExecutionPolicy(scheduler="nope")
    with pytest.raises(ValueError):
        ExecutionPolicy(latency="beta")


def test_replace_revalidates():
    pol = ExecutionPolicy()
    assert pol.replace(backend="jax").backend == "jax"
    with pytest.raises(ValueError):
        pol.replace(backend="definitely-not-registered")
    cfg = RuntimeConfig()
    assert cfg.replace(nprocs=2).nprocs == 2
    assert cfg.nprocs == 4  # original untouched
    with pytest.raises(ValueError):
        cfg.replace(nprocs=-1)


def test_resolved_channel_follows_scheduler():
    assert ExecutionPolicy().resolved_channel == "async"
    assert ExecutionPolicy(scheduler="blocking").resolved_channel == "blocking"
    assert ExecutionPolicy(channel="blocking").resolved_channel == "blocking"


def test_runtime_helper_routes_overrides():
    with repro.runtime(nprocs=2, block_size=5, scheduler="blocking") as rt:
        assert rt.nprocs == 2
        assert rt.block_size == 5
        assert rt.mode == "blocking"
        a = repro.ones((6, 6))
        got = np.asarray(a + 1.0)
    np.testing.assert_array_equal(got, np.full((6, 6), 2.0))


def test_runtime_helper_rejects_unknown_option():
    with pytest.raises(TypeError, match="unknown runtime option"):
        repro.runtime(nprocks=8)


def test_from_config_matches_kwargs():
    cfg = RuntimeConfig(nprocs=3, block_size=7, fusion=True)
    pol = ExecutionPolicy(scheduler="blocking")
    rt = repro.Runtime.from_config(cfg, pol)
    assert (rt.nprocs, rt.block_size, rt.fusion, rt.mode) == (3, 7, True, "blocking")
    assert rt.flush_backend == "sim"


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_builtin_registrations_present():
    assert {"numpy", "jax", "auto"} <= set(repro.available_backends())
    assert {"async", "blocking"} <= set(repro.available_channels())
    assert {"latency_hiding", "blocking"} <= set(repro.available_schedulers())


def test_duplicate_registration_refused():
    from repro.exec import NumpyBackend

    with pytest.raises(ValueError, match="already registered"):
        repro.register_backend("numpy", lambda s, c: NumpyBackend(s, c))
    # idempotent re-registration of the same object is fine
    repro.register_backend("numpy", BACKENDS.get("numpy"))


def test_early_builtin_shadowing_refused():
    """Registering a built-in name BEFORE any lookup must fail at the
    register() call (defaults are loaded first), not poison the registry
    by exploding later inside the defaults import.  Needs a fresh
    interpreter: in this process the defaults are long since loaded."""
    import os
    import subprocess
    import sys

    code = (
        "import repro.api.registry as R\n"
        "try:\n"
        "    R.register_backend('numpy', object())\n"
        "    raise SystemExit('early shadowing was accepted')\n"
        "except ValueError as e:\n"
        "    assert 'already registered' in str(e), e\n"
        "avail = set(R.available_backends())\n"
        "assert {'numpy', 'jax', 'auto'} <= avail, avail\n"
        "print('ok')\n"
    )
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0 and "ok" in proc.stdout, proc.stderr


def test_unknown_lookup_lists_registered():
    with pytest.raises(ValueError, match="registered:"):
        BACKENDS.get("missing")


def test_custom_backend_via_registry():
    """A user-registered backend is selectable by name end-to-end."""
    from repro.exec import NumpyBackend

    class CountingBackend(NumpyBackend):
        name = "counting"
        executed = 0

        def execute(self, op):
            CountingBackend.executed += 1
            super().execute(op)

    repro.register_backend("counting-test", CountingBackend)
    try:
        policy = ExecutionPolicy(flush="async", backend="counting-test")
        with repro.runtime(RuntimeConfig(nprocs=2, block_size=4), policy):
            got = np.asarray(repro.ones((8, 8)) * 3.0)
        np.testing.assert_array_equal(got, np.full((8, 8), 3.0))
        assert CountingBackend.executed > 0
    finally:
        BACKENDS.unregister("counting-test")


def test_custom_scheduler_via_registry():
    from repro.core.scheduler import run_schedule

    calls = []

    def tracing(deps, cluster, executor=None):
        calls.append(deps.n_pending)
        return run_schedule(deps, cluster, mode="latency_hiding", executor=executor)

    repro.register_scheduler("tracing-test", tracing)
    try:
        with repro.runtime(scheduler="tracing-test", nprocs=2, block_size=4):
            got = np.asarray(repro.ones((6, 6)) + 2.0)
        np.testing.assert_array_equal(got, np.full((6, 6), 3.0))
        assert calls  # our scheduler drained the flush
    finally:
        SCHEDULERS.unregister("tracing-test")


def test_custom_channel_via_registry():
    from repro.exec.channels import BlockingChannel

    made = []

    def factory(*, latency=0.0, progress_threads=2):
        ch = BlockingChannel(latency=latency)
        made.append(ch)
        return ch

    repro.register_channel("sync-test", factory)
    try:
        policy = ExecutionPolicy(flush="async", channel="sync-test")
        with repro.runtime(RuntimeConfig(nprocs=2, block_size=4), policy):
            got = np.asarray(repro.ones((8, 8)) + 1.0)
        np.testing.assert_array_equal(got, np.full((8, 8), 2.0))
        assert made and made[0].n_delivered >= 0
    finally:
        CHANNELS.unregister("sync-test")


# ---------------------------------------------------------------------------
# auto backend
# ---------------------------------------------------------------------------


def test_auto_backend_scoring():
    from repro.core.engine import MapPayload, TransferPayload
    from repro.core.ufunc import exp, add
    from repro.exec import AutoBackend

    class FakeFrag:
        size = 16384

    ab = AutoBackend({}, {})
    heavy = MapPayload(exp, 1, FakeFrag(), (), np.float64)  # 4x cost
    light = MapPayload(add, 1, FakeFrag(), (), np.float64)
    assert ab._score(heavy) >= ab.threshold
    assert ab._score(light) < ab.threshold
    assert ab._score(TransferPayload(("s", 1), 2)) == 0.0


def test_auto_backend_end_to_end():
    """ExecutionPolicy(backend="auto") drains correctly, mixing eager
    NumPy (small/memory-bound payloads) with jitted JAX (heavy ones)."""
    policy = ExecutionPolicy(flush="async", backend="auto")
    with repro.runtime(RuntimeConfig(nprocs=2, block_size=128), policy):
        a = repro.array(np.linspace(0.1, 1.0, 128 * 128).reshape(128, 128))
        got = np.asarray(np.exp(a) + a * 2.0)
    an = np.linspace(0.1, 1.0, 128 * 128).reshape(128, 128)
    np.testing.assert_allclose(got, np.exp(an) + an * 2.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# unified stats rendering + deprecations
# ---------------------------------------------------------------------------


def test_run_app_refuses_object_kwarg_mix():
    from benchmarks.paper_apps import run_app

    with pytest.raises(TypeError, match="policy="):
        run_app("jacobi_stencil", mode="blocking",
                policy=ExecutionPolicy(), n=16, iters=1)
    with pytest.raises(TypeError, match="config="):
        run_app("jacobi_stencil", nprocs=2,
                config=RuntimeConfig(nprocs=4, block_size=8), n=16, iters=1)


def test_format_stats_unifies_sim_and_measured():
    with repro.runtime(nprocs=2, block_size=4) as rt:
        np.asarray(repro.ones((8, 8)) + 1.0)
        sim = rt.stats()
    with repro.runtime(nprocs=2, block_size=4, flush="async") as rt:
        np.asarray(repro.ones((8, 8)) + 1.0)
        measured = rt.stats()
    table = format_stats([("model", sim), ("real", measured)])
    lines = table.splitlines()
    # header + one row per source + one dispatch line per source
    assert len(lines) == 5
    assert "makespan ms" in lines[0] and "wait%" in lines[0]
    assert "simulated" in lines[1]
    assert "measured" in lines[2]
    # dispatch-overhead counters: ops/s for both, handoffs/messages only
    # for the measured source (the simulator has no worker queues)
    assert lines[3].startswith("dispatch:") and "ops/s=" in lines[3]
    assert "handoffs/flush=       -" in lines[3]
    assert "handoffs/flush=" in lines[4] and "-" not in lines[4].split("handoffs/flush=")[1].split()[0]
    # the table-only form is still available
    assert len(format_stats([("model", sim)], dispatch=False).splitlines()) == 2
    # single-pair convenience form
    assert "model" in format_stats(("model", sim))


def test_legacy_reduction_shims_removed():
    """The pre-protocol dsum/dmin/dmax aliases are gone (deprecated for
    two PRs); np.sum / a.sum() is the only spelling."""
    from repro.core import darray as dnp

    for name in ("dsum", "dmin", "dmax"):
        assert not hasattr(dnp, name)
        assert name not in dnp.__all__
