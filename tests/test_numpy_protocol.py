"""NumPy-protocol dispatch on DistArray (paper's 'no user-visible API'
promise): ``np.<ufunc>(DistArray...)`` and ``np.<function>`` calls must

1. record lazily into the active runtime (no flush at call time),
2. match eager NumPy bit-for-bit after the flush (dtype included), and
3. behave identically when the recorded graphs are drained by the real
   async executor (``flush="async"``) with both registered reference
   backends.
"""
import numpy as np
import pytest

import repro
from repro.api import ExecutionPolicy, RuntimeConfig
from repro.core.darray import DistArray, Expr

A_NP = np.linspace(0.3, 2.7, 35).reshape(5, 7)
B_NP = np.linspace(1.1, 3.3, 35)[::-1].reshape(5, 7).copy()

UNARY = [np.exp, np.log, np.sqrt, np.square, np.absolute, np.negative]
BINARY = [
    np.add,
    np.subtract,
    np.multiply,
    np.divide,
    np.power,
    np.maximum,
    np.minimum,
    np.greater,
    np.less,
]


def _apply_np(fn, a, b):
    return fn(a) if fn in UNARY else fn(a, b)


def _record_and_check_lazy(rt, fn):
    a = repro.array(A_NP)
    b = repro.array(B_NP)
    res = _apply_np(fn, a, b)
    # recorded, not executed: nothing flushed, operations pending
    assert isinstance(res, (DistArray, Expr))
    assert rt.flush_count == 0
    assert rt.deps.n_pending > 0
    return res


@pytest.mark.parametrize("fn", UNARY + BINARY, ids=lambda f: f.__name__)
def test_ufunc_lazy_and_bit_identical(fn):
    with repro.runtime(nprocs=4, block_size=3) as rt:
        res = _record_and_check_lazy(rt, fn)
        got = np.asarray(res)
        assert rt.flush_count >= 1  # readback was the flush trigger
    want = _apply_np(fn, A_NP, B_NP)
    assert got.dtype == want.dtype  # comparisons return real bools
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fn", [np.exp, np.add, np.greater], ids=lambda f: f.__name__)
def test_ufunc_lazy_under_fusion(fn):
    with repro.runtime(nprocs=4, block_size=3, fusion=True):
        a = repro.array(A_NP)
        b = repro.array(B_NP)
        got = np.asarray(_apply_np(fn, a, b))
    want = _apply_np(fn, A_NP, B_NP)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize(
    "fn", [np.add, np.multiply, np.exp, np.sqrt, np.greater], ids=lambda f: f.__name__
)
def test_ufunc_through_async_executor(fn, backend):
    policy = ExecutionPolicy(flush="async", backend=backend)
    with repro.runtime(RuntimeConfig(nprocs=2, block_size=3), policy) as rt:
        res = _record_and_check_lazy(rt, fn)
        got = np.asarray(res)
    want = _apply_np(fn, A_NP, B_NP)
    assert got.dtype == want.dtype
    if backend == "numpy":
        # bit-identical by construction (same payload interpreter)
        np.testing.assert_array_equal(got, want)
    else:
        # float32 compute without jax_enable_x64: close, not identical
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# np functions (__array_function__) and reductions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "fn,want_fn",
    [
        (lambda a: np.sum(a), lambda a: np.sum(a)),
        (lambda a: np.sum(a, axis=0), lambda a: np.sum(a, axis=0)),
        (lambda a: np.sum(a, axis=1, keepdims=True),
         lambda a: np.sum(a, axis=1, keepdims=True)),
        (lambda a: np.min(a, axis=0), lambda a: np.min(a, axis=0)),
        (lambda a: np.max(a, axis=1), lambda a: np.max(a, axis=1)),
        (lambda a: np.amax(a), lambda a: np.amax(a)),
        (lambda a: np.roll(a, 3, axis=1), lambda a: np.roll(a, 3, axis=1)),
        (lambda a: np.where(np.greater(a, 1.5), a, -a),
         lambda a: np.where(np.greater(a, 1.5), a, -a)),
        (lambda a: np.matmul(a[:, :5], a[:5, :]),
         lambda a: np.matmul(a[:, :5], a[:5, :])),
        (lambda a: np.add.reduce(a), lambda a: np.add.reduce(a)),
    ],
    ids=["sum", "sum_axis0", "sum_keepdims", "min_axis0", "max_axis1",
         "amax", "roll", "where", "matmul", "add_reduce"],
)
def test_np_functions_match(fn, want_fn):
    with repro.runtime(nprocs=4, block_size=3) as rt:
        a = repro.array(A_NP)
        res = fn(a)
        assert rt.flush_count == 0
        got = np.asarray(res)
    # reductions/matmul reassociate across blocks (np.sum is pairwise),
    # so equality is to the last ulp, not bitwise
    np.testing.assert_allclose(got, want_fn(A_NP), rtol=1e-12, atol=0)


def test_mixed_ndarray_operands():
    """np.<ufunc>(ndarray, DistArray) dispatches to us (priority) and the
    host array is scattered automatically."""
    with repro.runtime(nprocs=4, block_size=3):
        a = repro.array(A_NP)
        got1 = np.asarray(np.add(B_NP, a))
        got2 = np.asarray(B_NP * a)
        got3 = np.asarray(a / B_NP)
    np.testing.assert_array_equal(got1, B_NP + A_NP)
    np.testing.assert_array_equal(got2, B_NP * A_NP)
    np.testing.assert_array_equal(got3, A_NP / B_NP)


def test_out_kwarg_records_into_target():
    with repro.runtime(nprocs=4, block_size=3) as rt:
        a = repro.array(A_NP)
        b = repro.array(B_NP)
        c = repro.zeros(A_NP.shape)
        ret = np.add(a, b, out=c)
        assert ret is c
        assert rt.flush_count == 0
        got = np.asarray(c)
    np.testing.assert_array_equal(got, A_NP + B_NP)


def test_comparison_dtype_is_bool():
    with repro.runtime(nprocs=4, block_size=3):
        a = repro.array(A_NP)
        g = np.greater(a, 1.5)
        assert g.dtype == np.bool_
        got = np.asarray(g)
    assert got.dtype == np.bool_
    np.testing.assert_array_equal(got, A_NP > 1.5)


def test_bool_sum_counts_like_numpy():
    """np.sum(comparison) is the counting idiom: must promote to int,
    not saturate at True."""
    with repro.runtime(nprocs=4, block_size=3):
        a = repro.array(A_NP)
        n = np.sum(np.greater(a, 1.5))
        per_col = np.sum(np.less(a, 1.5), axis=0)
        got_n, got_cols = np.asarray(n), np.asarray(per_col)
    assert got_n.dtype == np.int64
    assert got_n.item() == int(np.sum(A_NP > 1.5))
    np.testing.assert_array_equal(got_cols, np.sum(A_NP < 1.5, axis=0))
    # min/max of bools stay bool, as in NumPy
    with repro.runtime(nprocs=4, block_size=3):
        m = np.max(np.greater(repro.array(A_NP), 1.5))
        assert m.dtype == np.bool_
        assert np.asarray(m).item() == bool(np.max(A_NP > 1.5))


def test_unsupported_kwargs_fall_back_cleanly():
    with repro.runtime(nprocs=4, block_size=3):
        a = repro.array(A_NP)
        with pytest.raises(TypeError):
            np.add(a, a, where=np.ones_like(A_NP, dtype=bool))


def test_whole_program_only_numpy_namespace():
    """The acceptance program shape: slicing + np ops, no repro-specific
    operation names, async drain equals the simulator bit-for-bit."""

    def prog():
        f = repro.zeros((13, 13))
        f[0, :] = 1.0
        for _ in range(3):
            f[1:-1, 1:-1] = 0.2 * (
                f[1:-1, 1:-1] + f[:-2, 1:-1] + f[2:, 1:-1]
                + f[1:-1, :-2] + f[1:-1, 2:]
            )
        return np.asarray(np.sum(np.square(f), axis=0))

    with repro.runtime(nprocs=4, block_size=4):
        ref = prog()
    with repro.runtime(
        RuntimeConfig(nprocs=4, block_size=4),
        ExecutionPolicy(flush="async", backend="numpy"),
    ):
        got = prog()
    f = np.zeros((13, 13))
    f[0, :] = 1.0
    for _ in range(3):
        f[1:-1, 1:-1] = 0.2 * (
            f[1:-1, 1:-1] + f[:-2, 1:-1] + f[2:, 1:-1]
            + f[1:-1, :-2] + f[1:-1, 2:]
        )
    want = np.sum(np.square(f), axis=0)
    # sim and async drains of the same graphs are bit-identical to each
    # other; vs NumPy the blocked reduction reassociates (ulp-level)
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_allclose(ref, want, rtol=1e-12)
