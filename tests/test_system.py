"""End-to-end system tests: the paper's behaviour claims, checked small.

These exercise the whole stack the way §6 does: an application written
against the DistArray API, executed under both scheduling modes, with
the paper's qualitative claims asserted (identical results; LH strictly
reduces waiting on comm-bound apps; no benefit on embarrassingly
parallel apps; the dependency heuristic beats the full DAG).
"""
import numpy as np
import pytest

from benchmarks.paper_apps import run_app
from repro.core import Runtime
from repro.core import darray as dnp


def test_stencil_latency_hiding_beats_blocking():
    kw = dict(n=512, iters=4)
    st_lh, r_lh = run_app("jacobi_stencil", mode="latency_hiding", block_size=128, **kw)
    st_bl, r_bl = run_app("jacobi_stencil", mode="blocking", block_size=128, **kw)
    np.testing.assert_allclose(r_lh, r_bl)
    assert st_lh.makespan < st_bl.makespan * 0.8
    assert st_lh.wait_fraction < st_bl.wait_fraction


def test_embarrassingly_parallel_no_benefit():
    kw = dict(n=256, iters=4)
    st_lh, r_lh = run_app("fractal", mode="latency_hiding", **kw)
    st_bl, r_bl = run_app("fractal", mode="blocking", **kw)
    np.testing.assert_allclose(r_lh, r_bl)
    # no communication → the two schedules are equivalent (±5%)
    assert st_lh.makespan == pytest.approx(st_bl.makespan, rel=0.05)


def test_fusion_reduces_operations_same_result():
    kw = dict(n=256, iters=3)
    st_plain, r_plain = run_app("jacobi_stencil", block_size=64, **kw)
    st_fused, r_fused = run_app("jacobi_stencil", block_size=64, fusion=True, **kw)
    np.testing.assert_allclose(r_plain, r_fused)
    assert st_fused.n_compute_ops < st_plain.n_compute_ops


def test_lbm_identical_across_modes():
    st_lh, r_lh = run_app("lbm2d", mode="latency_hiding", h=64, w=64, steps=3)
    st_bl, r_bl = run_app("lbm2d", mode="blocking", h=64, w=64, steps=3)
    np.testing.assert_allclose(r_lh, r_bl)


def test_nprocs_sweep_consistency():
    """The same program gives identical numerics for any process count
    and block size (the auto-parallelization transparency claim)."""
    def prog():
        a = dnp.array(np.arange(100.0).reshape(10, 10))
        b = a[1:, :-1] * 2.0 + a[:-1, 1:]
        return np.asarray(b.sum(axis=0))

    ref = None
    for nprocs in (1, 3, 8):
        for bs in (2, 5, 16):
            with Runtime(nprocs=nprocs, block_size=bs):
                got = prog()
            if ref is None:
                ref = got
            np.testing.assert_allclose(got, ref)


def test_depsys_scales_better_than_dag():
    from benchmarks.depsys_overhead import measure

    m = measure(1500, n_blocks=128)
    assert m["heuristic"]["scan_steps"] * 20 < m["full_dag"]["scan_steps"]


def test_timeline_projects_to_tpu_cluster():
    """The α–β model parametrized to TPU ICI still shows the LH win
    (the projection used in DESIGN.md §3)."""
    from repro.core.timeline import TPU_V5E_ICI

    kw = dict(n=512, iters=3)
    st_lh, _ = run_app("jacobi_stencil", mode="latency_hiding",
                       cluster=TPU_V5E_ICI.with_nprocs(16), execute=False,
                       block_size=128, **kw)
    st_bl, _ = run_app("jacobi_stencil", mode="blocking",
                       cluster=TPU_V5E_ICI.with_nprocs(16), execute=False,
                       block_size=128, **kw)
    assert st_lh.makespan <= st_bl.makespan
