"""Plan-stage tests: the record → plan → execute pipeline, the pass
registry, and the built-in passes (coalesce / fuse / batch).

The invariant under test is the plan-stage correctness contract: a pass
must preserve the relative program order of every pair of conflicting
accesses, so planned graphs produce block contents bit-identical to the
unplanned simulator.  ``test_plan_properties.py`` checks the same
contract on random programs with hypothesis.
"""
import numpy as np
import pytest

import repro
from repro.api import ExecutionPolicy
from repro.api.registry import PASSES
from repro.core import DependencySystem, plan, resolve_pipeline
from repro.core.plan import DEFAULT_ASYNC_PIPELINE

from benchmarks.paper_apps import run_app


# ---------------------------------------------------------------------------
# pipeline resolution + registry
# ---------------------------------------------------------------------------


def test_builtin_passes_registered():
    names = repro.available_passes()
    for name in ("coalesce", "fuse", "batch"):
        assert name in names


def test_resolve_pipeline():
    assert resolve_pipeline("auto", "async") == DEFAULT_ASYNC_PIPELINE
    assert resolve_pipeline("auto", "sim") == ()
    assert resolve_pipeline("coalesce, fuse", "sim") == ("coalesce", "fuse")
    assert resolve_pipeline((), "async") == ()
    assert resolve_pipeline(None, "async") == ()
    with pytest.raises(ValueError, match="unknown pass"):
        resolve_pipeline("nope", "sim")


def test_policy_validates_passes():
    assert ExecutionPolicy(passes="coalesce,batch").passes == "coalesce,batch"
    assert ExecutionPolicy(passes=["coalesce"]).passes == ("coalesce",)
    assert ExecutionPolicy(flush="async").resolved_passes == DEFAULT_ASYNC_PIPELINE
    assert ExecutionPolicy().resolved_passes == ()
    with pytest.raises(ValueError, match="unknown pass"):
        ExecutionPolicy(passes="nope")
    with pytest.raises(ValueError, match="unknown pass"):
        repro.Runtime(nprocs=2, passes="nope")


def test_custom_pass_pluggable():
    """A user pass registers by name and runs in the pipeline — the same
    plugin mechanism as backends and channels."""
    seen = {}

    def tag_everything(ctx):
        seen["ops"] = len(ctx.ops)
        ctx.hints["tagged"] = True

    repro.register_pass("tag-everything", tag_everything)
    try:
        with repro.runtime(nprocs=2, block_size=4,
                           passes=("tag-everything",)) as rt:
            a = repro.ones((8, 8))
            np.asarray(a + 1.0)
        assert seen["ops"] > 0
        with pytest.raises(ValueError, match="already registered"):
            repro.register_pass("tag-everything", lambda ctx: None)
    finally:
        PASSES.unregister("tag-everything")


def test_plan_noop_without_pipeline():
    deps = DependencySystem()
    res = plan(deps, ())
    assert res.deps is deps and res.hints == {}


# ---------------------------------------------------------------------------
# coalesce: fewer messages, same bits
# ---------------------------------------------------------------------------


def test_coalesce_sim_bit_identical_fewer_messages():
    kw = dict(n=96, iters=3, nprocs=4, block_size=16)
    st0, ref = run_app("jacobi_stencil", **kw)
    st1, got = run_app("jacobi_stencil",
                       policy=ExecutionPolicy(passes=("coalesce",)), **kw)
    assert np.array_equal(ref, got)
    assert 0 < st1.n_comm_ops < st0.n_comm_ops
    assert st1.comm_bytes == st0.comm_bytes  # merged, not dropped


def test_coalesce_async_fewer_posted_messages():
    kw = dict(n=96, iters=3, nprocs=4, block_size=16)
    _, ref = run_app("jacobi_stencil", **kw)
    st1, got1 = run_app("jacobi_stencil", flush_backend="async",
                        passes=("coalesce",), **kw)
    st0, got0 = run_app("jacobi_stencil", flush_backend="async",
                        passes=(), **kw)
    assert np.array_equal(ref, np.asarray(got1))
    assert np.array_equal(ref, np.asarray(got0))
    assert 0 < st1.n_messages < st0.n_messages


def test_replan_of_planned_graph_preserves_program_order():
    """pending_ops must key on insertion (program) order, not uid: a
    plan-created merged node has a larger uid than the recorded ops
    around it, so re-planning a planned graph (flush retry, or more
    recording after a manual plan) must not sort it past the consumers
    of its scratch buffers."""
    from repro.core import darray as dnp
    from repro.core.plan import plan as run_plan

    data = np.arange(144.0).reshape(12, 12)
    with repro.Runtime(nprocs=4, block_size=3, passes=("coalesce",)) as rt:
        a = dnp.array(data)
        b = a[0:11, :] + a[1:12, :]  # halo reads cross block-row owners
        planned = run_plan(rt.deps, ("coalesce",), storage=rt.storage)
        assert planned.stats.n_transfers_coalesced > 0
        ops = planned.deps.pending_ops()
        assert [o.seq for o in ops] == list(range(len(ops)))
        assert any(o.label.startswith("xfer-coalesced") for o in ops)
        rt.deps = planned.deps
        c = a[0:10, :] + a[2:12, :]  # fresh transfers into the planned graph
        rb, rc = np.asarray(b), np.asarray(c)  # flush re-plans the mix
        assert rt.plan_stats.n_transfers_coalesced > 0
    np.testing.assert_array_equal(rb, data[0:11] + data[1:12])
    np.testing.assert_array_equal(rc, data[0:10] + data[2:12])


def test_coalesce_respects_intervening_writes():
    """Transfers across a write to their source must not merge past it
    (hoisting the read would see the wrong version)."""
    with repro.runtime(nprocs=4, block_size=4, passes=("coalesce",)) as rt:
        a = repro.array(np.arange(64.0).reshape(8, 8))
        b = a[0:4, :] + a[4:8, :]  # cross-block reads -> transfers
        a[:, :] = a * 2.0  # write to every block of a
        c = a[0:4, :] + a[4:8, :]  # transfers of the NEW version
        rb, rc = np.asarray(b), np.asarray(c)
    base = np.arange(64.0).reshape(8, 8)
    np.testing.assert_array_equal(rb, base[0:4] + base[4:8])
    np.testing.assert_array_equal(rc, 2 * base[0:4] + 2 * base[4:8])


# ---------------------------------------------------------------------------
# fuse: map→reduce fusion, fill const-fold, dead-store elimination
# ---------------------------------------------------------------------------


def test_fuse_map_reduce_on_dead_temp():
    data = np.arange(64.0).reshape(8, 8)
    with repro.runtime(nprocs=4, block_size=3, passes=("fuse",)) as rt:
        x = repro.array(data)
        r = np.asarray((x * x).sum(axis=0))  # temp x*x is dead at flush
        stats = rt.plan_stats
    assert stats.n_fused > 0
    assert stats.n_ops_out < stats.n_ops_in
    np.testing.assert_array_equal(r, (data * data).sum(axis=0))


def test_fuse_keeps_live_temps():
    """A temporary that is still referenced (could be gathered later)
    must not lose its block writes."""
    data = np.arange(64.0).reshape(8, 8)
    with repro.runtime(nprocs=4, block_size=3, passes=("fuse",)) as rt:
        x = repro.array(data)
        t = x * x  # live: we read it after the reduction
        s = np.asarray(t.sum(axis=0))
        tv = np.asarray(t)
    np.testing.assert_array_equal(s, (data * data).sum(axis=0))
    np.testing.assert_array_equal(tv, data * data)


def test_fuse_const_folds_fills_and_drops_dead_stores():
    with repro.runtime(nprocs=4, block_size=3, passes=("fuse",)) as rt:
        x = repro.empty((8, 8))
        x[:, :] = 3.0  # recorded fill
        y = x * 2.0  # reads only the filled region
        del x  # x is dead: fill becomes a dead store after folding
        r = np.asarray(y)
        stats = rt.plan_stats
    assert stats.n_const_folded > 0
    assert stats.n_dropped > 0
    assert (r == 6.0).all()


def test_fuse_partial_fill_not_folded():
    """A fill covering only part of what the map reads must survive."""
    data = np.arange(64.0).reshape(8, 8)
    with repro.runtime(nprocs=4, block_size=8, passes=("fuse",)) as rt:
        x = repro.array(data)
        x[0:2, :] = 1.0  # partial fill of the single block
        r = np.asarray(x * 1.0)
    expect = data.copy()
    expect[0:2, :] = 1.0
    np.testing.assert_array_equal(r, expect)


# ---------------------------------------------------------------------------
# batch: strictly fewer handoffs, same bits
# ---------------------------------------------------------------------------


def _chain(passes, steps=40, nblocks=8, block=16):
    with repro.runtime(nprocs=4, block_size=block, flush="async",
                       passes=passes) as rt:
        a = repro.ones((nblocks * block,))
        for _ in range(steps):
            a += 1.0
        return rt.stats(), np.asarray(a)


def test_batch_dispatch_fewer_handoffs():
    st_b, r_b = _chain(("batch",))
    st_u, r_u = _chain(())
    np.testing.assert_array_equal(r_b, r_u)
    assert 0 < st_b.n_handoffs < st_u.n_handoffs


def test_default_async_pipeline_reports_counters():
    """The auto pipeline wires its wins into the measured WaitStats."""
    st, _ = run_app("jacobi_stencil", nprocs=4, block_size=16,
                    flush_backend="async", n=64, iters=2)
    assert st.n_handoffs > 0
    assert st.n_messages == st.n_comm_ops > 0  # coalesced posts, counted once
    assert st.handoffs_per_flush > 0
    assert st.ops_per_sec > 0
