"""Demand-driven evaluation surface: dependency-cone extraction, partial
non-blocking flushes (FlushTicket), the futures API
(repro.evaluate/gather/wait), WaitStats accumulation across partial
flushes, executor-resource lifecycle (Runtime.close), and the error
surface of the redesigned API."""
import numpy as np
import pytest

import repro
from repro.api import ExecutionPolicy
from repro.api.futures import ArrayFuture
from repro.core.engine import FlushTicket
from repro.core.graph import (
    COMM,
    COMPUTE,
    AccessNode,
    DependencySystem,
    OperationNode,
    producer_cone,
)


# ---------------------------------------------------------------------------
# producer_cone — the graph-level closure
# ---------------------------------------------------------------------------


def _op(writes, reads=(), kind=COMPUTE, label=""):
    op = OperationNode(kind, None, procs=(0,), label=label)
    for key in writes:
        op.add_access(AccessNode(key, None, write=True))
    for key in reads:
        op.add_access(AccessNode(key, None, write=False))
    return op


def test_cone_picks_only_the_producer_chain():
    # two independent chains on bases 1 and 2
    a1 = _op(writes=[(1, (0,))], label="w1a")
    a2 = _op(writes=[(1, (0,))], reads=[(1, (0,))], label="w1b")
    b1 = _op(writes=[(2, (0,))], label="w2a")
    b2 = _op(writes=[(2, (0,))], reads=[(2, (0,))], label="w2b")
    ops = [a1, b1, a2, b2]
    cone, rest = producer_cone(ops, {1})
    assert cone == [a1, a2]
    assert rest == [b1, b2]


def test_cone_transitive_through_scratch():
    # transfer writes scratch, consumer of base 2 reads it; base 2's cone
    # must pull the transfer AND the producer of the transferred block
    src = _op(writes=[(1, (0,))], label="produce-src")
    xfer = _op(writes=[("s", 7)], reads=[(1, (0,))], kind=COMM, label="xfer")
    cons = _op(writes=[(2, (0,))], reads=[("s", 7)], label="consume")
    other = _op(writes=[(3, (0,))], label="other")
    cone, rest = producer_cone([src, xfer, other, cons], {2})
    assert cone == [src, xfer, cons]
    assert rest == [other]


def test_cone_respects_anti_dependencies():
    # read of base 1 recorded BETWEEN two writes must drain with the
    # cone, or it would observe the post-cone value
    w1 = _op(writes=[(1, (0,))], label="w1")
    r = _op(writes=[(9, (0,))], reads=[(1, (0,))], label="reader")
    w2 = _op(writes=[(1, (0,))], label="w2")
    cone, rest = producer_cone([w1, r, w2], {1})
    assert cone == [w1, r, w2]
    assert rest == []


def test_cone_leaves_late_readers_behind():
    # a read recorded AFTER the last pending write of the target stays
    # pending: draining the cone first cannot change what it reads
    w1 = _op(writes=[(1, (0,))], label="w1")
    late = _op(writes=[(9, (0,))], reads=[(1, (0,))], label="late-reader")
    cone, rest = producer_cone([w1, late], {1})
    assert cone == [w1]
    assert rest == [late]


def test_cone_rebuild_roundtrip_executes_both_halves():
    with repro.runtime(nprocs=4, block_size=4, sync="demand") as rt:
        x = repro.ones((8, 8))
        y = repro.ones((8, 8))
        x2 = x * 2.0
        y2 = y * 3.0
        total = rt.deps.n_pending
        vx = np.asarray(x2)  # cone flush: only x2's producers
        assert 0 < rt.deps.n_pending < total
        vy = np.asarray(y2)
        assert rt.deps.n_pending == 0
    np.testing.assert_array_equal(vx, np.full((8, 8), 2.0))
    np.testing.assert_array_equal(vy, np.full((8, 8), 3.0))


# ---------------------------------------------------------------------------
# partial + non-blocking flush (FlushTicket)
# ---------------------------------------------------------------------------


def test_flush_targets_drains_partial_graph_sim():
    with repro.runtime(nprocs=2, block_size=4) as rt:
        a = repro.ones((8,)) + 1.0
        b = repro.ones((8,)) * 5.0
        res = rt.flush(targets=(a,))
        assert res is not None
        assert rt.deps.n_pending > 0  # b's ops untouched
        np.testing.assert_array_equal(np.asarray(b), np.full((8,), 5.0))


def test_flush_nowait_returns_ticket_without_joining():
    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        a = repro.ones((16,))
        for _ in range(64):
            a += 1.0
        t = rt.flush(wait=False)
        assert isinstance(t, FlushTicket)
        # recording continues while the drain is (possibly) in flight
        a += 1.0
        st = t.wait()
        assert st.n_compute_ops > 0
        assert t.wait() is st  # idempotent
        np.testing.assert_array_equal(np.asarray(a), np.full((16,), 66.0))


def test_flush_nowait_empty_graph_gives_completed_ticket():
    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        t = rt.flush(wait=False)
        assert isinstance(t, FlushTicket) and t.done()
        assert t.wait() is None


def test_sim_backend_ticket_comes_back_completed():
    with repro.runtime(nprocs=2, block_size=8) as rt:
        a = repro.ones((8,)) + 1.0
        t = rt.flush(wait=False)
        assert t.done()
        assert t.wait() is not None
        np.testing.assert_array_equal(np.asarray(a), np.full((8,), 2.0))


def test_readback_forces_only_its_cone_async():
    with repro.runtime(nprocs=4, block_size=64, flush="async") as rt:
        arrs = [repro.ones((64,)) for _ in range(8)]
        for _ in range(10):
            for x in arrs:
                x += 1.0
        recorded = rt.deps.n_pending
        np.asarray(arrs[0])
        drained = rt.exec_stats.n_compute_ops + rt.exec_stats.n_comm_ops
        assert drained < recorded / 4  # one chain out of eight
        for x in arrs:
            np.testing.assert_array_equal(np.asarray(x), np.full((64,), 11.0))


def test_subview_readback_forces_only_touched_blocks():
    # one base, 4 blocks, independent per-block chains: reading a
    # sub-view must drain only the blocks that view touches
    with repro.runtime(nprocs=4, block_size=16, flush="async") as rt:
        a = repro.ones((64,))
        for _ in range(8):
            a += 1.0  # per-block fragments: 4 independent chains
        recorded = rt.deps.n_pending
        v = np.asarray(a[0:16])  # exactly one block
        drained = rt.exec_stats.n_compute_ops + rt.exec_stats.n_comm_ops
        assert drained <= recorded // 4
        assert rt.deps.n_pending == recorded - drained
        np.testing.assert_array_equal(v, np.full((16,), 9.0))
        np.testing.assert_array_equal(np.asarray(a), np.full((64,), 9.0))


def test_barrier_sync_preserves_whole_graph_flush():
    with repro.runtime(
        nprocs=4, block_size=64, flush="async", sync="barrier"
    ) as rt:
        arrs = [repro.ones((64,)) for _ in range(4)]
        for _ in range(5):
            for x in arrs:
                x += 1.0
        recorded = rt.deps.n_pending
        np.asarray(arrs[0])
        assert rt.deps.n_pending == 0  # everything drained at once
        drained = rt.exec_stats.n_compute_ops + rt.exec_stats.n_comm_ops
        assert drained == recorded


def test_demand_bit_identical_to_barrier():
    def run(sync, order):
        with repro.runtime(
            nprocs=4, block_size=8, flush="async", sync=sync
        ) as rt:
            a = repro.ones((16, 16))
            b = a * 2.0 + 1.0
            c = np.sqrt(a + 3.0)
            d = (b + c).sum(axis=0)
            outs = [b, c, d]
            got = [None] * 3
            for i in order:
                got[i] = np.asarray(outs[i]).copy()
            return got

    ref = run("barrier", [0, 1, 2])
    for order in ([2, 0, 1], [1, 2, 0], [0, 2, 1]):
        got = run("demand", order)
        for r, g in zip(ref, got):
            np.testing.assert_array_equal(r, g)


# ---------------------------------------------------------------------------
# futures surface: evaluate / gather / wait
# ---------------------------------------------------------------------------


def test_evaluate_returns_future_and_result_gathers():
    with repro.runtime(nprocs=2, block_size=8, flush="async"):
        a = repro.ones((16,)) * 4.0
        fut = repro.evaluate(a)
        assert isinstance(fut, ArrayFuture)
        assert fut.shape == (16,) and fut.dtype == np.float64
        out = fut.result()
        np.testing.assert_array_equal(out, np.full((16,), 4.0))
        assert fut.done()


def test_evaluate_many_shares_one_ticket():
    with repro.runtime(nprocs=2, block_size=8, flush="async"):
        a = repro.ones((16,)) + 1.0
        b = repro.ones((16,)) + 2.0
        fa, fb = repro.evaluate(a, b)
        assert fa._ticket is fb._ticket
        np.testing.assert_array_equal(fa.result(), np.full((16,), 2.0))
        np.testing.assert_array_equal(fb.result(), np.full((16,), 3.0))


def test_evaluate_does_not_drain_unrelated_work():
    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        a = repro.ones((16,)) + 1.0
        b = repro.ones((16,)) * 9.0
        fut = repro.evaluate(a)
        fut.block_until_ready()
        assert rt.deps.n_pending > 0  # b still lazy
        np.testing.assert_array_equal(np.asarray(b), np.full((16,), 9.0))


def test_block_until_ready_method():
    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        a = repro.ones((16,)) + 6.0
        same = a.block_until_ready()
        assert same is a
        # the cone drained: a's value is materialized in block storage
        assert rt.deps.n_pending == 0
        np.testing.assert_array_equal(np.asarray(a), np.full((16,), 7.0))


def test_wait_accepts_arrays_and_futures():
    with repro.runtime(nprocs=2, block_size=8, flush="async"):
        a = repro.ones((16,)) + 1.0
        b = repro.ones((16,)) + 2.0
        fut = repro.evaluate(b)
        ra, rfut = repro.wait(a, fut)
        assert ra is a and rfut is fut
        np.testing.assert_array_equal(repro.gather(ra), np.full((16,), 2.0))
        np.testing.assert_array_equal(repro.gather(rfut), np.full((16,), 3.0))


def test_gather_on_expr_and_ndarray():
    host = np.arange(4.0)
    assert repro.gather(host) is host
    with repro.runtime(nprocs=2, block_size=4, fusion=True):
        a = repro.ones((8,))
        expr = a * 2.0 + 1.0  # Expr under fusion=True
        np.testing.assert_array_equal(repro.gather(expr), np.full((8,), 3.0))


def test_np_asarray_on_future():
    with repro.runtime(nprocs=2, block_size=8, flush="async"):
        a = repro.ones((8,)) * 5.0
        fut = repro.evaluate(a)
        np.testing.assert_array_equal(np.asarray(fut), np.full((8,), 5.0))


# ---------------------------------------------------------------------------
# WaitStats accumulation across partial flushes (regression: whole-program
# wait%, not last-cone wait%)
# ---------------------------------------------------------------------------


def _disjoint_cone_program(rt):
    """Two disjoint cones, each with cross-owner transfers (shifted-slice
    products force neighbour communication)."""
    x = repro.ones((64,))
    y = repro.ones((64,))
    xs = x[0:63] * x[1:64]
    ys = y[0:63] + y[1:64]
    return xs, ys


def test_waitstats_merge_across_two_disjoint_cones():
    with repro.runtime(nprocs=4, block_size=16, flush="async") as rt:
        xs, ys = _disjoint_cone_program(rt)
        np.asarray(xs)  # cone 1
        st1 = rt.stats()
        ops1 = st1.n_compute_ops + st1.n_comm_ops
        msgs1 = st1.n_messages
        hand1 = st1.n_handoffs
        assert st1.n_flushes == 1 and ops1 > 0 and msgs1 > 0
        np.asarray(ys)  # cone 2 (disjoint)
        st2 = rt.stats()
        assert st2 is st1  # one accumulating object
        assert st2.n_flushes == 2
        assert st2.n_compute_ops + st2.n_comm_ops > ops1
        assert st2.n_messages > msgs1  # PR-3 counters keep accumulating
        assert st2.n_handoffs >= hand1
        # whole-program: elapsed is the sum of both drains, and the wait
        # fraction is computed against it (not the last cone's)
        assert st2.elapsed > st1.elapsed or st1.elapsed == st2.elapsed
        assert 0.0 <= st2.wait_fraction <= 1.0


def test_stats_joins_outstanding_nonblocking_flush():
    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        a = repro.ones((16,))
        for _ in range(32):
            a += 1.0
        rt.flush(wait=False)
        st = rt.stats()  # must include the in-flight drain
        assert st.n_compute_ops >= 32
        assert not rt._tickets


def test_format_stats_renders_merged_demand_stats():
    with repro.runtime(nprocs=2, block_size=16, flush="async") as rt:
        a = repro.ones((32,)) + 1.0
        b = repro.ones((32,)) + 2.0
        np.asarray(a)
        np.asarray(b)
        out = repro.format_stats([("demand", rt.stats())])
    assert "measured" in out and "ops/flush" in out


# ---------------------------------------------------------------------------
# executor-resource lifecycle (Runtime.close)
# ---------------------------------------------------------------------------


def test_close_shuts_down_executor_and_channel():
    rt = repro.runtime(nprocs=2, block_size=8, flush="async")
    with rt:
        a = repro.ones((16,))
        np.asarray(a + 1.0)
        executor = rt._exec_executor_obj
        channel = rt._exec_channel_obj
        assert executor is not None and channel is not None
        assert any(w.is_alive() for w in executor.workers)
    # __exit__ (clean path) closed everything
    assert rt._exec_executor_obj is None and rt._exec_channel_obj is None
    assert not any(w.is_alive() for w in executor.workers)
    assert all(not t.is_alive() for t in getattr(channel, "_threads", []))


def test_close_on_exception_path_and_double_close():
    rt = repro.runtime(nprocs=2, block_size=8, flush="async")
    with pytest.raises(ValueError, match="boom"):
        with rt:
            a = repro.ones((16,))
            np.asarray(a + 1.0)
            executor = rt._exec_executor_obj
            raise ValueError("boom")
    assert rt._exec_executor_obj is None  # closed despite the exception
    assert not any(w.is_alive() for w in executor.workers)
    rt.close()  # double close is a no-op
    rt.close()


def test_flush_after_close_raises():
    rt = repro.runtime(nprocs=2, block_size=8, flush="async")
    with rt:
        np.asarray(repro.ones((8,)) + 1.0)
    with pytest.raises(RuntimeError, match="closed"):
        rt.flush()


def test_close_joins_outstanding_concurrent_drains():
    """close() with several cones still in flight must join them all
    and release the pool — the serving shutdown path."""
    from repro.core import engine as _engine

    rt = repro.runtime(nprocs=2, block_size=8, flush="async", latency=2e-3)
    # bind TLS directly (not the context manager: __exit__ itself closes)
    prev = getattr(_engine._tls, "runtime", None)
    _engine._tls.runtime = rt
    try:
        arrs = [repro.ones((8,)) + float(i) for i in range(3)]
        tickets = [rt.flush(wait=False, targets=[a]) for a in arrs]
    finally:
        _engine._tls.runtime = prev
    rt.close()  # none of the tickets were waited on
    assert all(t.done() for t in tickets)
    assert rt._exec_executor_obj is None
    rt.close()  # double close stays a no-op


def test_close_surfaces_unobserved_drain_failure():
    """An in-flight drain that fails before anyone waits on its ticket
    must surface its exception from close() — after the resources are
    released — instead of vanishing."""
    from repro.core import engine as _engine
    from repro.core.ufunc import UFunc

    def _raise(x):
        raise ValueError("boom-close")

    boom = UFunc(name="boom_close_test", fn=_raise, nin=1)
    rt = repro.runtime(nprocs=2, block_size=8, flush="async", latency=2e-3)
    prev = getattr(_engine._tls, "runtime", None)
    _engine._tls.runtime = rt
    try:
        a = repro.ones((8,))
        rt.record_map(boom, (a._base, a._view), [(a._base, a._view)])
        rt.flush(wait=False, targets=[a])
    finally:
        _engine._tls.runtime = prev
    with pytest.raises(ValueError, match="boom-close"):
        rt.close()
    assert rt._exec_executor_obj is None  # released despite the error
    rt.close()  # and still a no-op afterwards


def test_executor_reusable_after_failed_drain():
    """A drain that errors must not wedge the persistent executor: the
    in-flight accounting resets, so a later submit still completes."""
    from repro.exec import AsyncExecutor

    class Boom:
        pass

    deps = DependencySystem()
    bad = OperationNode(COMPUTE, Boom(), procs=(0,), label="bad")
    bad.add_access(AccessNode((1, (0,)), None, write=True))
    deps.insert(bad)
    ex = AsyncExecutor(nworkers=2, storage={}, scratch={})
    try:
        with pytest.raises(TypeError, match="unknown payload"):
            ex.submit(deps).result(timeout=10.0)
        assert ex.n_active_drains == 0
    finally:
        ex.close()


def test_worker_idle_excludes_time_parked_between_drains():
    import time

    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        a = repro.ones((16,)) + 1.0
        np.asarray(a)  # drain 1
        time.sleep(0.5)  # main thread "records" for a while
        b = repro.ones((16,)) + 2.0
        np.asarray(b)  # drain 2
        st = rt.stats()
        # the 0.5 s gap must not be attributed to dependency-wait idle
        assert sum(p.idle for p in st.procs) < 0.25


def test_evaluate_rewraps_future_with_fresh_ticket():
    with repro.runtime(nprocs=2, block_size=8, flush="async"):
        a = repro.ones((16,))
        a += 1.0
        f1 = repro.evaluate(a)
        f1.block_until_ready()
        a += 5.0
        f2 = repro.evaluate(f1)
        assert f2 is not f1  # covers the drain this call submitted
        assert f2._ticket is not f1._ticket
        np.testing.assert_array_equal(f2.result(), np.full((16,), 7.0))


# ---------------------------------------------------------------------------
# error surface of the redesigned API
# ---------------------------------------------------------------------------


def test_gather_outside_runtime_raises():
    with pytest.raises(RuntimeError, match="no active repro.core Runtime"):
        repro.gather(object())


def test_evaluate_outside_runtime_raises():
    with pytest.raises(RuntimeError, match="no active repro.core Runtime"):
        repro.evaluate(object())


def test_evaluate_without_arguments_raises():
    with repro.runtime(nprocs=2, block_size=8):
        with pytest.raises(TypeError, match="at least one"):
            repro.evaluate()
        with pytest.raises(TypeError, match="at least one"):
            repro.wait()


def test_evaluate_rejects_non_arrays():
    with repro.runtime(nprocs=2, block_size=8):
        with pytest.raises(TypeError, match="DistArrays, Exprs or ArrayFutures"):
            repro.evaluate(3.14)


def test_result_after_base_garbage_collected_raises_clearly():
    with repro.runtime(nprocs=2, block_size=8, flush="async") as rt:
        a = repro.ones((16,)) + 1.0
        fut = repro.evaluate(a)
        fut.block_until_ready()
        # simulate the base dying (the future normally keeps it alive):
        # mark it dead and run the barrier purge
        rt._dead_bases.add(a._base.id)
        rt._barrier_cleanup()
        with pytest.raises(RuntimeError, match="garbage-collected"):
            fut.result()


def test_nested_runtime_rejected():
    with repro.runtime(nprocs=2, block_size=8):
        with pytest.raises(RuntimeError, match="nested Runtimes"):
            with repro.Runtime(nprocs=2):
                pass  # pragma: no cover


def test_policy_pass_typo_fails_at_construction_with_names():
    with pytest.raises(ValueError) as ei:
        ExecutionPolicy(passes=["coalesce", "fuze"])
    msg = str(ei.value)
    assert "fuze" in msg
    for name in ("batch", "coalesce", "fuse"):
        assert name in msg  # the available-names list


def test_policy_sync_validated_and_resolved():
    with pytest.raises(ValueError, match="auto\\|demand\\|barrier"):
        ExecutionPolicy(sync="sometimes")
    assert ExecutionPolicy().resolved_sync == "barrier"  # sim default
    assert ExecutionPolicy(flush="async").resolved_sync == "demand"
    assert ExecutionPolicy(flush="async", sync="barrier").resolved_sync == "barrier"
    assert ExecutionPolicy(sync="demand").resolved_sync == "demand"


def test_flush_targets_rejects_garbage():
    with repro.runtime(nprocs=2, block_size=8) as rt:
        with pytest.raises(TypeError, match="expected a DistArray"):
            rt.flush(targets=("nope",))
