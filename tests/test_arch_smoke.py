"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step + one decode step on CPU; output shapes + no
NaNs.  (The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_reduced
from repro.models import decode_step, forward, init_params, loss_fn, prefill

ARCHS = all_arch_ids()


def _batch(cfg, B=2, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.enc_dec:
        batch["enc_frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq, cfg.d_model))
    if cfg.n_img_tokens:
        batch["img_emb"] = jax.random.normal(ks[2], (B, cfg.n_img_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims_match_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "rwkv6-3b": (32, 2560, 8960, 65536),
        "whisper-small": (12, 768, 3072, 51865),
        "yi-34b": (60, 7168, 20480, 64000),
        "mistral-large-123b": (88, 12288, 28672, 32768),
        "h2o-danube-3-4b": (24, 3840, 10240, 32000),
        "granite-3-8b": (40, 4096, 12800, 49155),
        "internvl2-2b": (24, 2048, 8192, 92553),
        "grok-1-314b": (64, 6144, 32768, 131072),
        "deepseek-v2-lite-16b": (27, 2048, 1408, 102400),
        "zamba2-2.7b": (54, 2560, 10240, 32000),
    }[arch]
    ff = cfg.moe_d_ff if arch in ("grok-1-314b", "deepseek-v2-lite-16b") else cfg.d_ff
    assert (cfg.n_layers, cfg.d_model, ff, cfg.vocab_size) == expected


def test_param_counts_in_expected_range():
    """param_count() lands near the published sizes (±40% tolerance for
    the approximated families)."""
    targets = {
        "yi-34b": 34e9,
        "mistral-large-123b": 123e9,
        "grok-1-314b": 314e9,
        "deepseek-v2-lite-16b": 16e9,
        "zamba2-2.7b": 2.7e9,
        "rwkv6-3b": 3e9,
        "h2o-danube-3-4b": 4e9,
        "granite-3-8b": 8e9,
        "internvl2-2b": 2e9,
    }
    for arch, t in targets.items():
        n = get_config(arch).param_count()
        assert 0.6 * t < n < 1.5 * t, f"{arch}: {n/1e9:.1f}B vs {t/1e9:.0f}B"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    gn = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x).sum(), grads)
    )
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    last, state = prefill(cfg, params, batch, max_len=S + 4)
    assert last.shape == (B, cfg.vocab_size)
    toks = jnp.argmax(last, -1).astype(jnp.int32)
    for _ in range(3):
        logits, state = decode_step(cfg, params, toks, state)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        toks = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["granite-3-8b", "zamba2-2.7b", "rwkv6-3b",
                                  "deepseek-v2-lite-16b", "h2o-danube-3-4b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits (cache
    correctness), for one representative arch per family.  MoE capacity
    is raised to no-drop so routing is batch-size independent."""
    cfg = get_reduced(arch, capacity_factor=64.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 10
    batch = _batch(cfg, B, S)
    logits_full, _ = forward(cfg, params, batch)

    pre = {**batch, "tokens": batch["tokens"][:, :4]}
    if cfg.n_img_tokens:
        pytest.skip("image prefix offsets differ between paths")
    last, state = prefill(cfg, params, pre, max_len=S + 2)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full[:, 3]), rtol=2e-2, atol=2e-2
    )
    for t in range(4, S):
        # teacher forcing: feed the TRUE token at position t; the returned
        # logits predict position t+1 == full-forward logits at column t
        lg, state = decode_step(cfg, params, batch["tokens"][:, t], state)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]), rtol=2e-2, atol=2e-2
        )
