"""Checkpoint store: roundtrip, async, atomic commit, keep-N, sharding."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (16, 8)),
        "b": jnp.arange(8, dtype=jnp.float32),
        "nested": {"scale": jnp.float32(2.5), "table": jnp.ones((4, 4), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def _assert_tree_equal(a, b):
    fa, _ = jax.tree.flatten(a)
    fb, _ = jax.tree.flatten(b)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(100, t, blocking=True)
    restored, step = mgr.restore(jax.eval_shape(lambda: t))
    assert step == 100
    _assert_tree_equal(t, restored)


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree(1)
    mgr.save(5, t)  # async
    mgr.wait()
    restored, step = mgr.restore(t)
    assert step == 5
    _assert_tree_equal(t, restored)


def test_latest_and_keep_n(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(s), blocking=True)
    steps = sorted(mgr._steps())
    assert steps == [30, 40]
    assert mgr.latest_step() == 40


def test_atomic_commit_no_partial_visible(tmp_path):
    """A .tmp directory (crash mid-save) must not count as a checkpoint."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(), blocking=True)
    (tmp_path / "step_000000000002.tmp").mkdir()
    assert mgr.latest_step() == 1


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree(2)
    mgr.save(3, t, blocking=True)
    shard = next((tmp_path / "step_000000000003").glob("shard_*.bin"))
    raw = bytearray(shard.read_bytes())
    raw[-8] ^= 0xFF  # flip a payload bit
    shard.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        mgr.restore(t)


def test_multi_shard_layout(tmp_path):
    """Two 'hosts' write disjoint leaf shards; restore reassembles."""
    t = _tree(3)
    m0 = CheckpointManager(tmp_path, shard_id=0, n_shards=2, is_primary=False)
    m1 = CheckpointManager(tmp_path, shard_id=1, n_shards=2, is_primary=True)
    m0.save(9, t, blocking=True)
    m1.save(9, t, blocking=True)
    restored, step = CheckpointManager(tmp_path).restore(t)
    assert step == 9
    _assert_tree_equal(t, restored)


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CheckpointManager(tmp_path).restore(_tree())
