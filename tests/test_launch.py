"""Launch-layer tests: sharding rules (divisibility fallback, axis
dedupe), batch/state specs, cell assembly — all on AbstractMesh (no
devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.data.pipeline import make_batch_specs
from repro.launch.sharding import batch_specs, param_specs, state_specs
from repro.launch.steps import cell_config, skip_reason
from repro.models import init_params, make_decode_state

def _abstract_mesh(sizes, names):
    try:  # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:  # jax <= 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _leaf_specs(cfg, mesh=MESH):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(shapes, mesh)
    flat_sh, _ = jax.tree_util.tree_flatten_with_path(shapes)
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return {
        jax.tree_util.keystr(kp): (leaf.shape, sp)
        for (kp, leaf), sp in zip(flat_sh, flat_sp)
    }


def _check_divisibility(leaves, mesh):
    for path, (shape, spec) in leaves.items():
        assert len(spec) <= len(shape), (path, shape, spec)
        used = []
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axes:
                assert a not in used, f"axis reused in {path}: {spec}"
                used.append(a)
                n *= mesh.shape[a]
            assert shape[d] % n == 0, f"{path}: {shape}[{d}] not divisible by {n} ({spec})"


@pytest.mark.parametrize("arch", ["yi-34b", "grok-1-314b", "deepseek-v2-lite-16b",
                                  "zamba2-2.7b", "rwkv6-3b", "whisper-small"])
def test_param_specs_divisibility(arch):
    cfg = get_config(arch)
    leaves = _leaf_specs(cfg)
    _check_divisibility(leaves, MESH)


def test_param_specs_multipod():
    cfg = get_config("granite-3-8b")
    leaves = _leaf_specs(cfg, MESH3)
    _check_divisibility(leaves, MESH3)


def test_embed_vocab_parallel_when_divisible():
    # yi vocab 64000 divides 16 → V over model, D unsharded (Megatron);
    # granite 49155 does not → fully replicated (divisibility fallback)
    yi = _leaf_specs(get_config("yi-34b"))
    embed = [v for k, v in yi.items() if k.endswith("['embed']")][0]
    assert embed[1] == P("model", None)
    gr = _leaf_specs(get_config("granite-3-8b"))
    embed = [v for k, v in gr.items() if k.endswith("['embed']")][0]
    assert embed[1] == P(None, None)


def test_yi_heads_fallback():
    """yi-34b: 56 heads don't divide 16 — wq's head-dim axis must fall
    back where needed but wq [D, H*hd]: 56*128=7168 divides 16 fine;
    the router-level check is that NOTHING asserts on divisibility."""
    cfg = get_config("yi-34b")
    leaves = _leaf_specs(cfg)
    wq = [v for k, v in leaves.items() if "wq" in k][0]
    assert wq[1][-1] == "model"  # 7168 % 16 == 0 → sharded (trailing dim)


def test_grok_experts_tp_fallback():
    """grok: 8 experts < 16-way model axis → EP falls back to TP inside
    the expert matrices."""
    cfg = get_config("grok-1-314b")
    leaves = _leaf_specs(cfg)
    w_in = [v for k, v in leaves.items() if "moe']['w_in" in k][0]
    shape, spec = w_in
    assert shape[-3] == 8
    assert spec[-3] is None  # experts NOT sharded (8 % 16 != 0)
    assert spec[-1] == "model"  # TP on the expert hidden dim


def test_deepseek_experts_ep():
    cfg = get_config("deepseek-v2-lite-16b")
    leaves = _leaf_specs(cfg)
    w_in = [v for k, v in leaves.items() if "moe']['w_in" in k][0]
    shape, spec = w_in
    assert shape[-3] == 64
    assert spec[-3] == "model"  # 64 experts over 16-way model = EP


def test_batch_specs_dp_and_sp():
    cfg = get_config("granite-3-8b")
    b = make_batch_specs(cfg, SHAPES["train_4k"])
    spec = batch_specs(b, MESH)
    # older jax does not normalize P(("data",), ...) == P("data", ...)
    assert spec["tokens"] in (P(("data",), None), P("data", None))
    # long-context (batch=1): sequence sharded instead
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    spec1 = batch_specs(b1, MESH, seq_sharded=True)
    assert spec1["tokens"] == P(None, "data")


def test_state_specs_batch_or_cache_sharded():
    cfg = cell_config("h2o-danube-3-4b", "decode_32k")
    st = jax.eval_shape(lambda: make_decode_state(cfg, 128, 32768))
    specs = state_specs(st, MESH)
    flat_st, _ = jax.tree_util.tree_flatten_with_path(st)
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = sum(
        1 for sp in flat_sp if any(ax is not None for ax in sp)
    )
    assert n_sharded >= len(flat_sp) // 2  # most state is sharded
    _check_divisibility(
        {jax.tree_util.keystr(kp): (l.shape, sp)
         for (kp, l), sp in zip(flat_st, flat_sp)},
        MESH,
    )


def test_skip_reasons():
    assert skip_reason("yi-34b", "long_500k") is not None
    assert skip_reason("rwkv6-3b", "long_500k") is None
    assert skip_reason("zamba2-2.7b", "long_500k") is None
    assert skip_reason("h2o-danube-3-4b", "long_500k") is None
    assert skip_reason("yi-34b", "train_4k") is None


def test_cell_config_overrides():
    cfg = cell_config("zamba2-2.7b", "long_500k")
    assert cfg.swa_window == 4096  # hybrid long-context window
    cfg2 = cell_config("yi-34b", "decode_32k")
    assert cfg2.remat is False and cfg2.microbatches == 1
    cfg3 = cell_config("yi-34b", "train_4k")
    assert cfg3.remat is True and cfg3.microbatches > 1
