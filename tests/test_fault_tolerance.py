"""Fault-tolerance runtime: failure detection, elastic rescale,
straggler eviction, checkpoint/restart supervision, grad compression."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.resilience import (
    ClusterMonitor,
    ElasticPlan,
    StragglerTracker,
    TrainSupervisor,
    int8_compress_transform,
    topk_ef_transform,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_monitor_detects_missed_heartbeats():
    clk = FakeClock()
    mon = ClusterMonitor(4, deadline=10.0, clock=clk)
    clk.t = 5.0
    for h in range(4):
        mon.heartbeat(h)
    clk.t = 12.0
    mon.heartbeat(1)
    mon.heartbeat(3)
    clk.t = 16.0
    assert mon.failed() == [0, 2]
    assert mon.alive() == [1, 3]


def test_elastic_plan_rebalances():
    plan = ElasticPlan.make([0, 1, 2, 3, 5, 6, 7, 9], global_batch=256)
    assert plan.n_hosts == 8
    assert plan.rows_per_host == 32
    assert plan.rank_of[5] == 4
    # after another loss
    plan2 = ElasticPlan.make(plan.hosts[:-1], 256)
    assert plan2.rows_per_host == 36
    assert plan2.global_batch == 252  # largest multiple kept (documented)
    assert plan2.mesh_shape(model_parallel=7) == (1, 7)
    assert plan2.mesh_shape(model_parallel=4) == (7, 1)


def test_elastic_data_pipeline_consistency():
    """After rescale the union of host shards is deterministic per step."""
    from repro.data.pipeline import DataConfig, TokenPipeline

    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8)
    before = TokenPipeline(cfg, host_id=0, n_hosts=1).batch_at(3)
    shards = [
        TokenPipeline(cfg, host_id=h, n_hosts=2).batch_at(3, host_id=h)
        for h in range(2)
    ]
    # each host's shard is itself deterministic
    again = TokenPipeline(cfg, host_id=1, n_hosts=2).batch_at(3)
    np.testing.assert_array_equal(shards[1]["tokens"], again["tokens"])
    assert before["tokens"].shape == (8, 8)
    assert shards[0]["tokens"].shape == (4, 8)


def test_straggler_eviction():
    tr = StragglerTracker(4, threshold=2.0, window=4, patience=2)
    for step in range(6):
        for h in range(4):
            tr.record(h, 1.0 if h != 2 else 5.0)
        evict = tr.evaluate()
    assert evict == [2]


def test_supervisor_restart_and_rescale():
    saves = {}
    state = {"x": 0}
    events = []

    def step_fn(st, step, plan):
        if step == 5 and 3 in plan.hosts:
            raise TrainSupervisor.HostFailure(3)
        return {"x": st["x"] + plan.n_hosts}

    def save_fn(st, step):
        saves["latest"] = (dict(st), step)

    def restore_fn():
        st, step = saves["latest"]
        events.append(("restore", step))
        return dict(st), step

    sup = TrainSupervisor(
        n_hosts=4, global_batch=64,
        step_fn=step_fn, save_fn=save_fn, restore_fn=restore_fn,
        checkpoint_every=2, on_rescale=lambda p: events.append(("rescale", p.n_hosts)),
    )
    final, step = sup.run({"x": 0}, 0, 10)
    assert step == 10
    assert ("rescale", 3) in events
    assert any(e[0] == "restore" for e in events)
    # after rescale, steps advance with 3 hosts
    assert sup.plan.n_hosts == 3


def test_supervisor_gives_up_after_max_restarts():
    def step_fn(st, step, plan):
        raise TrainSupervisor.HostFailure(plan.hosts[0])

    sup = TrainSupervisor(
        n_hosts=4, global_batch=64,
        step_fn=step_fn, save_fn=lambda s, t: None,
        restore_fn=lambda: ({}, 0), max_restarts=2,
    )
    with pytest.raises(TrainSupervisor.HostFailure):
        sup.run({}, 0, 5)


def test_int8_compression_roundtrip_error_small():
    g = {"a": jnp.linspace(-3, 3, 1024).reshape(32, 32)}
    out = int8_compress_transform(0)(g)
    err = jnp.abs(out["a"] - g["a"]).max()
    assert err < 3.0 / 127 * 2  # within 2 quant steps
    # wire size: int8 + scale = 4x reduction
    assert out["a"].dtype == g["a"].dtype


def test_topk_error_feedback_accumulates():
    transform, init = topk_ef_transform(k_frac=0.25)
    g = {"a": jnp.array([1.0, -2.0, 0.1, 0.05])}
    res = init(g)
    sent1, res = transform(g, res)
    # only the largest |g| entry goes through
    assert float(jnp.count_nonzero(sent1["a"])) == 1
    assert float(sent1["a"][1]) == -2.0
    # the residual re-sends suppressed coordinates later
    sent2, res = transform(g, res)
    assert float(sent2["a"][0]) != 0.0  # 1.0 + residual 1.0
