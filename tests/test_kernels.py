"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.mamba2_scan import ssd_scan, ssd_scan_ref
from repro.kernels.rwkv6_wkv import wkv6, wkv6_ref
from repro.kernels.stencil import jacobi_sweep, jacobi_sweep_ref

TOL = {jnp.float32: 5e-4, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("B,Sq,Sk,H,KV,d", [
    (1, 128, 128, 2, 2, 64),
    (2, 130, 130, 4, 2, 64),     # padding path
    (1, 64, 192, 2, 1, 80),      # cross-length + non-128 head dim
    (1, 96, 96, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Sq, Sk, H, KV, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, d), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, d), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, d), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    err = jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32)).max()
    assert err < TOL[dtype], err


@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 37)])
def test_flash_attention_masks(causal, window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    got = flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    assert jnp.abs(got - ref).max() < 5e-4


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 3, 16, 8, 16),
    (1, 100, 2, 32, 16, 32),   # padding path
    (1, 256, 1, 64, 64, 128),
])
@pytest.mark.parametrize("with_state", [False, True])
def test_ssd_scan_shapes(b, s, h, p, n, chunk, with_state):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    s0 = jax.random.normal(ks[5], (b, h, p, n)) if with_state else None
    y, fin = ssd_scan(x, dt, A, B, C, s0, chunk=chunk)
    yr, fr = ssd_scan_ref(x, dt, A, B, C, init_state=s0)
    assert jnp.abs(y - yr).max() < 2e-3
    assert jnp.abs(fin - fr).max() < 2e-3


@pytest.mark.parametrize("B,T,H,N,chunk", [
    (2, 64, 3, 16, 16),
    (1, 100, 2, 32, 32),   # padding path
    (1, 128, 2, 64, 64),
])
@pytest.mark.parametrize("with_state", [False, True])
def test_wkv6_shapes(B, T, H, N, chunk, with_state):
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r, k, v = [jax.random.normal(ks[i], (B, T, H, N)) for i in range(3)]
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.55 + 0.4
    u = jax.random.normal(ks[4], (H, N))
    s0 = jax.random.normal(ks[5], (B, H, N, N)) if with_state else None
    y, fin = wkv6(r, k, v, w, u, s0, chunk=chunk)
    yr, fr = wkv6_ref(r, k, v, w, u, init_state=s0)
    assert jnp.abs(y - yr).max() < 1e-3
    assert jnp.abs(fin - fr).max() < 1e-3


@pytest.mark.parametrize("H,W,band", [
    (128, 256, 32), (100, 64, 32), (64, 64, 64), (96, 128, 128),
])
def test_jacobi_sweep_shapes(H, W, band):
    x = jax.random.normal(jax.random.PRNGKey(4), (H, W))
    got = jacobi_sweep(x, band=band)
    ref = jacobi_sweep_ref(x)
    assert jnp.abs(got - ref).max() < 1e-6


def test_jacobi_sweep_iterated():
    x = jax.random.normal(jax.random.PRNGKey(5), (96, 96))
    a = b = x
    for _ in range(4):
        a = jacobi_sweep(a, band=32)
        b = jacobi_sweep_ref(b)
    assert jnp.abs(a - b).max() < 1e-6


def test_kernels_match_model_paths():
    """Kernel outputs == the model-substrate jnp twins (chunked paths)."""
    from repro.models.attention import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = chunked_attention(q, k, v, causal=True, chunk=64)
    assert jnp.abs(a - b).max() < 5e-4
