"""Dependency-system tests: heuristic vs full-DAG equivalence (§5.7)."""
import itertools
import random

import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import (
    COMM,
    COMPUTE,
    AccessNode,
    DependencySystem,
    FullDAG,
    OperationNode,
)


def _op(kind, writes, reads, uid_order):
    op = OperationNode(kind, None, procs=(0,), cost=1.0)
    for key, region in writes:
        op.add_access(AccessNode(key, region, write=True))
    for key, region in reads:
        op.add_access(AccessNode(key, region, write=False))
    uid_order.append(op.uid)
    return op


def _drain_order(sys_):
    order = []
    while True:
        op = sys_.pop_ready()
        if op is None:
            break
        order.append(op.uid)
        sys_.complete(op)
    return order


def test_raw_conflicts_serialize():
    order = []
    d = DependencySystem()
    a = _op(COMPUTE, [(("b", 0), ((0, 4),))], [], order)  # write b0[0:4]
    b = _op(COMPUTE, [], [(("b", 0), ((2, 6),))], order)  # read overlap
    c = _op(COMPUTE, [], [(("b", 0), ((4, 8),))], order)  # read disjoint
    for op in (a, b, c):
        d.insert(op)
    ready0 = {op.uid for op in d.ready}
    assert a.uid in ready0 and c.uid in ready0 and b.uid not in ready0
    got = _drain_order(d)
    assert got.index(a.uid) < got.index(b.uid)


def test_read_read_no_conflict():
    order = []
    d = DependencySystem()
    ops = [_op(COMPUTE, [], [(("b", 0), ((0, 8),))], order) for _ in range(5)]
    for op in ops:
        d.insert(op)
    assert len(d.ready) == 5


def _random_program(rng, n_ops, n_blocks):
    """Random op stream over a few blocks with region-level conflicts."""
    ops = []
    for _ in range(n_ops):
        writes, reads = [], []
        for _ in range(rng.randint(1, 2)):
            key = ("b", rng.randrange(n_blocks))
            lo = rng.randrange(0, 8)
            region = ((lo, lo + rng.randint(1, 4)),)
            if rng.random() < 0.5:
                writes.append((key, region))
            else:
                reads.append((key, region))
        ops.append((writes, reads))
    return ops


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(2, 40))
def test_heuristic_matches_full_dag(seed, n_ops):
    """Property (paper §5.7.2): the per-block dependency-list heuristic
    must admit exactly the schedules the full DAG admits — same ready
    sets at every step when draining in uid order."""
    rng = random.Random(seed)
    prog = _random_program(rng, n_ops, n_blocks=3)

    def build(cls):
        order = []
        s = cls()
        id_map = {}
        for writes, reads in prog:
            op = _op(COMPUTE, writes, reads, order)
            id_map[op.uid] = op
            s.insert(op)
        return s, order, id_map

    h, order_h, map_h = build(DependencySystem)
    g, order_g, map_g = build(FullDAG)
    # drain both in deterministic (uid ascending) order, comparing ready sets
    pos_h = {uid: i for i, uid in enumerate(order_h)}
    pos_g = {uid: i for i, uid in enumerate(order_g)}
    while True:
        ready_h = sorted(pos_h[op.uid] for op in h.ready if not op.executed)
        ready_g = sorted(pos_g[op.uid] for op in g.ready if not op.executed)
        assert ready_h == ready_g
        if not ready_h:
            break
        # complete the lowest-index ready op in both
        tgt_h = min((op for op in h.ready if not op.executed), key=lambda o: pos_h[o.uid])
        tgt_g = min((op for op in g.ready if not op.executed), key=lambda o: pos_g[o.uid])
        h.ready.remove(tgt_h)
        g.ready.remove(tgt_g)
        h.complete(tgt_h)
        g.complete(tgt_g)
    assert h.done and g.n_pending == 0


def test_comm_priority_pop():
    order = []
    d = DependencySystem()
    c1 = _op(COMPUTE, [(("b", 1), None)], [], order)
    m1 = _op(COMM, [(("s", 1), None)], [], order)
    d.insert(c1)
    d.insert(m1)
    assert d.pop_ready(COMM) is m1
    assert d.pop_ready(COMM) is None
    assert d.pop_ready(COMPUTE) is c1
