"""Latency-hiding collectives under shard_map — runs in a SUBPROCESS with
8 fake XLA devices so the main test process keeps its single-device view.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # jax <= 0.4.x
        from jax.experimental.shard_map import shard_map
    from repro.comm.collectives import (
        ring_all_gather, ring_reduce_scatter, ag_matmul, matmul_rs,
        halo_exchange, stencil_1d_sharded, jacobi_step_sharded,
    )

    mesh = jax.make_mesh((8,), ("x",))
    def smap(f, in_specs, out_specs):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
        except TypeError:  # jax <= 0.4.x spells it check_rep
            return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)

    k = jax.random.PRNGKey(0)
    # ring all-gather == lax.all_gather
    x = jax.random.normal(k, (16, 4))
    got = smap(lambda a: ring_all_gather(a, "x"), P("x"), P(None))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)
    print("ring_all_gather OK")

    # ring reduce-scatter == psum-then-slice oracle
    z = jax.random.normal(k, (64, 8))
    def rs2(a):  # local [8, 8]
        return ring_reduce_scatter(a.reshape(8, 8)[:, :], "x", axis=0)
    # oracle: psum then slice
    def oracle(a):
        full = jax.lax.psum(a, "x")
        i = jax.lax.axis_index("x")
        return jax.lax.dynamic_slice_in_dim(full, i * 1, 1, 0)
    got = smap(rs2, P("x", None), P("x", None))(z)
    want = smap(oracle, P("x", None), P("x", None))(z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    print("ring_reduce_scatter OK")

    # overlapped ag_matmul == all_gather(x) @ w
    xs = jax.random.normal(k, (32, 16))   # gather axis rows
    w = jax.random.normal(k, (16, 8))
    got = smap(lambda a, b: ag_matmul(a, b, "x", gather_axis=0),
               (P("x", None), P(None, None)), P(None, None))(xs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(xs @ w), rtol=1e-4, atol=1e-4)
    got_nb = smap(lambda a, b: ag_matmul(a, b, "x", overlap="none", gather_axis=0),
                  (P("x", None), P(None, None)), P(None, None))(xs, w)
    np.testing.assert_allclose(np.asarray(got_nb), np.asarray(xs @ w), rtol=1e-4, atol=1e-4)
    print("ag_matmul OK")

    # overlapped matmul_rs == reduce_scatter(x @ w)
    xk = jax.random.normal(k, (32, 64))   # K sharded
    wk = jax.random.normal(k, (64, 8))
    got = smap(lambda a, b: matmul_rs(a, b, "x", scatter_axis=0),
               (P(None, "x"), P("x", None)), P("x", None))(xk, wk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(xk @ wk), rtol=1e-4, atol=1e-4)
    print("matmul_rs OK")

    # halo exchange + sharded stencil == dense stencil
    u = jax.random.normal(k, (64,))
    def pt(l, c, r):
        return 0.25 * l + 0.5 * c + 0.25 * r
    got = smap(lambda a: stencil_1d_sharded(a, "x", pt), P("x"), P("x"))(u)
    un = np.asarray(u)
    ext = np.concatenate([[0.0], un, [0.0]])
    want = 0.25 * ext[:-2] + 0.5 * ext[1:-1] + 0.25 * ext[2:]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)
    got_nb = smap(lambda a: stencil_1d_sharded(a, "x", pt, overlap="none"), P("x"), P("x"))(u)
    np.testing.assert_allclose(np.asarray(got_nb), want, rtol=1e-5, atol=1e-6)
    print("stencil_1d OK")

    # 2-D jacobi step, row-sharded == reference
    g = jax.random.normal(k, (32, 16))
    got = smap(lambda a: jacobi_step_sharded(a, "x"), P("x", None), P("x", None))(g)
    gn = np.asarray(g)
    ref = gn.copy()
    interior = 0.2 * (gn[1:-1, 1:-1] + gn[:-2, 1:-1] + gn[2:, 1:-1] + gn[1:-1, :-2] + gn[1:-1, 2:])
    pad_top = 0.2 * (gn[0, 1:-1] + 0 + gn[1, 1:-1] + gn[0, :-2] + gn[0, 2:])
    # reference via the same halo-zero convention: build padded array
    ext = np.zeros((34, 16)); ext[1:-1] = gn
    new = 0.2 * (ext[1:-1, 1:-1] + ext[:-2, 1:-1] + ext[2:, 1:-1] + ext[1:-1, :-2] + ext[1:-1, 2:])
    ref[:, 1:-1] = new
    ref[0] = gn[0]; ref[-1] = gn[-1]   # global Dirichlet rows
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-6)
    print("jacobi_step OK")
    print("ALL-COLLECTIVES-PASS")
    """
)


@pytest.mark.slow
def test_collectives_under_shard_map():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # force the host CPU backend: the fake-device XLA flag only applies to
    # it, and probing for a TPU wastes minutes when libtpu is present
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=600,
    )
    assert "ALL-COLLECTIVES-PASS" in res.stdout, res.stdout + "\n" + res.stderr
