"""DistArray API vs NumPy oracle — including property-based equivalence.

The central invariant of the whole runtime (paper §5): ANY program
written against the DistArray API must produce bit-identical results to
NumPy, for every block size, process count, scheduling mode, and with
fusion on or off.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import Runtime
from repro.core import darray as dnp


def run_program(prog, mode="latency_hiding", nprocs=4, block_size=3, fusion=False):
    with Runtime(nprocs=nprocs, block_size=block_size, mode=mode, fusion=fusion):
        return np.asarray(prog(dnp))  # materialize inside the context


def np_program(prog):
    class NpShim:
        array = staticmethod(lambda d, **k: np.array(d, dtype=float))
        zeros = staticmethod(lambda s, **k: np.zeros(s))
        ones = staticmethod(lambda s, **k: np.ones(s))
        empty = staticmethod(lambda s, **k: np.zeros(s))
        exp = staticmethod(np.exp)
        log = staticmethod(np.log)
        sqrt = staticmethod(np.sqrt)
        absolute = staticmethod(np.absolute)
        maximum = staticmethod(np.maximum)
        minimum = staticmethod(np.minimum)
        where = staticmethod(np.where)
        less = staticmethod(lambda a, b: np.less(a, b).astype(float))
        greater = staticmethod(lambda a, b: np.greater(a, b).astype(float))
        matmul = staticmethod(
            lambda a, b, trans_a=False, trans_b=False: (a.T if trans_a else a)
            @ (b.T if trans_b else b)
        )
        roll = staticmethod(np.roll)

    return prog(NpShim)


PROGRAMS = {
    "elementwise_views": lambda m: (
        lambda a: (a[1:] * 2.0 + a[:-1]) / (1.0 + m.exp(-a[1:]))
    )(m.array(np.arange(37.0))),
    "stencil": lambda m: (
        lambda f: [
            f.__setitem__(
                (slice(1, -1), slice(1, -1)),
                0.2 * (f[1:-1, 1:-1] + f[:-2, 1:-1] + f[2:, 1:-1]
                       + f[1:-1, :-2] + f[1:-1, 2:]),
            )
            or f
            for _ in range(3)
        ][-1]
    )(m.array(np.arange(121.0).reshape(11, 11))),
    "reduce": lambda m: (lambda a: a.sum(axis=0) + a.max(axis=0))(
        m.array(np.arange(56.0).reshape(7, 8))
    ),
    "matmul": lambda m: m.matmul(
        m.array(np.arange(30.0).reshape(5, 6)),
        m.array(np.arange(30.0).reshape(6, 5)),
    ),
    "matmul_trans": lambda m: m.matmul(
        m.array(np.arange(30.0).reshape(6, 5)),
        m.array(np.arange(30.0).reshape(6, 5)),
        trans_a=True,
    ),
    "roll": lambda m: m.roll(m.array(np.arange(24.0).reshape(4, 6)), 2, 1),
    "broadcast": lambda m: m.array(np.arange(20.0).reshape(4, 5))
    * m.array(np.arange(5.0).reshape(1, 5))
    + m.array(np.arange(4.0).reshape(4, 1)),
}


@pytest.mark.parametrize("name", list(PROGRAMS))
@pytest.mark.parametrize("mode", ["latency_hiding", "blocking"])
def test_programs_match_numpy(name, mode):
    prog = PROGRAMS[name]
    got = np.asarray(run_program(prog, mode=mode))
    want = np.asarray(np_program(prog))
    np.testing.assert_allclose(got, want, rtol=1e-12)


@pytest.mark.parametrize("name", list(PROGRAMS))
def test_programs_match_numpy_fused(name):
    got = np.asarray(run_program(PROGRAMS[name], fusion=True))
    want = np.asarray(np_program(PROGRAMS[name]))
    np.testing.assert_allclose(got, want, rtol=1e-12)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(3, 24),
    bs=st.integers(1, 9),
    nprocs=st.sampled_from([1, 2, 4, 7]),
    lo=st.integers(0, 2),
    step=st.integers(1, 2),
    mode=st.sampled_from(["latency_hiding", "blocking"]),
    seed=st.integers(0, 99),
)
def test_property_view_arithmetic(n, bs, nprocs, lo, step, mode, seed):
    """Random strided-view expression == NumPy, any layout/schedule."""
    rng = np.random.default_rng(seed)
    a_np = rng.random((n, n))
    b_np = rng.random((n, n))
    key = (slice(lo, n, step), slice(0, n - lo))

    def prog(m):
        a = m.array(a_np)
        b = m.array(b_np)
        x = a[key]
        y = b[: x.shape[0], : x.shape[1]]
        return x * 2.0 + y * y - x / (y + 1.5)

    with Runtime(nprocs=nprocs, block_size=bs, mode=mode):
        got = np.asarray(prog(dnp))
    want = np_program(prog)
    np.testing.assert_allclose(got, want, rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 12),
    k=st.integers(2, 12),
    n=st.integers(2, 12),
    bs=st.integers(2, 7),
    seed=st.integers(0, 99),
)
def test_property_matmul(m, k, n, bs, seed):
    rng = np.random.default_rng(seed)
    a_np = rng.random((m, k))
    b_np = rng.random((k, n))
    with Runtime(nprocs=4, block_size=bs):
        got = np.asarray(dnp.matmul(dnp.array(a_np), dnp.array(b_np)))
    np.testing.assert_allclose(got, a_np @ b_np, rtol=1e-10)


def test_overlapping_self_assignment():
    a_np = np.arange(20.0)
    with Runtime(nprocs=4, block_size=3):
        a = dnp.array(a_np)
        a[1:] = a[:-1]
        got = np.asarray(a)
    want = a_np.copy()
    want[1:] = a_np[:-1]
    np.testing.assert_array_equal(got, want)


def test_flush_threshold_triggers():
    with Runtime(nprocs=2, block_size=4, flush_threshold=10) as rt:
        a = dnp.zeros((8, 8))
        for _ in range(30):
            a += 1.0
        assert rt.flush_count >= 2  # threshold flushes happened mid-stream
        got = np.asarray(a)
    np.testing.assert_array_equal(got, np.full((8, 8), 30.0))


def test_scalar_readback_triggers_flush():
    with Runtime(nprocs=2, block_size=4) as rt:
        a = dnp.ones((6, 6))
        s = (a + 1.0).sum()
        assert float(s) == 72.0
        assert rt.flush_count >= 1
