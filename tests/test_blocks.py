"""Unit + property tests for the block decomposition (paper §5.2)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.blocks import (
    Layout,
    OperandSpec,
    ViewSpec,
    default_process_grid,
    fragment_iteration_space,
)


def test_default_process_grid():
    assert default_process_grid(16, 2) == (4, 4)
    assert default_process_grid(8, 2) in ((4, 2), (2, 4))
    assert np.prod(default_process_grid(12, 3)) == 12
    assert default_process_grid(1, 2) == (1, 1)


def test_layout_owner_block_cyclic():
    lay = Layout((8, 8), (2, 2), (2, 2))
    owners = {coord: lay.owner(coord) for coord, _ in lay.blocks()}
    # round-robin per dim: owner = (bi % 2) * 2 + (bj % 2)
    for (bi, bj), r in owners.items():
        assert r == (bi % 2) * 2 + (bj % 2)
    assert set(owners.values()) == {0, 1, 2, 3}


def test_view_compose_slice():
    v = ViewSpec.full((10, 10))
    v2 = v.compose_slice((slice(2, 8), slice(0, 10, 2)))
    assert v2.vshape == (6, 5)
    assert v2.offset == (2, 0)
    assert v2.step == (1, 2)
    v3 = v2.compose_slice((slice(1, 4), slice(1, 5)))
    assert v3.offset == (3, 2)
    assert v3.step == (1, 2)
    assert v3.vshape == (3, 4)


def _np_of_fragments(shape, view, layout):
    """Reassemble a view through its fragments and compare with numpy."""
    base = np.arange(int(np.prod(shape))).reshape(shape)
    spec = OperandSpec(view, layout, tuple(range(view.ndim)))
    out = np.full(view.vshape, -1, dtype=base.dtype)
    for vint, (frag,) in fragment_iteration_space(view.vshape, (spec,)):
        dst = tuple(slice(lo, hi) for lo, hi in vint)
        blk = base[layout.block_slices(frag.block)]
        out[dst] = blk[frag.slices]
    # oracle: strided view
    key = tuple(
        slice(o, o + (L - 1) * s + 1, s)
        for o, s, L in zip(view.offset, view.step, view.vshape)
    )
    np.testing.assert_array_equal(out, base[key])


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(4, 40),
    m=st.integers(4, 40),
    bs=st.integers(1, 9),
    off=st.integers(0, 3),
    step=st.integers(1, 3),
)
def test_fragmentation_covers_view_exactly(n, m, bs, off, step):
    shape = (n, m)
    lay = Layout(shape, (bs, bs), (2, 2))
    L1 = max(1, (n - off + step - 1) // step - 1)
    L2 = max(1, (m - off + step - 1) // step - 1)
    view = ViewSpec((off, off), (step, step), (L1, L2))
    _np_of_fragments(shape, view, lay)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(4, 32),
    bs_a=st.integers(1, 8),
    bs_b=st.integers(1, 8),
)
def test_fragments_single_block_invariant(n, bs_a, bs_b):
    """Every fragment must touch exactly one base-block of each operand."""
    lay_a = Layout((n, n), (bs_a, bs_a), (2, 2))
    lay_b = Layout((n, n), (bs_b, bs_b), (2, 2))
    va = ViewSpec.full((n, n))
    specs = (
        OperandSpec(va, lay_a, (0, 1)),
        OperandSpec(va, lay_b, (0, 1)),
    )
    frags = fragment_iteration_space((n, n), specs)
    total = 0
    for vint, (fa, fb) in frags:
        size = int(np.prod([hi - lo for lo, hi in vint]))
        total += size
        assert fa.size == size and fb.size == size
    assert total == n * n


def test_matmul_fragmentation_shapes():
    M = N = K = 12
    lay = Layout((M, K), (4, 4), (2, 2))
    specs = (
        OperandSpec(ViewSpec.full((M, N)), lay, (0, 1)),
        OperandSpec(ViewSpec.full((M, K)), lay, (0, 2)),
        OperandSpec(ViewSpec.full((K, N)), lay, (2, 1)),
    )
    frags = fragment_iteration_space((M, N, K), specs)
    vol = sum(
        int(np.prod([hi - lo for lo, hi in vint])) for vint, _ in frags
    )
    assert vol == M * N * K
