"""repro.obs tests: lifecycle-event completeness, the disabled-path
no-op guarantee, Chrome-trace export schema, wait attribution, and the
trace-enables-nothing invariant (traced runs stay bit-identical).

The completeness tests run with ``passes=()`` so the recorded uids are
the executing uids — rewrite passes (coalesce/fuse) replace nodes, which
is exercised separately via the ``rewritten`` provenance events.
"""
import json
from collections import Counter

import numpy as np
import pytest

import repro
from repro import api
from repro.core import COMM, COMPUTE
from repro.obs import (
    AttributionReport,
    TraceCollector,
    attribution,
    export_trace,
    trace,
    validate_trace,
)
from repro.obs import collector as obs_collector
from repro.obs.collector import activate, current_tracer, deactivate


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Tracing must never leak across tests (or from a crashed one)."""
    obs_collector.CURRENT = None
    yield
    obs_collector.CURRENT = None


def _program(**rt_kwargs):
    """Small pipeline with genuine inter-process transfers (roll)."""
    with repro.runtime(block_size=32, **rt_kwargs) as rt:
        a = repro.array(np.arange(16384.0).reshape(128, 128))
        b = np.sqrt(a * a + 1.0)
        c = api.roll(b, 1, axis=0) + b
        out = np.asarray(np.sum(c, axis=0))
        st = rt.stats()
    return out, st, rt


# ---------------------------------------------------------------------------
# event completeness
# ---------------------------------------------------------------------------


def test_event_completeness_async():
    with trace() as tr:
        _program(nprocs=4, flush="async", passes=())
    ev = list(tr.events)
    etypes = Counter(et for _, et, _, _, _ in ev)
    assert tr.dropped == 0

    # every recorded compute op executes exactly once (start and end)
    recorded_compute = sorted(
        uid for _, et, uid, _, _ in ev
        if et == "recorded" and tr.ops[uid][0] == COMPUTE
    )
    starts = sorted(uid for _, et, uid, _, _ in ev if et == "compute-start")
    ends = sorted(uid for _, et, uid, _, _ in ev if et == "compute-end")
    assert starts == recorded_compute
    assert ends == recorded_compute

    # every compute op passes through a worker queue exactly once
    enq = Counter(uid for _, et, uid, _, _ in ev if et == "enqueued")
    deq = Counter(uid for _, et, uid, _, _ in ev if et == "dequeued")
    for uid in recorded_compute:
        assert enq[uid] == 1 and deq[uid] == 1

    # every posted message was delivered (the drain barrier guarantees it)
    posted = sorted(uid for _, et, uid, _, _ in ev if et == "msg-posted")
    delivered = sorted(uid for _, et, uid, _, _ in ev if et == "msg-delivered")
    assert posted and posted == delivered
    for uid in posted:
        assert tr.ops[uid][0] == COMM

    # flush/drain segmentation is balanced and tagged
    assert etypes["flush-begin"] >= 1
    drain_b = [uid for _, et, uid, _, _ in ev if et == "drain-begin"]
    drain_e = [uid for _, et, uid, _, _ in ev if et == "drain-end"]
    assert sorted(drain_b) == sorted(drain_e)

    # timestamps are monotonic non-decreasing per the deque order...
    # (events interleave across threads; only sanity-check the range)
    ts = [e[0] for e in ev]
    assert min(ts) >= 0.0 and max(ts) >= min(ts)


def test_rewrite_provenance_events():
    # default pipeline coalesces transfers: rewritten events carry the
    # pass name and the source uids they replace
    with trace() as tr:
        _program(nprocs=4, flush="async", passes="auto")
    ev = list(tr.events)
    passes_run = [x[0] for _, et, _, _, x in ev if et == "plan-pass"]
    assert "coalesce" in passes_run
    rewrites = [(uid, x) for _, et, uid, _, x in ev if et == "rewritten"]
    assert rewrites
    for uid, (pass_name, srcs) in rewrites:
        assert pass_name in ("coalesce", "fuse")
        assert len(srcs) >= 2
        assert uid in tr.ops


def test_sim_flush_traced():
    with trace() as tr:
        out, st, _ = _program(nprocs=4, flush="sim")
    ev = list(tr.events)
    etypes = {et for _, et, _, _, _ in ev}
    assert "recorded" in etypes and "flush-begin" in etypes
    assert "drain-begin" in etypes and "drain-end" in etypes
    validate_trace(export_trace(tr))


# ---------------------------------------------------------------------------
# disabled path: a true no-op
# ---------------------------------------------------------------------------


def test_disabled_no_collector_no_tracer():
    out, st, rt = _program(nprocs=4, flush="async")
    assert obs_collector.CURRENT is None
    assert rt.tracer is None
    assert current_tracer() is None


@pytest.mark.parametrize("flush", ["async", "sim"])
@pytest.mark.parametrize("sync", ["demand", "barrier"])
def test_traced_bit_identical(flush, sync):
    if flush == "sim" and sync == "demand":
        pytest.skip("simulator resolves sync to barrier")
    base, _, _ = _program(nprocs=4, flush=flush, sync=sync)
    with trace():
        traced, _, _ = _program(nprocs=4, flush=flush, sync=sync)
    np.testing.assert_array_equal(base, traced)


@pytest.mark.parametrize("passes", ["auto", ()])
def test_traced_bit_identical_across_passes(passes):
    base, _, _ = _program(nprocs=4, flush="async", passes=passes)
    with trace():
        traced, _, _ = _program(nprocs=4, flush="async", passes=passes)
    np.testing.assert_array_equal(base, traced)


# ---------------------------------------------------------------------------
# trace context manager / activation plumbing
# ---------------------------------------------------------------------------


def test_trace_cm_nesting_restores_previous():
    outer = TraceCollector()
    prev = activate(outer)
    assert current_tracer() is outer
    with trace() as inner:
        assert current_tracer() is inner
        assert inner is not outer
    assert current_tracer() is outer
    deactivate(prev)
    assert current_tracer() is None


def test_trace_cm_exports_on_exit(tmp_path):
    path = tmp_path / "t.json"
    with trace(str(path)):
        _program(nprocs=2, flush="async")
    doc = json.loads(path.read_text())
    info = validate_trace(doc)
    assert info["n_events"] > 0


def test_runtime_adopts_ambient_collector():
    with trace() as tr:
        _, _, rt = _program(nprocs=2, flush="async")
        assert rt.tracer is tr
    # the runtime must not deactivate a collector it does not own
    assert current_tracer() is None


def test_policy_trace_field(tmp_path):
    with pytest.raises(ValueError):
        repro.ExecutionPolicy(trace=3)
    path = tmp_path / "policy.json"
    with repro.runtime(nprocs=2, flush="async", trace=str(path)) as rt:
        a = repro.array(np.ones((32, 32)))
        np.asarray(a + 1.0)
        assert rt.tracer is not None
        assert current_tracer() is rt.tracer
    assert current_tracer() is None
    validate_trace(json.loads(path.read_text()))


def test_repro_trace_env(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TRACE", "1")
    _, _, rt = _program(nprocs=2, flush="async")
    assert rt.tracer is not None and rt.trace_path is None

    path = tmp_path / "env.json"
    monkeypatch.setenv("REPRO_TRACE", str(path))
    _program(nprocs=2, flush="async")
    validate_trace(json.loads(path.read_text()))

    monkeypatch.setenv("REPRO_TRACE", "0")
    _, _, rt = _program(nprocs=2, flush="async")
    assert rt.tracer is None


# ---------------------------------------------------------------------------
# exporter schema
# ---------------------------------------------------------------------------


def test_export_schema_and_tracks():
    with trace() as tr:
        _program(nprocs=4, flush="async", latency=2e-4)
    doc = export_trace(tr)
    info = validate_trace(doc)
    assert doc["displayTimeUnit"] == "ms"
    assert info["n_events"] > 0
    # runtime, worker, and counter tracks all present
    assert {1, 2, 4} <= set(info["pids"])
    # at least one channel track
    assert any(pid >= 10 for pid in info["pids"])
    per_phase = info["per_phase"]
    assert per_phase.get("X", 0) > 0  # compute/wait slices
    assert per_phase.get("C", 0) > 0  # counters
    assert per_phase.get("b", 0) == per_phase.get("e", 0)  # async msgs balance
    assert per_phase.get("s", 0) == per_phase.get("f", 0)  # flow arrows pair up
    # worker tids are named
    names = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["args"]["name"].startswith("worker") for e in names
               if e["name"] == "thread_name")


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "Z", "pid": 1, "ts": 0.0, "name": "x"}]})
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X", "pid": 1, "ts": 0.0, "name": "x"}]})
    with pytest.raises(ValueError):  # unbalanced async begin
        validate_trace({"traceEvents": [
            {"ph": "b", "pid": 1, "tid": 0, "ts": 0.0, "cat": "msg", "id": "1", "name": "m"},
        ]})


def test_validate_trace_flow_endpoints():
    """Every flow id needs both an 's' and an 'f' endpoint, and the
    arrow must not point backwards in time."""
    s = {"ph": "s", "pid": 10, "tid": 0, "ts": 1.0, "cat": "unblocks",
         "id": 1, "name": "unblocks"}
    f = {"ph": "f", "bp": "e", "pid": 2, "tid": 0, "ts": 2.0,
         "cat": "unblocks", "id": 1, "name": "unblocks"}
    validate_trace({"traceEvents": [s, f]})  # well-formed arrow
    with pytest.raises(ValueError, match="missing its 'f'"):
        validate_trace({"traceEvents": [s]})
    with pytest.raises(ValueError, match="missing its 's'"):
        validate_trace({"traceEvents": [f]})
    with pytest.raises(ValueError, match="backwards in time"):
        validate_trace({"traceEvents": [dict(s, ts=3.0), f]})
    with pytest.raises(ValueError, match="without an id"):
        validate_trace({"traceEvents": [{k: v for k, v in s.items()
                                         if k != "id"}]})


def test_validate_trace_drain_nesting():
    """Async drain segments must open before they close, with no
    double-open of the same (cat, id)."""
    b = {"ph": "b", "pid": 1, "tid": 0, "ts": 0.0, "cat": "drain",
         "id": "1", "name": "drain#1"}
    e = {"ph": "e", "pid": 1, "tid": 0, "ts": 5.0, "cat": "drain",
         "id": "1", "name": "drain#1"}
    validate_trace({"traceEvents": [b, e]})
    # interleaved segments of *different* ids are the concurrent-drain
    # case the b/e encoding exists for — must stay valid
    b2, e2 = dict(b, id="2"), dict(e, id="2")
    validate_trace({"traceEvents": [b, b2, e, e2]})
    with pytest.raises(ValueError, match="never opened"):
        validate_trace({"traceEvents": [e, b]})
    with pytest.raises(ValueError, match="opened twice"):
        validate_trace({"traceEvents": [b, b, e, e]})


def test_exported_traces_pass_extended_checks():
    """Real exported traces (with flow arrows and interleaved drains)
    satisfy the flow-endpoint and drain-nesting checks."""
    with trace() as tr:
        _program(nprocs=4, flush="async", latency=1e-3)
    info = validate_trace(export_trace(tr))
    assert info["n_events"] > 0


def test_dropped_event_exported():
    """Dead-store elimination shows up as a 'drop:fuse' instant in the
    exported trace, carrying the eliminated op's uid."""
    with trace() as tr:
        with repro.runtime(nprocs=2, block_size=8, flush="async",
                           passes=("fuse",), sync="barrier"):
            a = repro.array(np.ones((16, 16)))
            t = a * 3.0  # dead temp: never read after the del
            del t
            np.asarray(a + 1.0)
    drops = [(uid, x) for _, et, uid, _, x in tr.events if et == "dropped"]
    assert drops and all(p == "fuse" for _, p in drops)
    doc = export_trace(tr)
    names = [e["name"] for e in doc["traceEvents"]
             if e.get("cat") == "plan" and e["ph"] == "i"]
    assert any(n.startswith("drop:fuse") for n in names)
    validate_trace(doc)


def test_export_roundtrip_file(tmp_path):
    with trace() as tr:
        _program(nprocs=2, flush="async")
    path = tmp_path / "rt.json"
    doc = export_trace(tr, str(path))
    on_disk = json.loads(path.read_text())
    assert validate_trace(on_disk) == validate_trace(doc)


# ---------------------------------------------------------------------------
# wait attribution
# ---------------------------------------------------------------------------


def test_attribution_report_shape():
    with trace() as tr:
        out, st, _ = _program(nprocs=4, flush="async", latency=1e-3)
    rep = attribution(tr)
    assert isinstance(rep, AttributionReport)
    assert rep.nworkers == 4
    assert rep.elapsed > 0
    assert 0.0 <= rep.wait_fraction <= 1.0
    assert rep.n_spans > 0
    assert rep.offenders  # something was waited on
    top = rep.top(3)
    assert len(top) <= 3
    assert top[0]["seconds"] >= (top[1]["seconds"] if len(top) > 1 else 0.0)
    text = rep.format(5)
    assert "wait attribution" in text and "offender" in text
    assert set(rep.per_worker) == set(range(4))


def test_attribution_charges_transfers_under_latency():
    # with injected wire latency the roll()'s halo transfers dominate:
    # attribution must name the transfer group among the top offenders
    with trace() as tr:
        _program(nprocs=4, flush="async", latency=2e-3)
    rep = attribution(tr)
    worker_offenders = [
        o for o in rep.offenders if not o["group"].startswith("flush#")
    ]
    assert worker_offenders
    xfer_groups = [o for o in worker_offenders if o["group"].startswith("xfer")]
    assert xfer_groups, [o["group"] for o in rep.offenders]
    # transfer offenders carry message traffic detail
    assert xfer_groups[0]["n_msgs"] >= 1
    assert xfer_groups[0]["msg_bytes"] > 0


def test_demand_sync_multiple_drain_segments():
    with trace() as tr:
        with repro.runtime(nprocs=4, block_size=32, flush="async",
                           sync="demand") as rt:
            a = repro.array(np.arange(4096.0).reshape(64, 64))
            b = a * 2.0
            np.asarray(np.sum(b))       # cone flush 1
            c = a + 1.0
            np.asarray(np.sum(c))       # cone flush 2
    tags = [uid for _, et, uid, _, _ in tr.events if et == "drain-begin"]
    assert len(tags) >= 2
    assert len(set(tags)) == len(tags)  # distinct flush ids


def test_concurrent_overlapping_drains_trace_valid_and_tagged():
    """Two disjoint cones in flight at once (the serving-runtime drain
    shape): the trace must stay schema-valid, drain segments balanced,
    and every executed op tagged with its own flush id — events from
    simultaneous drains interleave but never cross-tag."""
    ha = np.arange(4096.0).reshape(64, 64)
    hb = ha * 2.0 - 7.0
    with trace() as tr:
        with repro.runtime(nprocs=4, block_size=32, flush="async",
                           sync="demand", latency=2e-3, passes=()) as rt:
            a, b = repro.array(ha), repro.array(hb)
            x = api.roll(a, 1, axis=0) + a
            y = api.roll(b, 1, axis=0) + b
            t1 = rt.flush(wait=False, targets=[x])
            t2 = rt.flush(wait=False, targets=[y])  # overlaps t1's drain
            t1.wait()
            t2.wait()
            np.testing.assert_array_equal(
                np.asarray(x), np.roll(ha, 1, axis=0) + ha
            )
            np.testing.assert_array_equal(
                np.asarray(y), np.roll(hb, 1, axis=0) + hb
            )
    ev = list(tr.events)
    drain_b = [uid for _, et, uid, _, _ in ev if et == "drain-begin"]
    drain_e = [uid for _, et, uid, _, _ in ev if et == "drain-end"]
    assert len(drain_b) >= 2 and len(set(drain_b)) == len(drain_b)
    assert sorted(drain_b) == sorted(drain_e)
    # every executed compute op is attributed to exactly one drain tag
    executed = {uid for _, et, uid, _, _ in ev if et == "compute-start"}
    assert executed and executed <= set(tr.flush_of)
    assert len({tr.flush_of[uid] for uid in executed}) >= 2
    validate_trace(export_trace(tr))
    rep = attribution(tr)
    assert rep.elapsed > 0 and rep.n_spans > 0


# ---------------------------------------------------------------------------
# reporting integration (satellite: per-worker breakdown)
# ---------------------------------------------------------------------------


def test_format_stats_per_worker():
    _, st, _ = _program(nprocs=4, flush="async")
    default = repro.format_stats([("run", st)])
    assert "per-worker" not in default
    s = repro.format_stats([("run", st)], per_worker=True)
    assert "per-worker: run" in s
    assert "worker" in s and "compute ms" in s
    # simulated rows are skipped, not crashed on
    _, sim_st, _ = _program(nprocs=4, flush="sim")
    both = repro.format_stats(
        [("meas", st), ("sim", sim_st)], per_worker=True
    )
    assert "per-worker: meas" in both and "per-worker: sim" not in both
