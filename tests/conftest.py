import os

# Tests see the single real CPU device (the dry-run sets its own flag in
# a separate process).  Some sharding tests need a few fake devices; they
# spawn subprocesses (see test_collectives.py) rather than polluting this
# process's jax config.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
