"""Model-substrate tests: recurrent-path equivalences + loss sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.attention import chunked_attention
from repro.models.mamba2 import (
    init_mamba2_state,
    mamba2_apply,
    mamba2_init,
    mamba2_step,
)
from repro.models.rwkv6 import init_rwkv6_state, rwkv6_apply, rwkv6_init, rwkv6_step

F32 = dict(dtype="float32", param_dtype="float32")


def test_chunked_attention_matches_dense():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, KV, d = 2, 96, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, KV, d))
    v = jax.random.normal(ks[2], (B, S, KV, d))
    out = chunked_attention(q, k, v, causal=True, chunk=32)
    # dense oracle
    G = H // KV
    qf = q.reshape(B, S, KV, G, d) * d ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgqs,bskd->bkgqd", p, v).transpose(0, 3, 1, 2, 4).reshape(B, S, H, d)
    assert jnp.abs(out - ref).max() < 1e-4


def test_chunked_attention_kv_len_masking():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, Sk, H, d = 2, 64, 2, 16
    q = jax.random.normal(ks[0], (B, 1, H, d))
    k = jax.random.normal(ks[1], (B, Sk, H, d))
    v = jax.random.normal(ks[2], (B, Sk, H, d))
    kv_len = jnp.array([10, 30])
    out = chunked_attention(q, k, v, causal=False, kv_len=kv_len, chunk=16)
    # zeroing the invalid tail must not change the result
    mask = jnp.arange(Sk)[None, :, None, None] < kv_len[:, None, None, None]
    out2 = chunked_attention(q, k * mask, v * mask, causal=False, kv_len=kv_len, chunk=16)
    assert jnp.abs(out - out2).max() < 1e-5


def test_mamba2_prefill_decode_equivalence():
    cfg = ModelConfig(d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=8, **F32)
    p = mamba2_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 33
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    y_full, st_full = mamba2_apply(cfg, p, x)
    st = {k: v[0] for k, v in init_mamba2_state(cfg, B, 1).items()}
    ys = []
    for t in range(S):
        yt, st = mamba2_step(cfg, p, x[:, t : t + 1], st)
        ys.append(yt)
    assert jnp.abs(jnp.concatenate(ys, 1) - y_full).max() < 1e-4
    assert jnp.abs(st_full["ssm"] - st["ssm"]).max() < 1e-4


def test_rwkv6_prefill_decode_equivalence():
    cfg = ModelConfig(d_model=64, rwkv_head_size=16, d_ff=128, ssm_chunk=8, **F32)
    p = rwkv6_init(jax.random.PRNGKey(0), cfg)
    B, T = 2, 29
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.5
    y_full, st_full = rwkv6_apply(cfg, p, x)
    st0 = init_rwkv6_state(cfg, B, 1)
    st = {k: v[0] for k, v in st0.items()}
    ys = []
    for t in range(T):
        yt, st = rwkv6_step(cfg, p, x[:, t : t + 1], st)
        ys.append(yt)
    assert jnp.abs(jnp.concatenate(ys, 1) - y_full).max() < 1e-4
    assert jnp.abs(st_full["wkv"] - st["wkv"]).max() < 1e-4


def test_split_prefill_continuation():
    """Prefill in two chunks with carried state == one-shot prefill."""
    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_head_dim=8, ssm_chunk=4, **F32)
    p = mamba2_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32))
    y, _ = mamba2_apply(cfg, p, x)
    ya, st = mamba2_apply(cfg, p, x[:, :10])
    yb, _ = mamba2_apply(cfg, p, x[:, 10:], init_state=st)
    assert jnp.abs(jnp.concatenate([ya, yb], 1) - y).max() < 1e-4


def test_moe_routing_topk_weights():
    from repro.models.moe import moe_apply, moe_init

    cfg = ModelConfig(
        d_model=32, n_experts=8, top_k=2, moe_d_ff=16, d_ff=64,
        n_shared_experts=1, **F32,
    )
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    assert aux > 0.5  # load-balance loss near 1 for near-uniform routing


def test_loss_decreases_on_tiny_train():
    """Few AdamW steps on a reduced dense config actually learn."""
    from repro.configs import get_reduced
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models import init_params, loss_fn
    from repro.optim import AdamW

    cfg = get_reduced("granite-3-8b", n_layers=2, vocab_size=128, d_model=64,
                      d_ff=128, n_heads=2, n_kv_heads=2, head_dim=32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3, moment_dtype="float32")
    opt_state = opt.init(params)
    pipe = TokenPipeline(DataConfig(vocab_size=128, seq_len=32, global_batch=8, ngram=4))

    @jax.jit
    def step(params, opt_state, batch):
        (l, _), g = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, _ = opt.update(g, opt_state, params)
        return params, opt_state, l

    losses = []
    for i in range(30):
        b = pipe.batch_at(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, l = step(params, opt_state, batch)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
