"""Data pipeline determinism/sharding + optimizer unit tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import AdamW, cosine_schedule, linear_warmup_cosine


def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    a = TokenPipeline(cfg).batch_at(5)
    b = TokenPipeline(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = TokenPipeline(cfg).batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_host_shards_disjoint_rows():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    s0 = TokenPipeline(cfg, host_id=0, n_hosts=4).batch_at(2)
    s1 = TokenPipeline(cfg, host_id=1, n_hosts=4).batch_at(2)
    assert s0["tokens"].shape == (2, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_pipeline_prefetch_iterator():
    cfg = DataConfig(vocab_size=50, seq_len=4, global_batch=2)
    pipe = TokenPipeline(cfg, prefetch=2)
    it = iter(pipe)
    batches = [next(it) for _ in range(3)]
    pipe.close()
    for i, b in enumerate(batches):
        np.testing.assert_array_equal(b["tokens"], pipe.batch_at(i)["tokens"])


def test_token_range():
    cfg = DataConfig(vocab_size=37, seq_len=64, global_batch=4)
    b = TokenPipeline(cfg).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 37


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"x": 2 * params["x"]}  # d/dx x²
        params, state, m = opt.update(g, state, params)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adamw_clipping():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"x": jnp.array([3.0, 4.0, 0.0])}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(5.0)


def test_adamw_bf16_moments():
    opt = AdamW(lr=1e-3, moment_dtype="bfloat16")
    params = {"x": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state.mu["x"].dtype == jnp.bfloat16
    p2, s2, _ = opt.update({"x": jnp.ones((4, 4))}, state, params)
    assert s2.mu["x"].dtype == jnp.bfloat16
    assert p2["x"].dtype == params["x"].dtype


def test_weight_decay_matrices_only():
    opt = AdamW(lr=0.1, weight_decay=1.0, clip_norm=None)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = opt.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = opt.update(zero_g, state, params)
    assert float(p2["mat"][0, 0]) < 1.0  # decayed
    assert float(p2["vec"][0]) == 1.0  # not decayed


@settings(max_examples=20, deadline=None)
@given(warmup=st.integers(1, 50), total=st.integers(60, 500))
def test_schedule_monotone_warmup_then_decay(warmup, total):
    lr = linear_warmup_cosine(1e-3, warmup, total)
    vals = [float(lr(jnp.int32(s))) for s in range(0, total, max(1, total // 50))]
    peak = max(vals)
    assert peak <= 1e-3 * 1.01
    assert float(lr(jnp.int32(total))) < peak


def test_cosine_schedule_endpoints():
    lr = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(lr(jnp.int32(0))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1)
