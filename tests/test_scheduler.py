"""Scheduler tests: flush invariants, LH-vs-blocking, fig. 6 deadlock."""
import pytest

from repro.core import (
    COMM,
    COMPUTE,
    AccessNode,
    DependencySystem,
    OperationNode,
    run_rendezvous_bsp,
    run_schedule,
)
from repro.core.timeline import ClusterSpec

CL = ClusterSpec(nprocs=2, alpha=1e-3, beta=1e-8, o_msg=1e-5,
                 elem_time=1e-8, flop_time=1e-9, name="test")


def _chain(n, kind_of, proc_of, nbytes=1000, cost=1e-3):
    """ops[i] depends on ops[i-1] via a shared block."""
    d = DependencySystem()
    ops = []
    for i in range(n):
        op = OperationNode(
            kind_of(i), None,
            procs=proc_of(i),
            nbytes=nbytes, cost=cost,
        )
        op.add_access(AccessNode(("b", 0), None, write=True))
        d.insert(op)
        ops.append(op)
    return d, ops


def test_serial_chain_executes_in_order():
    d, ops = _chain(5, lambda i: COMPUTE, lambda i: (0,))
    res = run_schedule(d, CL)
    assert d.done
    assert res.n_compute_ops == 5
    assert res.makespan == pytest.approx(5e-3)


def test_latency_hiding_overlaps_independent_comm():
    """One compute chain on p0 + independent transfers p0->p1: in LH mode
    the transfers hide behind compute; blocking serializes them."""
    def build():
        d = DependencySystem()
        for i in range(10):
            op = OperationNode(COMPUTE, None, procs=(0,), cost=1e-3)
            op.add_access(AccessNode(("b", 0), None, write=True))
            d.insert(op)
            x = OperationNode(COMM, None, procs=(0, 1), nbytes=100_000)
            x.add_access(AccessNode(("s", i), None, write=True))
            d.insert(x)
        return d

    lh = run_schedule(build(), CL, mode="latency_hiding")
    bl = run_schedule(build(), CL, mode="blocking")
    assert lh.makespan < bl.makespan * 0.75
    assert lh.wait_fraction < bl.wait_fraction


def test_deadlock_free_invariant():
    """LH flush never waits while compute is ready (§5.7 invariant 3):
    total makespan of compute-only stream == sum of costs (no comm gaps)."""
    d, _ = _chain(20, lambda i: COMPUTE, lambda i: (0,), cost=1e-4)
    res = run_schedule(d, CL)
    assert res.makespan == pytest.approx(20e-4)


def test_naive_bsp_deadlocks_fig6():
    """Paper fig. 6: two processes, each sends its own block then
    receives — naive in-order rendezvous execution deadlocks."""
    p0 = [
        {"kind": "recv", "tag": "x", "peer": 1},
        {"kind": "send", "tag": "y", "peer": 1},
    ]
    p1 = [
        {"kind": "recv", "tag": "y", "peer": 0},
        {"kind": "send", "tag": "x", "peer": 0},
    ]
    deadlocked, steps = run_rendezvous_bsp([p0, p1])
    assert deadlocked

    # the matching well-ordered program completes
    p0 = [
        {"kind": "send", "tag": "y", "peer": 1},
        {"kind": "recv", "tag": "x", "peer": 1},
    ]
    p1 = [
        {"kind": "recv", "tag": "y", "peer": 0},
        {"kind": "send", "tag": "x", "peer": 0},
    ]
    deadlocked, steps = run_rendezvous_bsp([p0, p1])
    assert not deadlocked and steps == 4


def test_flush_algorithm_comm_first():
    """Invariant 2: a ready transfer is initiated before any ready
    compute starts — its delivery should overlap the first compute op."""
    d = DependencySystem()
    x = OperationNode(COMM, None, procs=(0, 1), nbytes=10_000_000)  # slow
    x.add_access(AccessNode(("s", 0), None, write=True))
    c = OperationNode(COMPUTE, None, procs=(0,), cost=5e-3)
    c.add_access(AccessNode(("b", 0), None, write=True))
    # consumer of the transfer on p1
    c2 = OperationNode(COMPUTE, None, procs=(1,), cost=1e-3)
    c2.add_access(AccessNode(("s", 0), None, write=False))
    c2.add_access(AccessNode(("b", 1), None, write=True))
    for op in (c, x, c2):
        d.insert(op)
    res = run_schedule(d, CL, mode="latency_hiding")
    # comm ~0.1s dominates; compute hid inside it
    assert res.makespan == pytest.approx(CL.comm_time(10_000_000) + 1e-3, rel=0.05)
