"""Mutation suite for the static analyzer (repro.analysis).

Each analysis must catch its seeded mutant:

* the happens-before plan verifier flags a dependence-inverting
  rewrite, dead-store elimination of a live store (including the PR-4
  bug class: an unrestricted dead set dropping a store whose consumer
  is in the flush remainder), and a merged node whose placement hoists
  a read above a conflicting write;
* the region-level race detector (the soundness oracle for the
  key-granular ``cones_conflict``) flags a broken conflict test that
  would let racing drains run concurrently — and counts key-level
  conflicts that are region-level false positives as the precision
  report;
* the static deadlock detector rejects the paper's fig. 6 rendezvous
  cycle (and unmatched messages) at plan time, and flags planned ops
  reading scratch no producer delivers.

And the built-in pipelines must verify clean: every diagnostic on a
real program is a bug in a pass, not noise.
"""
import numpy as np
import pytest

import repro
from repro.analysis import (
    AnalysisReport,
    Diagnostic,
    VerificationError,
    available_rules,
    check,
    register_rule,
)
from repro.api.config import ExecutionPolicy
from repro.api.registry import PASSES, RULES, register_pass
from repro.core.engine import FlushTicket
from repro.core.graph import (
    COMPUTE,
    AccessNode,
    OperationNode,
    cone_region_footprint,
    region_footprints_conflict,
)

FULL = ExecutionPolicy(flush="async", channel="async", sync="demand",
                       verify="full")


@pytest.fixture
def evil_pass():
    """Register a throwaway mutant pass; unregister on teardown."""
    names = []

    def add(name, fn):
        register_pass(name, fn)
        names.append(name)
        return name

    yield add
    for name in names:
        PASSES.unregister(name)


def _mk(key, region, write, label):
    op = OperationNode(COMPUTE, None, procs=(0,), label=label)
    op.add_access(AccessNode(key, region, write=write))
    return op


# ---------------------------------------------------------------------------
# configuration surface
# ---------------------------------------------------------------------------


def test_policy_verify_validation():
    with pytest.raises(ValueError, match="off|plan|full"):
        ExecutionPolicy(verify="bogus")
    for mode in ("off", "plan", "full"):
        assert ExecutionPolicy(verify=mode).verify == mode


def test_runtime_verify_kwarg_and_env(monkeypatch):
    from repro.core.engine import Runtime

    rt = Runtime(nprocs=2, verify="plan")
    assert rt.verify_mode == "plan" and rt.verify_stats is not None
    rt = Runtime(nprocs=2)
    assert rt.verify_mode == "off" and rt.verify_stats is None

    monkeypatch.setenv("REPRO_VERIFY", "full")
    rt = Runtime(nprocs=2)
    assert rt.verify_mode == "full"
    # explicit kwarg beats the environment
    rt = Runtime(nprocs=2, verify="plan")
    assert rt.verify_mode == "plan"
    monkeypatch.setenv("REPRO_VERIFY", "bogus")
    with pytest.raises(ValueError, match="verify"):
        Runtime(nprocs=2)


def test_register_rule_registry():
    assert {"plan", "races", "deadlock"} <= set(available_rules())
    seen = []

    @register_rule("test-custom")
    def custom(ctx):
        seen.append(True)
        ctx.emit("test-custom", "info", "ran")

    try:
        rep = check(rules=("test-custom",))
        assert seen and len(rep.diagnostics) == 1
        assert rep.rules_run == ("test-custom",)
    finally:
        RULES.unregister("test-custom")


# ---------------------------------------------------------------------------
# clean programs: built-in pipelines verify clean under verify="full"
# ---------------------------------------------------------------------------


def test_builtin_pipeline_verifies_clean():
    with repro.runtime(nprocs=4, block_size=16, policy=FULL) as rt:
        a = repro.array(np.arange(64.0))
        b = a * 2.0 + 1.0
        t = b * b
        s = t.sum()  # dead temp -> map+reduce fusion
        del t
        a[0:8] = 7.0
        np.testing.assert_allclose(np.asarray(b), np.arange(64.0) * 2 + 1)
        np.testing.assert_allclose(
            np.asarray(s), ((np.arange(64.0) * 2 + 1) ** 2).sum()
        )
        vs = rt.verify_stats
        assert vs.n_flushes_verified >= 1
        assert vs.n_diagnostics == 0
        assert rt.last_verify_report is not None
        assert rt.last_verify_report.ok


def test_check_identity_plan_is_clean():
    ops = [
        _mk((1, (0,)), ((0, 8),), True, "w"),
        _mk((1, (0,)), ((0, 8),), False, "r"),
    ]
    rep = check(pre=ops, post=ops, rules=("plan", "deadlock"))
    assert rep.ok and not rep.diagnostics


# ---------------------------------------------------------------------------
# plan-rule mutants
# ---------------------------------------------------------------------------


def test_inversion_mutant_caught(evil_pass):
    def reverse(ctx):
        ctx.ops = list(reversed(ctx.ops))
        ctx.dirty = True

    name = evil_pass("evil-reverse", reverse)
    with pytest.raises(VerificationError) as ei:
        with repro.runtime(nprocs=2, block_size=16,
                           policy=FULL.replace(passes=(name,))):
            a = repro.ones((32,))
            a += 1.0
            a *= 3.0  # conflicting write pair -> inverted by the mutant
            np.asarray(a)
    report = ei.value.report
    assert any(d.rule == "plan" and "inverted" in d.message
               for d in report.errors)


def test_dropped_live_store_mutant_caught(evil_pass):
    def drop_first_store(ctx):
        for i, op in enumerate(ctx.ops):
            if any(a.write and a.key[0] != "s" for a in op.accesses):
                ctx.note_drop(op)
                ctx.ops = ctx.ops[:i] + ctx.ops[i + 1:]
                ctx.dirty = True
                return

    name = evil_pass("evil-drop", drop_first_store)
    with pytest.raises(VerificationError) as ei:
        with repro.runtime(nprocs=2, block_size=16,
                           policy=FULL.replace(passes=(name,))):
            a = repro.ones((32,))
            a += 1.0
            np.asarray(a)
    report = ei.value.report
    err = next(d for d in report.errors if d.rule == "plan")
    assert "live base" in err.message
    assert err.pass_name == "evil-drop"  # provenance blames the mutant


def test_pr4_unrestricted_dse_mutant_caught(evil_pass):
    """The PR-4 bug class: fuse's dead-store elimination must not treat
    a base as dead when its consumer is in the flush *remainder* (the
    engine restricts the dead set per cone).  A mutant doing DSE with
    the unrestricted runtime-wide dead set drops the producer whose
    consumer is still pending — the verifier must flag it; the real
    pipeline on the same program must verify clean and stay correct."""
    host = np.arange(32.0)

    def scenario(rt_policy, rt_holder=None):
        with repro.runtime(nprocs=2, block_size=16, policy=rt_policy) as rt:
            if rt_holder is not None:
                rt_holder.append(rt)
            a = repro.array(host.copy())
            np.asarray(a)  # drain creation: the cone below is P+W only
            x = a * 2.0  # P: producer, reads a
            y = x + 1.0  # C: consumer — stays in the remainder
            a[0:16] = 7.0  # W: write to a pulls P in (anti-dependency)
            del x  # x's base is GC-dead runtime-wide, but C still reads it
            # sub-view readback forces the {P, W} cone; C is remainder
            sub = np.asarray(a[0:16])
            return sub, np.asarray(y)

    # the real pipeline: correct and clean
    holder = []
    sub, y = scenario(FULL.replace(passes=("coalesce", "fuse", "batch")),
                      holder)
    np.testing.assert_allclose(sub, 7.0)
    np.testing.assert_allclose(y, host * 2.0 + 1.0)
    assert holder[0].verify_stats.n_diagnostics == 0

    # the mutant: DSE keyed on the *unrestricted* dead set
    holder2 = []

    def unrestricted_dse(ctx):
        rt = holder2[0]
        drop = [
            i for i, op in enumerate(ctx.ops)
            if getattr(op.payload, "out_base", None) in rt._dead_bases
        ]
        if drop:
            for i in drop:
                ctx.note_drop(ctx.ops[i])
            ctx.ops = [op for i, op in enumerate(ctx.ops)
                       if i not in set(drop)]
            ctx.dirty = True

    name = evil_pass("evil-unrestricted-dse", unrestricted_dse)
    with pytest.raises(VerificationError) as ei:
        scenario(FULL.replace(passes=(name,)), holder2)
    report = ei.value.report
    err = next(d for d in report.errors if d.rule == "plan")
    assert "live base" in err.message
    assert err.pass_name == "evil-unrestricted-dse"


def test_merge_hoisting_read_above_write_caught():
    """A merged node is exempt from ordering *within itself*, but its
    placement must still respect conflicts with third ops: merging two
    reads across an intervening write hoists the later read."""
    k = (7, (0,))
    A = _mk(k, None, False, "readA")
    B = _mk(k, None, True, "writeB")
    C = _mk(k, None, False, "readC")
    M = OperationNode(COMPUTE, None, procs=(0,), label="mergedAC")
    M.add_access(AccessNode(k, None, write=False))
    rep = check(
        pre=[A, B, C],
        post=[M, B],
        provenance={M.uid: ("evil-merge", (A.uid, C.uid))},
        rules=("plan",),
    )
    err = next(d for d in rep.errors if d.rule == "plan")
    assert "inverted" in err.message
    assert set(err.ops) == {B.uid, C.uid}
    assert err.pass_name == "evil-merge"


def test_legit_merge_shares_position_no_false_positive():
    """Coalesce-style merges keep both members at one post position —
    conflicting accesses *inside* the merged node must not be reported
    (they execute atomically in the merged payload)."""
    k = (7, (0,))
    A = _mk(k, None, True, "w1")
    B = _mk(k, None, True, "w2")
    M = _mk(k, None, True, "merged")
    rep = check(pre=[A, B], post=[M],
                provenance={M.uid: ("coalesce", (A.uid, B.uid))},
                rules=("plan",))
    assert rep.ok


# ---------------------------------------------------------------------------
# race-rule mutants (the cones_conflict soundness oracle)
# ---------------------------------------------------------------------------


class _FakeFut:
    """An in-flight drain future: never done, resolves to None when
    joined (so _join_conflicting does not block)."""

    def done(self):
        return False

    def result(self, timeout=None):
        return None

    def add_done_callback(self, fn):
        pass


def test_race_rule_flags_broken_cones_conflict(monkeypatch):
    c1 = [_mk((1, (0,)), ((0, 16),), True, "w0")]
    c2 = [_mk((1, (0,)), ((8, 24),), False, "r0")]
    rep = check(cones=[("A", c1), ("B", c2)], rules=("races",))
    assert rep.ok and rep.n_key_conflicts == 1  # sound oracle: no error

    from repro.core import graph as G

    monkeypatch.setattr(G, "cones_conflict", lambda a, b: False)
    rep = check(cones=[("A", c1), ("B", c2)], rules=("races",))
    err = next(d for d in rep.errors if d.rule == "races")
    assert "race" in err.message and err.key == (1, (0,))


def test_race_rule_precision_report():
    c1 = [_mk((1, (0,)), ((0, 8),), True, "w")]
    c2 = [_mk((1, (0,)), ((8, 16),), False, "r")]
    rep = check(cones=[c1, c2], rules=("races",))
    assert rep.ok
    assert rep.n_key_conflicts == 1
    assert rep.n_region_false_positives == 1  # disjoint regions, same key
    assert any(d.severity == "info" for d in rep.diagnostics)


def test_engine_race_oracle_catches_broken_cones_conflict(monkeypatch):
    """verify="full" end to end: a fabricated in-flight drain whose
    region footprint overlaps the new cone, plus a broken (always-
    False) cones_conflict, must abort the flush — with the in-flight
    state untouched (the check runs before any join/extraction)."""
    from repro.core import graph as G

    with repro.runtime(nprocs=2, block_size=8, policy=FULL) as rt:
        a = repro.array(np.ones(16))
        np.asarray(a)  # drain creation ops
        a += 1.0
        key = (a._base.id, (0,))
        fake = FlushTicket(rt, fut=_FakeFut(), tag=999,
                           keys=(set(), {key}),
                           regions={key: ([], [None])})
        rt._tickets.append(fake)
        try:
            monkeypatch.setattr(G, "cones_conflict", lambda x, y: False)
            n_pending = rt.deps.n_pending
            with pytest.raises(VerificationError) as ei:
                np.asarray(a)
            assert rt.deps.n_pending == n_pending  # nothing extracted
            assert rt.verify_stats.n_race_checks >= 1
            err = next(iter(ei.value.report.errors))
            assert err.rule == "races" and err.key == key
        finally:
            rt._tickets.remove(fake)
        np.testing.assert_allclose(np.asarray(a), 2.0)  # still usable


def test_engine_precision_counters():
    """A key-level conflict whose regions are disjoint serializes the
    drains (sound) but counts as a region-level false positive — the
    precision statistic the sub-block cone roadmap item feeds on."""
    with repro.runtime(nprocs=2, block_size=8, policy=FULL) as rt:
        a = repro.array(np.ones(16))
        np.asarray(a)
        a[0:4] += 1.0  # sub-region write in block 0
        ops = rt.deps.pending_ops()
        regions = [acc.region for op in ops for acc in op.accesses
                   if acc.write and acc.key == (a._base.id, (0,))]
        assert regions and all(r is not None for r in regions)
        key = (a._base.id, (0,))
        fake = FlushTicket(rt, fut=_FakeFut(), tag=998,
                           keys=(set(), {key}),
                           regions={key: ([], [((4, 8),)])})
        rt._tickets.append(fake)
        np.asarray(a)  # joins the fake (key conflict), counts the fp
        vs = rt.verify_stats
        assert vs.n_key_conflicts >= 1
        assert vs.n_region_false_positives >= 1
        assert vs.precision is not None and vs.precision < 1.0
        assert vs.n_diagnostics == 0  # precision loss is not an error


def test_region_footprint_geometry():
    ops = [
        _mk((1, (0,)), ((0, 8),), True, "w"),
        _mk((1, (0,)), ((4, 12),), False, "r"),
        _mk((2, (0,)), None, True, "whole"),
    ]
    fp = cone_region_footprint(ops)
    assert fp[(1, (0,))] == ([((4, 12),)], [((0, 8),)])
    assert fp[(2, (0,))] == ([], [None])
    other = cone_region_footprint([_mk((1, (0,)), ((12, 16),), True, "w2")])
    assert region_footprints_conflict(fp, other) is None  # disjoint regions
    other2 = cone_region_footprint([_mk((1, (0,)), ((6, 16),), True, "w3")])
    assert region_footprints_conflict(fp, other2) == (1, (0,))


# ---------------------------------------------------------------------------
# deadlock rule: static fig. 6 + dangling scratch
# ---------------------------------------------------------------------------


def test_fig6_cycle_rejected_statically():
    p0 = [{"kind": "recv", "tag": "x", "peer": 1},
          {"kind": "send", "tag": "y", "peer": 1}]
    p1 = [{"kind": "recv", "tag": "y", "peer": 0},
          {"kind": "send", "tag": "x", "peer": 0}]
    rep = check(schedule=[p0, p1], rules=("deadlock",))
    err = next(d for d in rep.errors if d.rule == "deadlock")
    assert "cycle" in err.message
    assert "stuck operation-nodes" in err.message
    assert "p0@step0" in err.message and "p1@step0" in err.message
    assert "recv tag='x'" in err.message


def test_well_ordered_schedule_is_clean():
    p0 = [{"kind": "send", "tag": "y", "peer": 1},
          {"kind": "compute"},
          {"kind": "recv", "tag": "x", "peer": 1}]
    p1 = [{"kind": "recv", "tag": "y", "peer": 0},
          {"kind": "send", "tag": "x", "peer": 0}]
    assert check(schedule=[p0, p1], rules=("deadlock",)).ok


def test_unmatched_message_rejected():
    p0 = [{"kind": "send", "tag": "z", "peer": 1}]
    p1 = [{"kind": "compute"}]
    rep = check(schedule=[p0, p1], rules=("deadlock",))
    err = next(d for d in rep.errors if d.rule == "deadlock")
    assert "unmatched" in err.message and "p0@step0" in err.message


def test_rendezvous_runner_rejects_fig6_before_any_thread():
    """run_rendezvous_bsp_async refuses statically (plan time), and the
    dynamic detector still exists behind static_check=False."""
    from repro.exec.backend import DeadlockError, run_rendezvous_bsp_async

    p0 = [{"kind": "recv", "tag": "x", "peer": 1},
          {"kind": "send", "tag": "y", "peer": 1}]
    p1 = [{"kind": "recv", "tag": "y", "peer": 0},
          {"kind": "send", "tag": "x", "peer": 0}]
    with pytest.raises(DeadlockError, match="statically at plan time"):
        run_rendezvous_bsp_async([p0, p1])
    with pytest.raises(DeadlockError, match="every live rank is parked"):
        run_rendezvous_bsp_async([p0, p1], static_check=False)


def test_dangling_scratch_read_flagged():
    reader = _mk(("s", 123), None, False, "scratch-reader")
    rep = check(post=[reader], rules=("deadlock",))
    err = next(d for d in rep.errors if d.rule == "deadlock")
    assert "stall" in err.message
    # already delivered by an earlier drain: fine
    assert check(post=[reader], scratch_available=[123],
                 rules=("deadlock",)).ok
    # written by an earlier planned op: fine
    writer = _mk(("s", 123), None, True, "scratch-writer")
    assert check(post=[writer, reader], rules=("deadlock",)).ok
    # a pass dropped the producer: blamed
    rep = check(pre=[writer, reader], post=[reader],
                dropped={writer.uid: "evil"}, rules=("deadlock",))
    err = next(d for d in rep.errors if d.rule == "deadlock")
    assert err.pass_name == "evil"


# ---------------------------------------------------------------------------
# report surface
# ---------------------------------------------------------------------------


def test_report_and_error_formatting():
    d = Diagnostic("plan", "error", "boom", ops=(1, 2), key=(1, (0,)),
                   pass_name="fuse")
    assert "plan/error" in str(d) and "fuse" in str(d)
    with pytest.raises(ValueError):
        Diagnostic("plan", "fatal", "bad severity")
    rep = AnalysisReport(diagnostics=[d])
    assert not rep.ok and rep.errors == [d]
    with pytest.raises(VerificationError) as ei:
        rep.raise_if_errors()
    assert ei.value.report is rep
    assert "static verification failed with 1 error" in str(ei.value)
