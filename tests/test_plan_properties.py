"""Property-based plan-stage invariant: any registered pass pipeline
preserves the total order of conflicting accesses, so planned graphs
stay bit-identical to the unplanned simulator on random programs.

Random programs mix fills, strided slice writes, elementwise maps with
cross-block transfers, in-place updates, and reductions over dead
temporaries — the exact shapes the coalesce/fuse rewrites target.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

import repro

SHAPE = (8, 6)
N_ARRAYS = 3

# one program step: (kind, *params); indexes are taken modulo the pool
_step = st.one_of(
    st.tuples(st.just("fill"), st.integers(0, 9), st.integers(0, 7),
              st.integers(0, 5), st.floats(-4, 4, allow_nan=False)),
    st.tuples(st.just("binop"), st.integers(0, 9), st.integers(0, 9),
              st.sampled_from(["add", "mul", "max"])),
    st.tuples(st.just("setslice"), st.integers(0, 9), st.integers(0, 9),
              st.integers(0, 7)),
    st.tuples(st.just("iadd"), st.integers(0, 9), st.integers(0, 9)),
    st.tuples(st.just("sumexpr"), st.integers(0, 9), st.integers(0, 9),
              st.integers(0, 1)),
    st.tuples(st.just("reduce"), st.integers(0, 9), st.integers(0, 1)),
)
programs = st.lists(_step, min_size=1, max_size=10)

_BINOPS = {
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
}


def _run(prog, passes):
    from repro.core import darray as dnp

    with repro.runtime(nprocs=4, block_size=3, passes=passes):
        arrs = [
            dnp.array(np.arange(48.0).reshape(SHAPE) * (i + 1) - 20.0)
            for i in range(N_ARRAYS)
        ]
        outs = []
        for step in prog:
            kind = step[0]
            if kind == "fill":
                _, d, r0, c0, v = step
                dst = arrs[d % len(arrs)]
                dst[r0 % SHAPE[0]:, c0 % SHAPE[1]:] = float(v)
            elif kind == "binop":
                _, a, b, opname = step
                x, y = arrs[a % len(arrs)], arrs[b % len(arrs)]
                if opname == "max":
                    arrs.append(dnp.maximum(x, y))
                else:
                    arrs.append(_BINOPS[opname](x, y))
            elif kind == "setslice":
                _, d, s, r0 = step
                dst, src = arrs[d % len(arrs)], arrs[s % len(arrs)]
                lo = r0 % SHAPE[0]
                dst[lo:, :] = src[lo:, :]
            elif kind == "iadd":
                _, d, s = step
                if d % len(arrs) != s % len(arrs):
                    arrs[d % len(arrs)] += arrs[s % len(arrs)]
            elif kind == "sumexpr":
                _, a, b, ax = step
                x, y = arrs[a % len(arrs)], arrs[b % len(arrs)]
                outs.append((x * y).sum(axis=ax))  # dead temp -> fuse target
            elif kind == "reduce":
                _, a, ax = step
                outs.append(arrs[a % len(arrs)].sum(axis=ax))
        return [np.asarray(a).copy() for a in arrs] + [
            np.asarray(o).copy() for o in outs
        ]


@settings(max_examples=20, deadline=None)
@given(prog=programs)
def test_passes_bit_identical_to_unplanned_simulator(prog):
    baseline = _run(prog, passes=())
    for pipeline in (("coalesce",), ("fuse",), ("coalesce", "fuse")):
        got = _run(prog, passes=pipeline)
        assert len(got) == len(baseline)
        for ref, out in zip(baseline, got):
            np.testing.assert_array_equal(ref, out, err_msg=f"{pipeline}")
