"""Property-based plan-stage invariant: any registered pass pipeline
preserves the total order of conflicting accesses, so planned graphs
stay bit-identical to the unplanned simulator on random programs.

Random programs mix fills, strided slice writes, elementwise maps with
cross-block transfers, in-place updates, and reductions over dead
temporaries — the exact shapes the coalesce/fuse rewrites target.

The demand-driven readback surface adds a second axis: under
``sync="demand"`` every readback extracts and drains only the
dependency cone of its base, so the *forcing order* of multiple cones
partitions the recorded graph differently on every run.  The second
property below randomizes that order and checks every pass pipeline ×
sync mode combination against the unplanned barrier simulator.
"""
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

import repro

SHAPE = (8, 6)
N_ARRAYS = 3

# one program step: (kind, *params); indexes are taken modulo the pool
_step = st.one_of(
    st.tuples(st.just("fill"), st.integers(0, 9), st.integers(0, 7),
              st.integers(0, 5), st.floats(-4, 4, allow_nan=False)),
    st.tuples(st.just("binop"), st.integers(0, 9), st.integers(0, 9),
              st.sampled_from(["add", "mul", "max"])),
    st.tuples(st.just("setslice"), st.integers(0, 9), st.integers(0, 9),
              st.integers(0, 7)),
    st.tuples(st.just("iadd"), st.integers(0, 9), st.integers(0, 9)),
    st.tuples(st.just("sumexpr"), st.integers(0, 9), st.integers(0, 9),
              st.integers(0, 1)),
    st.tuples(st.just("reduce"), st.integers(0, 9), st.integers(0, 1)),
)
programs = st.lists(_step, min_size=1, max_size=10)

_BINOPS = {
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
}


def _exec_program(prog, force_seed=None):
    """Interpret one program inside the *current* runtime and force
    every array/output (in a seed-shuffled cone order when asked),
    returning the gathered ndarrays."""
    from repro.core import darray as dnp

    arrs = [
        dnp.array(np.arange(48.0).reshape(SHAPE) * (i + 1) - 20.0)
        for i in range(N_ARRAYS)
    ]
    outs = []
    for step in prog:
        kind = step[0]
        if kind == "fill":
            _, d, r0, c0, v = step
            dst = arrs[d % len(arrs)]
            dst[r0 % SHAPE[0]:, c0 % SHAPE[1]:] = float(v)
        elif kind == "binop":
            _, a, b, opname = step
            x, y = arrs[a % len(arrs)], arrs[b % len(arrs)]
            if opname == "max":
                arrs.append(dnp.maximum(x, y))
            else:
                arrs.append(_BINOPS[opname](x, y))
        elif kind == "setslice":
            _, d, s, r0 = step
            dst, src = arrs[d % len(arrs)], arrs[s % len(arrs)]
            lo = r0 % SHAPE[0]
            dst[lo:, :] = src[lo:, :]
        elif kind == "iadd":
            _, d, s = step
            if d % len(arrs) != s % len(arrs):
                arrs[d % len(arrs)] += arrs[s % len(arrs)]
        elif kind == "sumexpr":
            _, a, b, ax = step
            x, y = arrs[a % len(arrs)], arrs[b % len(arrs)]
            outs.append((x * y).sum(axis=ax))  # dead temp -> fuse target
        elif kind == "reduce":
            _, a, ax = step
            outs.append(arrs[a % len(arrs)].sum(axis=ax))
    everything = list(arrs) + list(outs)
    results = [None] * len(everything)
    order = list(range(len(everything)))
    if force_seed is not None:
        # randomized forcing order: each readback extracts + drains
        # one dependency cone; the cones partition the graph
        # differently for every permutation
        random.Random(force_seed).shuffle(order)
    for i in order:
        results[i] = np.asarray(everything[i]).copy()
    return results


def _run(prog, passes, sync="auto", force_seed=None, verify="off",
         verify_stats_out=None):
    with repro.runtime(nprocs=4, block_size=3, passes=passes, sync=sync,
                       verify=verify) as _rt:
        if verify_stats_out is not None:
            verify_stats_out.append(_rt.verify_stats)
        return _exec_program(prog, force_seed=force_seed)


@settings(max_examples=20, deadline=None)
@given(prog=programs)
def test_passes_bit_identical_to_unplanned_simulator(prog):
    baseline = _run(prog, passes=())
    for pipeline in (("coalesce",), ("fuse",), ("coalesce", "fuse")):
        got = _run(prog, passes=pipeline)
        assert len(got) == len(baseline)
        for ref, out in zip(baseline, got):
            np.testing.assert_array_equal(ref, out, err_msg=f"{pipeline}")


# per-tenant chain step for the concurrent-cone property: elementwise
# ops plus rolls (the roll forces cross-block halo transfers, so the
# overlapping drains really do share the channel and the worker pool)
_tenant_op = st.one_of(
    st.tuples(st.just("mul"), st.floats(-2, 2, allow_nan=False)),
    st.tuples(st.just("add"), st.floats(-2, 2, allow_nan=False)),
    st.tuples(st.just("roll"), st.integers(-3, 3), st.integers(0, 1)),
)
tenant_programs = st.lists(
    st.lists(_tenant_op, min_size=1, max_size=5), min_size=2, max_size=4
)


def _apply_chain(x, prog):
    """Run one tenant's op chain on ``x`` — a NumPy ndarray or a
    DistArray (np.roll dispatches through __array_function__)."""
    for step in prog:
        if step[0] == "mul":
            x = x * step[1]
        elif step[0] == "add":
            x = x + step[1]
        else:
            x = np.roll(x, step[1], axis=step[2])
    return x


@settings(max_examples=8, deadline=None)
@given(progs=tenant_programs, seed=st.integers(0, 2**16))
def test_concurrent_disjoint_cones_bit_identical_to_barrier(progs, seed):
    """Serving-runtime property: each tenant's chain hangs off its own
    base array, so the cones are pairwise disjoint; submitting every
    cone via ``flush(wait=False)`` in a random order — all in flight
    before any is awaited — must be bit-identical to one barrier flush
    of the same graph and to the NumPy closed form, for both the empty
    and the full pass pipeline."""
    from repro.core import darray as dnp

    hosts = [
        np.arange(48.0).reshape(SHAPE) * (i + 1) - 20.0
        for i in range(len(progs))
    ]
    expected = [_apply_chain(h, p) for h, p in zip(hosts, progs)]
    for passes in ((), ("coalesce", "fuse", "batch")):
        # concurrent leg: every cone submitted before any wait
        with repro.runtime(nprocs=4, block_size=3, passes=passes,
                           flush="async", sync="demand",
                           latency=1e-3) as rt:
            outs = [_apply_chain(dnp.array(h), p)
                    for h, p in zip(hosts, progs)]
            order = list(range(len(outs)))
            random.Random(seed).shuffle(order)
            tickets = [(i, rt.flush(wait=False, targets=[outs[i]]))
                       for i in order]
            for _, t in tickets:
                t.wait()
            got = [np.asarray(o).copy() for o in outs]
        # barrier leg: the same graph, one whole-graph drain
        with repro.runtime(nprocs=4, block_size=3, passes=passes,
                           flush="async", sync="demand",
                           latency=1e-3) as rt:
            outs = [_apply_chain(dnp.array(h), p)
                    for h, p in zip(hosts, progs)]
            rt.flush()
            got_barrier = [np.asarray(o).copy() for o in outs]
        for ref, c, b in zip(expected, got, got_barrier):
            np.testing.assert_array_equal(
                c, ref, err_msg=f"concurrent diverged, passes={passes}"
            )
            np.testing.assert_array_equal(
                b, ref, err_msg=f"barrier diverged, passes={passes}"
            )


@settings(max_examples=12, deadline=None)
@given(prog=programs, seed=st.integers(0, 2**16))
def test_builtin_pipelines_verify_clean(prog, seed):
    """Static-verifier property: random programs × every built-in pass
    pipeline × sync modes produce ZERO diagnostics under
    ``verify="full"`` — no VerificationError, nothing collected.  Every
    diagnostic on a real program is a pass bug, not noise."""
    for pipeline in (("coalesce",), ("fuse",), ("coalesce", "fuse")):
        for sync in ("barrier", "demand"):
            sink = []
            _run(prog, passes=pipeline, sync=sync, force_seed=seed,
                 verify="full", verify_stats_out=sink)
            vs = sink[0]
            assert vs.n_diagnostics == 0, (
                f"passes={pipeline} sync={sync}: {vs}"
            )
            assert vs.n_flushes_verified >= 1


@settings(max_examples=12, deadline=None)
@given(prog=programs, seed=st.integers(0, 2**16))
def test_mutated_pipeline_always_flagged(prog, seed):
    """The complement: a seeded dependence-inverting mutant appended to
    the pipeline is *always* caught (the program is salted with one
    guaranteed conflicting write pair, so every run has an inversion to
    find)."""
    from repro.analysis import VerificationError
    from repro.api.registry import PASSES, register_pass

    def evil_reverse(ctx):
        if len(ctx.ops) > 1:
            ctx.ops = list(reversed(ctx.ops))
            ctx.dirty = True

    register_pass("evil-reverse-prop", evil_reverse, overwrite=True)
    try:
        salted = [("fill", 0, 0, 0, 1.0), ("iadd", 0, 1)] + list(prog)
        with pytest.raises(VerificationError):
            _run(salted, passes=("evil-reverse-prop",), sync="barrier",
                 verify="plan")
    finally:
        PASSES.unregister("evil-reverse-prop")


@settings(max_examples=15, deadline=None)
@given(prog=programs, seed=st.integers(0, 2**16))
def test_demand_cone_forcing_order_bit_identical(prog, seed):
    """Acceptance gate: every pass pipeline × sync mode combination is
    bit-identical to the unplanned barrier simulator, with the cones
    forced in a random order under sync="demand"."""
    baseline = _run(prog, passes=())
    for pipeline in ((), ("coalesce",), ("fuse",), ("coalesce", "fuse")):
        for sync in ("barrier", "demand"):
            got = _run(prog, passes=pipeline, sync=sync, force_seed=seed)
            assert len(got) == len(baseline)
            for ref, out in zip(baseline, got):
                np.testing.assert_array_equal(
                    ref, out, err_msg=f"passes={pipeline} sync={sync}"
                )


@settings(max_examples=10, deadline=None)
@given(prog=programs, seed=st.integers(0, 2**16))
def test_plan_cache_hits_bit_identical_to_cold_plans(prog, seed):
    """Plan-shape-cache property: running a random program twice inside
    one runtime (same forcing order, so the second repetition's cones
    are renamings of the first's) must hit the cache and stay
    bit-identical to the cache-off run and to the unplanned simulator —
    a replayed recipe is the *same plan*, re-targeted."""
    baseline = _run(prog, passes=())
    for pipeline in (("coalesce",), ("coalesce", "fuse")):
        legs = {}
        for cache_on in (False, True):
            with repro.runtime(nprocs=4, block_size=3, passes=pipeline,
                               sync="demand", plan_cache=cache_on) as rt:
                reps = [_exec_program(prog, force_seed=seed)
                        for _ in range(2)]
                if cache_on:
                    assert rt._plan_cache is not None
                    # every cone of rep 2 is a renaming of a rep-1 cone
                    assert rt._plan_cache.hits > 0, repr(rt._plan_cache)
            legs[cache_on] = reps
        for cache_on, reps in legs.items():
            for rep in reps:
                assert len(rep) == len(baseline)
                for ref, out in zip(baseline, rep):
                    np.testing.assert_array_equal(
                        ref, out,
                        err_msg=f"passes={pipeline} cache={cache_on}",
                    )
