"""The paper's flagship experiment (figs. 10/18), runnable end-to-end:
the Jacobi stencil with latency-hiding vs blocking communication, plus
the beyond-paper fused (§7) variant and the TPU shard_map mapping.

    PYTHONPATH=src python examples/stencil_latency_hiding.py
"""
import numpy as np

from benchmarks.paper_apps import run_app

N, ITERS = 1024, 6

print(f"Jacobi stencil {N}x{N}, {ITERS} sweeps, 16 processes "
      f"(paper fig. 18 setup)\n")

st_lh, r_lh = run_app("jacobi_stencil", mode="latency_hiding", n=N, iters=ITERS, block_size=128)
st_bl, r_bl = run_app("jacobi_stencil", mode="blocking", n=N, iters=ITERS, block_size=128)
st_fu, r_fu = run_app("jacobi_stencil", mode="latency_hiding", fusion=True, n=N, iters=ITERS, block_size=128)
np.testing.assert_allclose(r_lh, r_bl)
np.testing.assert_allclose(r_lh, r_fu)

print(f"{'variant':24s} {'makespan':>10s} {'wait%':>7s} {'speedup':>8s}")
for name, st in (("blocking (baseline)", st_bl),
                 ("latency-hiding (paper)", st_lh),
                 ("LH + fusion (§7, ours)", st_fu)):
    print(f"{name:24s} {st.makespan*1e3:8.1f}ms {st.wait_fraction*100:6.1f}% {st.speedup:8.2f}")

print(f"\nlatency-hiding wall-clock win: {st_bl.makespan/st_lh.makespan:.2f}x "
      f"(paper: 18.4/7.7 = 2.4x at 16 cores)")

# --- the same program, executed for real (repro.exec) -------------------
# flush_backend="async" drains the identical dependency graphs on worker
# threads: transfers go through a non-blocking progress engine (overlap
# on) or a synchronous channel (overlap off), with the cluster's α
# injected per message so there is real latency to hide.  The wait% here
# is MEASURED on the wall clock, not simulated.  (Smaller grid and a
# scaled-up 10 ms α: past ~10k sub-ms block ops, Python thread-scheduling
# overhead — not communication — dominates a single-machine run, so the
# injected latency must dominate the ~0.1 ms/op dispatch cost.)
MN = 512
st_on, r_on = run_app("jacobi_stencil", n=MN, iters=ITERS, block_size=128,
                      nprocs=8, flush_backend="async",
                      exec_channel="async", exec_latency=10e-3)
st_off, r_off = run_app("jacobi_stencil", n=MN, iters=ITERS, block_size=128,
                        nprocs=8, flush_backend="async",
                        exec_channel="blocking", exec_latency=10e-3)
np.testing.assert_array_equal(r_on, r_off)

print(f"\nmeasured (repro.exec, {MN}x{MN}, 8 workers):")
for name, st in (("overlap off (blocking)", st_off),
                 ("overlap on (async)", st_on)):
    print(f"{name:24s} {st.makespan*1e3:8.1f}ms {st.wait_fraction*100:6.1f}% "
          f"{st.speedup:8.2f}")
print(f"measured overlap win: {st_off.makespan/st_on.makespan:.2f}x")

# --- the same schedule as a compiled TPU/XLA program --------------------
# (runs on CPU here; on a TPU pod the ppermute halo exchange overlaps the
# interior update via async collective-permute — DESIGN.md §3)
import jax
import jax.numpy as jnp
from repro.kernels.stencil import jacobi_sweep, jacobi_sweep_ref

g = jnp.asarray(np.random.default_rng(0).random((256, 256)), jnp.float32)
fused = jacobi_sweep(g, band=64)          # Pallas kernel (interpret=True)
ref = jacobi_sweep_ref(g)                  # 5-view jnp chain (paper's form)
print(f"\nPallas fused-sweep kernel matches the 5-view reference: "
      f"{bool(jnp.allclose(fused, ref, atol=1e-6))}")
