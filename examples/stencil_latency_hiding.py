"""The paper's flagship experiment (figs. 10/18), runnable end-to-end:
the Jacobi stencil with latency-hiding vs blocking communication, plus
the beyond-paper fused (§7) variant and the TPU shard_map mapping.

The stencil is written against the plain NumPy programming model — the
paper's whole point: slicing, arithmetic and ``np.asarray`` readback on
DistArrays, no repro-specific operation names.  Execution is swept
declaratively through ``ExecutionPolicy`` objects, with compute
backends and transfer channels resolved exclusively through the plugin
registry (``repro.available_backends()``).

    PYTHONPATH=src python examples/stencil_latency_hiding.py

Readback sync is demand-driven by default under the measured backend
(every ``np.asarray`` forces only its dependency cone);
``REPRO_SYNC=demand|barrier`` pins it for every policy below.
"""
import os

import jax
import numpy as np

# float64 end to end, so the jitted JAX backend is bit-identical to the
# eager NumPy interpreter on this elementwise program
jax.config.update("jax_enable_x64", True)

import repro
from repro.api import ExecutionPolicy, RuntimeConfig, format_stats

SYNC = os.environ.get("REPRO_SYNC", "auto")
N, ITERS = 1024, 6


def jacobi_stencil(n: int, iters: int) -> np.ndarray:
    """Figs. 10/18 written exactly like the sequential NumPy code."""
    full = repro.zeros((n + 2, n + 2))
    full[0, :] = 1.0
    full[:, 0] = 1.0
    for _ in range(iters):
        full[1:-1, 1:-1] = 0.2 * (
            full[1:-1, 1:-1]
            + full[0:-2, 1:-1]
            + full[2:, 1:-1]
            + full[1:-1, 0:-2]
            + full[1:-1, 2:]
        )
    return np.asarray(full)  # readback triggers the flush


def run(config: repro.RuntimeConfig, policy: ExecutionPolicy, n: int, iters: int):
    with repro.runtime(config, policy) as rt:
        result = jacobi_stencil(n, iters)
        return rt.stats(), result


# --- simulated: the paper's table (16 processes, GbE cluster model) ------
print(f"Jacobi stencil {N}x{N}, {ITERS} sweeps, 16 processes "
      f"(paper fig. 18 setup)\n")

cfg = RuntimeConfig(nprocs=16, block_size=128)
lh = ExecutionPolicy(scheduler="latency_hiding", sync=SYNC)

st_lh, r_lh = run(cfg, lh, N, ITERS)
st_bl, r_bl = run(cfg, lh.replace(scheduler="blocking"), N, ITERS)
st_fu, r_fu = run(cfg.replace(fusion=True), lh, N, ITERS)
np.testing.assert_array_equal(r_lh, r_bl)
np.testing.assert_allclose(r_lh, r_fu)

print(format_stats([
    ("blocking (baseline)", st_bl),
    ("latency-hiding (paper)", st_lh),
    ("LH + fusion (§7, ours)", st_fu),
]))
print(f"\nlatency-hiding wall-clock win: {st_bl.makespan/st_lh.makespan:.2f}x "
      f"(paper: 18.4/7.7 = 2.4x at 16 cores)")

# --- the same program, executed for real (repro.exec) -------------------
# flush="async" drains the identical dependency graphs on worker
# threads: transfers go through a non-blocking progress engine (overlap
# on) or a synchronous channel (overlap off), with 10 ms of wire latency
# injected per message so there is real latency to hide.  The wait%
# here is MEASURED on the wall clock; the simulated rows model the same
# α, rendered in the same table by format_stats.  Both registered
# compute backends drain the same graphs and must agree bit-for-bit
# (float64 everywhere, elementwise IEEE ops).
#
# The async flush runs the record→plan→execute pipeline: with the
# default passes="auto", transfers are coalesced into fewer, larger
# messages and worker handoffs are batched — visible in the dispatch:
# lines below (handoffs/flush, msgs/flush), and bit-identical to the
# passes-off drain by the plan-stage ordering contract.
MN, MITERS, MPROCS, ALPHA = 256, 4, 8, 10e-3
mcfg = RuntimeConfig(nprocs=MPROCS, block_size=64)
measured = ExecutionPolicy(flush="async", channel="async", latency=ALPHA,
                           sync=SYNC)
sim_alpha = ExecutionPolicy(
    cluster=repro.GIGE_2012.replace(alpha=ALPHA, name="gige-alpha-10ms"),
    sync=SYNC,
)

st_sim_on, _ = run(mcfg, sim_alpha, MN, MITERS)
st_sim_off, _ = run(mcfg, sim_alpha.replace(scheduler="blocking"), MN, MITERS)

backends = [b for b in repro.available_backends() if b in ("numpy", "jax")]
reference = None
for backend in backends:
    st_on, r_on = run(mcfg, measured.replace(backend=backend), MN, MITERS)
    st_off, r_off = run(
        mcfg, measured.replace(backend=backend, channel="blocking"), MN, MITERS
    )
    np.testing.assert_array_equal(r_on, r_off)
    if reference is None:
        reference = r_on
    np.testing.assert_array_equal(r_on, reference)  # backends agree bit-for-bit

    print(f"\nmeasured vs simulated ({MN}x{MN}, {MPROCS} workers, "
          f"backend={backend!r}):")
    print(format_stats([
        ("overlap ON  (async)", st_on),
        ("overlap OFF (blocking)", st_off),
        ("latency-hiding (model)", st_sim_on),
        ("blocking (model)", st_sim_off),
    ]))
    print(f"measured overlap win: {st_off.makespan/st_on.makespan:.2f}x")

# plan-stage sweep: the same drain without any graph pass must be
# bit-identical — the passes only change WHEN data moves, never what it is
st_plan, r_plan = run(mcfg, measured, MN, MITERS)
st_nop, r_nop = run(mcfg, measured.replace(passes=()), MN, MITERS)
np.testing.assert_array_equal(r_plan, r_nop)
print(f"\nplan-stage dispatch win (passes='auto' vs none, bit-identical): "
      f"handoffs {st_nop.n_handoffs} -> {st_plan.n_handoffs}, "
      f"messages {st_nop.n_messages} -> {st_plan.n_messages}")

# --- traced run: Perfetto export + wait attribution ----------------------
# REPRO_TRACE=1 re-runs the flagship measured config under a live
# collector, exports Chrome-trace JSON (load it at https://ui.perfetto.dev),
# and cross-checks the trace against the measured stats: the
# trace-derived wait fraction must agree with WaitStats.wait_fraction
# within 2 points, and attribution must name the halo-exchange
# transfers as the top worker-wait source.  REPRO_TRACE=<path> picks the
# export path (default stencil_trace.json).
TRACE = os.environ.get("REPRO_TRACE", "")
if TRACE not in ("", "0", "false", "False"):
    from repro.obs import attribution, export_trace, validate_trace

    with repro.trace() as tr:
        st_tr, r_tr = run(mcfg, measured, MN, MITERS)
    np.testing.assert_array_equal(r_tr, reference)
    path = TRACE if TRACE not in ("1", "true", "True") else "stencil_trace.json"
    export_trace(tr, path)
    info = validate_trace(path)
    print(f"\ntrace: {info['n_events']} events -> {path} "
          f"(open in https://ui.perfetto.dev)")

    rep = attribution(tr)
    print(rep.format(5))
    delta = abs(rep.wait_fraction - st_tr.wait_fraction)
    print(f"wait fraction: trace {rep.wait_fraction * 100:.1f}% vs "
          f"measured {st_tr.wait_fraction * 100:.1f}% (|delta| "
          f"{delta * 100:.2f} points)")
    assert delta < 0.02, (
        f"trace-derived wait fraction diverged {delta * 100:.2f} points "
        f"from the measured WaitStats"
    )
    worker_offenders = [
        o for o in rep.offenders
        if not o["group"].startswith("flush#") and o["group"] != "(end of trace)"
    ]
    assert worker_offenders and worker_offenders[0]["group"].startswith("xfer"), (
        f"expected the halo-exchange transfers as top wait source, got "
        f"{[o['group'] for o in worker_offenders[:3]]}"
    )
    print("attribution names the halo-exchange transfers as top wait source ✓")

# --- the same schedule as a compiled TPU/XLA program --------------------
# (runs on CPU here; on a TPU pod the ppermute halo exchange overlaps the
# interior update via async collective-permute — DESIGN.md §3)
import jax.numpy as jnp
from repro.kernels.stencil import jacobi_sweep, jacobi_sweep_ref

g = jnp.asarray(np.random.default_rng(0).random((256, 256)), jnp.float32)
fused = jacobi_sweep(g, band=64)          # Pallas kernel (interpret=True)
ref = jacobi_sweep_ref(g)                  # 5-view jnp chain (paper's form)
print(f"\nPallas fused-sweep kernel matches the 5-view reference: "
      f"{bool(jnp.allclose(fused, ref, atol=1e-6))}")
