"""Quickstart: the paper's programming model in 30 lines.

A sequential-looking NumPy program runs distributed with automatic
latency-hiding — the only API difference from NumPy is creation-time
(`Runtime` context here; `dist=True` in DistNumPy).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Runtime
from repro.core import darray as dnp

# 16 virtual processes, paper-calibrated GbE cluster model
with Runtime(nprocs=16, block_size=64, mode="latency_hiding") as rt:
    # --- plain NumPy-looking code ---------------------------------------
    a = dnp.array(np.linspace(0.0, 1.0, 256 * 256).reshape(256, 256))
    b = dnp.ones((256, 256))
    c = dnp.sqrt(a * a + b) / 2.0          # elementwise, auto-parallel
    d = dnp.matmul(c, c, trans_b=True)     # distributed blocked matmul
    col_sums = d.sum(axis=0)               # distributed reduction
    result = np.asarray(col_sums)          # readback triggers the flush
    stats = rt.stats()

oracle_c = np.sqrt(
    np.linspace(0.0, 1.0, 256 * 256).reshape(256, 256) ** 2 + 1.0
) / 2.0
oracle = (oracle_c @ oracle_c.T).sum(axis=0)
np.testing.assert_allclose(result, oracle, rtol=1e-10)

print("matches NumPy oracle ✓")
print(f"schedule: {stats.summary()}")
print(f"waiting-on-comm share: {stats.wait_fraction * 100:.1f}%")
