"""Quickstart: the paper's programming model in 30 lines.

A sequential-looking NumPy program runs distributed with automatic
latency-hiding — the program below uses only the NumPy namespace on
distributed arrays (the paper's only API delta is creation time:
``repro.array`` / ``repro.ones`` inside a ``repro.runtime`` context).

Readback is demand-driven: ``np.asarray(x)`` / ``repro.gather(x)``
force only ``x``'s dependency cone (under ``sync="demand"``; the
default resolves per flush backend, and ``REPRO_SYNC=demand|barrier``
overrides it here).

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import numpy as np

import repro

SYNC = os.environ.get("REPRO_SYNC", "auto")

# 16 virtual processes, paper-calibrated GbE cluster model
with repro.runtime(nprocs=16, block_size=64, sync=SYNC) as rt:
    # --- plain NumPy code -----------------------------------------------
    a = repro.array(np.linspace(0.0, 1.0, 256 * 256).reshape(256, 256))
    b = repro.ones((256, 256))
    c = np.sqrt(a * a + b) / 2.0           # elementwise, auto-parallel
    d = np.matmul(c, c)                    # distributed blocked matmul
    col_sums = np.sum(d, axis=0)           # distributed reduction
    fut = repro.evaluate(col_sums)         # start draining its cone (async)
    result = fut.result()                  # block + gather the ndarray
    stats = rt.stats()

oracle_c = np.sqrt(
    np.linspace(0.0, 1.0, 256 * 256).reshape(256, 256) ** 2 + 1.0
) / 2.0
oracle = (oracle_c @ oracle_c).sum(axis=0)
np.testing.assert_allclose(result, oracle, rtol=1e-10)

print(f"matches NumPy oracle ✓ (sync={SYNC!r})")
print(repro.format_stats([("quickstart", stats)]))
print(f"waiting-on-comm share: {stats.wait_fraction * 100:.1f}%")

# REPRO_TRACE=1 makes the runtime collect lifecycle events (the env var
# is read by Runtime itself); export them for https://ui.perfetto.dev
if rt.tracer is not None and rt.trace_path is None:
    from repro.obs import export_trace

    export_trace(rt.tracer, "quickstart_trace.json")
    print(f"trace: {rt.tracer.n_emitted} events -> quickstart_trace.json")
