"""Two synthetic tenants served by one shared runtime.

    PYTHONPATH=src python examples/serve_lm.py

Each tenant thread submits halo-exchange stencil requests to a shared
:class:`repro.serve.Server`; their dependency cones are disjoint, so
the requests drain *concurrently* on one work-stealing worker pool
while staying bit-identical to a serialized execution.  Prints the
per-tenant wait% and p50/p95/p99 request latency via
``repro.format_stats``.
"""
from repro.launch.serve import serve

stats = serve(tenants=2, requests=8)
for name, st in stats.items():
    assert st.n_requests == 8 and st.n_failed == 0, (name, st)
    assert st.latency.count == 8, (name, st.latency)
print("two tenants served, results verified ✓")
