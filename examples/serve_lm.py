"""Serve a small model with batched requests: prefill + decode loop,
exercising every cache type (GQA ring/linear, MLA latent, SSM, wkv).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve

for arch in ("granite-3-8b", "deepseek-v2-lite-16b", "zamba2-2.7b", "rwkv6-3b"):
    serve(arch, reduced=True, batch=2, prompt_len=16, gen=16)
print("all families served ✓")
