"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
CPU with the real pipeline (data → microbatched train_step → async
checkpoints → restore).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="granite-3-8b")
args = ap.parse_args()

with tempfile.TemporaryDirectory() as d:
    losses = train(
        args.arch,
        reduced=True,          # ~small config of the same family on CPU
        steps=args.steps,
        seq_len=128,
        global_batch=8,
        ckpt_dir=d,
        ckpt_every=50,
    )
assert losses[-1] < losses[0], "training must reduce the loss"
print("loss decreased ✓")
