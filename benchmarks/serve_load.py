"""Multi-tenant serving load benchmark: the concurrency acceptance gate.

    PYTHONPATH=src python -m benchmarks.serve_load --clients 8 --requests 64
    PYTHONPATH=src python -m benchmarks.serve_load --clients 200 --requests 2000

Each client is one tenant: a closed-loop thread that records a small
halo-exchange stencil request against the shared
:class:`repro.serve.Server`, waits for the result, verifies it, and
submits the next.  Every request's cone touches only that tenant's
arrays, so the cones are pairwise disjoint — the workload the serving
runtime exists for.

The same request stream runs twice on the async backend with injected
wire latency (``--latency``):

* **serialized** — ``ServeConfig(max_inflight=1)``: one cone in flight,
  every other admitted request queues.  This is the pre-subsystem
  behaviour (each readback a lone drain) expressed through the same code
  path, so the comparison isolates concurrency, not overheads.
* **concurrent** — ``max_inflight=--inflight`` (default ``min(clients,
  16)``): disjoint cones drain together on the shared work-stealing
  pool, overlapping each other's wire waits.

Gates (exit non-zero on any failure):

1. **Correctness** — every per-tenant result bit-identical to the NumPy
   closed form AND to a barrier-flush reference (one whole-graph
   ``Runtime.flush()`` per tenant): zero cross-tenant corruption.
2. **Throughput** — with ≥ 8 clients, the concurrent variant must beat
   the serialized one by ≥ ``--min-speedup`` (default 1.5×) aggregate
   throughput.
3. **Tail latency** — concurrent p99 must stay under the calibrated
   budget ``--p99-factor × mean`` (self-calibrating: overload shows up
   as a fat tail relative to the run's own mean, machine speed does
   not).

Writes ``results/BENCH_serve_load.json`` (rendered by
``benchmarks.make_report``).
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np


def tenant_host(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((n, n))


def stencil_request(host):
    """Build one request: a 5-point-ish stencil step over the tenant's
    array — the rolls force halo-exchange communication, which is what
    the injected wire latency makes expensive."""
    import repro

    def fn():
        a = repro.array(host)
        b = (np.roll(a, 1, axis=0) + np.roll(a, -1, axis=0)
             + np.roll(a, 1, axis=1) + np.roll(a, -1, axis=1)) * 0.25
        return b - a * 0.5
    return fn


def stencil_expected(host: np.ndarray) -> np.ndarray:
    return (np.roll(host, 1, axis=0) + np.roll(host, -1, axis=0)
            + np.roll(host, 1, axis=1) + np.roll(host, -1, axis=1)) * 0.25 \
        - host * 0.5


def barrier_reference(host: np.ndarray, nprocs: int, block: int) -> np.ndarray:
    """The same request through a lone runtime with ONE whole-graph
    barrier flush — the bit-identity reference for the served results."""
    import repro

    with repro.runtime(nprocs=nprocs, block_size=block, flush="async") as rt:
        a = repro.array(host)
        b = (np.roll(a, 1, axis=0) + np.roll(a, -1, axis=0)
             + np.roll(a, 1, axis=1) + np.roll(a, -1, axis=1)) * 0.25
        out = b - a * 0.5
        rt.flush()  # explicit barrier: every recorded op in one drain
        return np.asarray(out)


def run_variant(label, args, max_inflight):
    """Drive ``--clients`` closed-loop tenant threads against one Server;
    returns (result dict, corruption count)."""
    import repro

    per_client = max(1, args.requests // args.clients)
    srv = repro.Server(
        nprocs=args.nprocs,
        block_size=args.block,
        latency=args.latency,
        max_inflight=max_inflight,
        # closed-loop clients all park in admission when inflight is
        # capped; the queue must hold them all or the gate would measure
        # shedding, not throughput
        max_queue=args.clients,
    )
    corrupt = [0]
    errors = []

    def client(idx: int):
        host = tenant_host(1000 + idx, args.n)
        expect = stencil_expected(host)
        fn = stencil_request(host)
        sess = srv.session(f"tenant-{idx:03d}")
        try:
            for _ in range(per_client):
                got = sess.request(fn).result()
                if not np.array_equal(got, expect):
                    corrupt[0] += 1
        except BaseException as exc:  # noqa: BLE001 - reported below
            errors.append((idx, exc))

    threads = [
        threading.Thread(target=client, args=(i,), name=f"client-{i}")
        for i in range(args.clients)
    ]
    t0 = time.perf_counter()
    with srv:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        if errors:
            idx, exc = errors[0]
            raise RuntimeError(
                f"{label}: client {idx} failed ({len(errors)} total)"
            ) from exc
        # aggregate latency across tenants (histograms merge exactly)
        from repro.serve import LatencyHistogram

        hist = LatencyHistogram()
        n_rejected = n_failed = 0
        for st in srv.stats().values():
            hist.merge(st.latency)
            n_rejected += st.n_rejected
            n_failed += st.n_failed
        adm = srv.admission
        total = args.clients * per_client
        result = {
            "label": label,
            "max_inflight": max_inflight,
            "n_requests": total,
            "elapsed_s": elapsed,
            "throughput_rps": total / elapsed if elapsed > 0 else 0.0,
            "latency_mean_s": hist.mean,
            "latency_p50_s": hist.p50,
            "latency_p95_s": hist.p95,
            "latency_p99_s": hist.p99,
            "latency_max_s": hist.max,
            "n_rejected": n_rejected,
            "n_failed": n_failed,
            "peak_inflight": adm.peak_inflight,
            "peak_queued": adm.peak_queued,
        }
    return result, corrupt[0]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8,
                    help="tenant threads (closed loop)")
    ap.add_argument("--requests", type=int, default=64,
                    help="total requests across all clients")
    ap.add_argument("--inflight", type=int, default=0,
                    help="max in-flight cones for the concurrent variant "
                         "(0 = min(clients, 16))")
    ap.add_argument("--nprocs", type=int, default=4)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--n", type=int, default=32,
                    help="per-tenant array side (n x n)")
    ap.add_argument("--latency", type=float, default=12e-3,
                    help="injected wire latency (s/message); must dominate "
                         "the per-request record+plan cost (~6 ms of Python "
                         "under the record lock) for concurrency to show")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="required concurrent/serialized throughput ratio "
                         "(enforced at >= 8 clients)")
    ap.add_argument("--p99-factor", type=float, default=8.0,
                    help="p99 budget as a multiple of the run's own mean")
    ap.add_argument("--out", default="results/BENCH_serve_load.json")
    args = ap.parse_args()

    inflight = args.inflight or min(args.clients, 16)
    print(f"== serve load: {args.clients} clients, "
          f"~{args.requests} requests, {args.nprocs} procs, "
          f"alpha={args.latency * 1e3:.1f} ms ==")

    print("  barrier reference (bit-identity check, 1 tenant/flush)...")
    for idx in (0, args.clients - 1):
        host = tenant_host(1000 + idx, args.n)
        ref = barrier_reference(host, args.nprocs, args.block)
        assert np.array_equal(ref, stencil_expected(host)), (
            "barrier-flush reference diverged from the NumPy closed form — "
            "served results below are checked against the same expectation"
        )

    ser, corrupt_s = run_variant("serialized", args, max_inflight=1)
    con, corrupt_c = run_variant("concurrent", args, max_inflight=inflight)

    for r in (ser, con):
        print(f"  {r['label']:<11s} inflight<={r['max_inflight']:<3d} "
              f"{r['elapsed_s'] * 1e3:8.1f} ms  "
              f"{r['throughput_rps']:8.1f} req/s  "
              f"p50={r['latency_p50_s'] * 1e3:7.2f} ms  "
              f"p99={r['latency_p99_s'] * 1e3:7.2f} ms  "
              f"(peak inflight {r['peak_inflight']}, "
              f"queued {r['peak_queued']})")

    speedup = (con["throughput_rps"] / ser["throughput_rps"]
               if ser["throughput_rps"] > 0 else 0.0)
    budget = args.p99_factor * con["latency_mean_s"]
    print(f"  speedup: {speedup:.2f}x aggregate throughput "
          f"(gate >= {args.min_speedup}x at >= 8 clients)")
    print(f"  p99 budget: {con['latency_p99_s'] * 1e3:.2f} ms vs "
          f"{budget * 1e3:.2f} ms ({args.p99_factor:.0f}x mean)")

    assert corrupt_s == 0 and corrupt_c == 0, (
        f"cross-tenant corruption: {corrupt_s} serialized / "
        f"{corrupt_c} concurrent results differ from the tenant's own "
        f"closed form"
    )
    assert ser["n_rejected"] == 0 and con["n_rejected"] == 0, (
        "admission shed requests despite max_queue=clients — the gate "
        "would measure shedding, not throughput"
    )
    if args.clients >= 8:
        assert speedup >= args.min_speedup, (
            f"concurrent drains only {speedup:.2f}x the serialized "
            f"throughput (required >= {args.min_speedup}x)"
        )
    assert con["latency_p99_s"] <= budget, (
        f"concurrent p99 {con['latency_p99_s'] * 1e3:.2f} ms exceeds the "
        f"calibrated budget {budget * 1e3:.2f} ms "
        f"({args.p99_factor:.0f}x mean)"
    )

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "section": "serve-load",
        "clients": args.clients,
        "requests": args.requests,
        "nprocs": args.nprocs,
        "block": args.block,
        "n": args.n,
        "latency_s": args.latency,
        "speedup": speedup,
        "p99_budget_s": budget,
        "corruption": corrupt_s + corrupt_c,
        "variants": {r["label"]: r for r in (ser, con)},
    }, indent=2))
    print(f"  wrote {out}")
    print("serve-load: OK")


if __name__ == "__main__":
    main()
