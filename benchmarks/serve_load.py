"""Multi-tenant serving load benchmark: the concurrency acceptance gate.

    PYTHONPATH=src python -m benchmarks.serve_load --clients 8 --requests 64
    PYTHONPATH=src python -m benchmarks.serve_load --clients 200 --requests 2000

Each client is one tenant: a closed-loop thread that records a small
halo-exchange stencil request against the shared
:class:`repro.serve.Server`, waits for the result, verifies it, and
submits the next.  Every request's cone touches only that tenant's
arrays, so the cones are pairwise disjoint — the workload the serving
runtime exists for.

The same request stream runs twice on the async backend with injected
wire latency (``--latency``):

* **serialized** — ``ServeConfig(max_inflight=1)``: one cone in flight,
  every other admitted request queues.  This is the pre-subsystem
  behaviour (each readback a lone drain) expressed through the same code
  path, so the comparison isolates concurrency, not overheads.
* **concurrent** — ``max_inflight=--inflight`` (default ``min(clients,
  16)``): disjoint cones drain together on the shared work-stealing
  pool, overlapping each other's wire waits.

Gates (exit non-zero on any failure):

1. **Correctness** — every per-tenant result bit-identical to the NumPy
   closed form AND to a barrier-flush reference (one whole-graph
   ``Runtime.flush()`` per tenant): zero cross-tenant corruption.
2. **Throughput** — with ≥ 8 clients, the concurrent variant must beat
   the serialized one by ≥ ``--min-speedup`` (default 1.5×) aggregate
   throughput.
3. **Tail latency** — concurrent p99 must stay under the calibrated
   budget ``--p99-factor × mean`` (self-calibrating: overload shows up
   as a fat tail relative to the run's own mean, machine speed does
   not).

Writes ``results/BENCH_serve_load.json`` (rendered by
``benchmarks.make_report``).
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np


def tenant_host(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((n, n))


def stencil_request(host):
    """Build one request: a 5-point-ish stencil step over the tenant's
    array — the rolls force halo-exchange communication, which is what
    the injected wire latency makes expensive."""
    import repro

    def fn():
        a = repro.array(host)
        b = (np.roll(a, 1, axis=0) + np.roll(a, -1, axis=0)
             + np.roll(a, 1, axis=1) + np.roll(a, -1, axis=1)) * 0.25
        return b - a * 0.5
    return fn


def stencil_expected(host: np.ndarray) -> np.ndarray:
    return (np.roll(host, 1, axis=0) + np.roll(host, -1, axis=0)
            + np.roll(host, 1, axis=1) + np.roll(host, -1, axis=1)) * 0.25 \
        - host * 0.5


def barrier_reference(host: np.ndarray, nprocs: int, block: int) -> np.ndarray:
    """The same request through a lone runtime with ONE whole-graph
    barrier flush — the bit-identity reference for the served results."""
    import repro

    with repro.runtime(nprocs=nprocs, block_size=block, flush="async") as rt:
        a = repro.array(host)
        b = (np.roll(a, 1, axis=0) + np.roll(a, -1, axis=0)
             + np.roll(a, 1, axis=1) + np.roll(a, -1, axis=1)) * 0.25
        out = b - a * 0.5
        rt.flush()  # explicit barrier: every recorded op in one drain
        return np.asarray(out)


def run_variant(label, args, max_inflight, plan_cache=None,
                batch_cones=False, verify="off"):
    """Drive ``--clients`` closed-loop tenant threads against one Server;
    returns (result dict, corruption count)."""
    import repro
    from repro.serve import LatencyHistogram

    per_client = max(1, args.requests // args.clients)
    srv = repro.Server(
        nprocs=args.nprocs,
        block_size=args.block,
        latency=args.latency,
        max_inflight=max_inflight,
        # closed-loop clients all park in admission when inflight is
        # capped; the queue must hold them all or the gate would measure
        # shedding, not throughput
        max_queue=args.clients,
        plan_cache=plan_cache,
        batch_cones=batch_cones,
        verify=verify,
    )
    corrupt = [0]
    errors = []
    # client-side record→submit cost (admission + lock wait + record +
    # extract + plan + submit; everything but the drain wait) — the
    # denominator of the lock-hold reduction gate
    submit_hist = LatencyHistogram()
    submit_lock = threading.Lock()

    def client(idx: int):
        host = tenant_host(1000 + idx, args.n)
        expect = stencil_expected(host)
        fn = stencil_request(host)
        sess = srv.session(f"tenant-{idx:03d}")
        try:
            for _ in range(per_client):
                t0 = time.perf_counter()
                req = sess.request(fn)
                dt = time.perf_counter() - t0
                with submit_lock:
                    submit_hist.record(dt)
                got = req.result()
                if not np.array_equal(got, expect):
                    corrupt[0] += 1
        except BaseException as exc:  # noqa: BLE001 - reported below
            errors.append((idx, exc))

    threads = [
        threading.Thread(target=client, args=(i,), name=f"client-{i}")
        for i in range(args.clients)
    ]
    t0 = time.perf_counter()
    with srv:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        if errors:
            idx, exc = errors[0]
            raise RuntimeError(
                f"{label}: client {idx} failed ({len(errors)} total)"
            ) from exc
        # aggregate latency across tenants (histograms merge exactly)
        hist = LatencyHistogram()
        n_rejected = n_failed = 0
        for st in srv.stats().values():
            hist.merge(st.latency)
            n_rejected += st.n_rejected
            n_failed += st.n_failed
        adm = srv.admission
        total = args.clients * per_client
        result = {
            "label": label,
            "max_inflight": max_inflight,
            "n_requests": total,
            "elapsed_s": elapsed,
            "throughput_rps": total / elapsed if elapsed > 0 else 0.0,
            "latency_mean_s": hist.mean,
            "latency_p50_s": hist.p50,
            "latency_p95_s": hist.p95,
            "latency_p99_s": hist.p99,
            "latency_max_s": hist.max,
            "n_rejected": n_rejected,
            "n_failed": n_failed,
            "peak_inflight": adm.peak_inflight,
            "peak_queued": adm.peak_queued,
            # off-lock planning accounting: the record lock covers only
            # record + cone extraction; plan/verify/submit run after it
            "lock_hold_p50_s": srv.lock_hold.quantile(0.5),
            "lock_hold_p99_s": srv.lock_hold.quantile(0.99),
            "lock_hold_mean_s": srv.lock_hold.mean,
            # server-measured off-lock plan+verify+submit time: no
            # admission or lock *wait* in either number, so
            # (lock_hold + plan) / lock_hold is exactly the hold
            # reduction vs planning under the lock
            "plan_mean_s": srv.plan_time.mean,
            "plan_p50_s": srv.plan_time.quantile(0.5),
            "submit_p50_s": submit_hist.quantile(0.5),
            "submit_mean_s": submit_hist.mean,
        }
        cache = srv.runtime._plan_cache
        if cache is not None:
            result["plan_cache"] = {
                "hits": cache.hits,
                "misses": cache.misses,
                "uncacheable": cache.n_uncacheable,
                "hit_rate": cache.hit_rate,
                "resident": len(cache),
            }
            # graph-lint the resident recipes: every cached plan must
            # still prove clean under the static verifier
            reports = srv.runtime.verify_cached_plans()
            result["cached_plan_diagnostics"] = sum(
                len(rep.diagnostics) for rep in reports
            )
        batcher = getattr(srv.runtime, "_batcher", None)
        if batcher is not None:
            result["batcher"] = {
                "n_batches": batcher.n_batches,
                "n_merged": batcher.n_merged,
            }
    return result, corrupt[0]


def run_plan_cache_suite(args) -> None:
    """Repeated-shape workload: every tenant records the *same* request
    structure (different data, same canonical cone shape), so the
    plan-shape cache should hit on every request after each shape's cold
    plan.  Three variants — serialized baseline, concurrent with the
    cache off, concurrent with cache + cone batching — gated on:

    1. zero corruption (as ever);
    2. concurrent+cache ≥ ``--min-speedup`` × serialized throughput at
       ≥ 8 clients;
    3. cache hit rate ≥ ``--min-hit-rate`` after warmup;
    4. median record-lock hold ≤ ½ of the median record→submit cost on
       the cold-planning variant — i.e. off-lock planning at least
       halves what the on-lock design would have held;
    5. every resident cached recipe re-proves clean under the static
       plan verifier (graph-lint for cached plans).

    Writes ``results/BENCH_serve_plan_cache.json``.
    """
    inflight = args.inflight or min(args.clients, 16)
    print(f"== serve plan-cache: {args.clients} clients, "
          f"~{args.requests} requests (repeated shape), "
          f"{args.nprocs} procs, alpha={args.latency * 1e3:.1f} ms ==")

    ser, c_ser = run_variant("serialized", args, max_inflight=1,
                             plan_cache=False, verify="plan")
    cold, c_cold = run_variant("concurrent-nocache", args,
                               max_inflight=inflight, plan_cache=False,
                               verify="plan")
    warm, c_warm = run_variant("concurrent-cache", args,
                               max_inflight=inflight, plan_cache=True,
                               batch_cones=True, verify="plan")

    for r in (ser, cold, warm):
        pc = r.get("plan_cache")
        hit = f"hit={pc['hit_rate'] * 100:5.1f}%" if pc else "cache off  "
        print(f"  {r['label']:<18s} {r['throughput_rps']:8.1f} req/s  "
              f"p50={r['latency_p50_s'] * 1e3:7.2f} ms  {hit}  "
              f"lock={r['lock_hold_mean_s'] * 1e6:7.1f} us  "
              f"plan={r['plan_mean_s'] * 1e6:8.1f} us")

    speedup = (warm["throughput_rps"] / ser["throughput_rps"]
               if ser["throughput_rps"] > 0 else 0.0)
    cache_ratio = (warm["throughput_rps"] / cold["throughput_rps"]
                   if cold["throughput_rps"] > 0 else 0.0)
    # lock-hold reduction on the cold-planning variant, from the
    # server's own wait-free measurements with exact histogram means
    # (medians are log-bucket-quantized): an on-lock design would hold
    # the lock for record+extract+plan+submit; this one holds it for
    # record+extract only
    hold_ratio = (
        (cold["lock_hold_mean_s"] + cold["plan_mean_s"])
        / cold["lock_hold_mean_s"]
        if cold["lock_hold_mean_s"] > 0 else float("inf")
    )
    hit_rate = warm["plan_cache"]["hit_rate"]
    print(f"  concurrent+cache vs serialized: {speedup:.2f}x "
          f"(gate >= {args.min_speedup}x at >= 8 clients)")
    print(f"  cache on/off throughput: {cache_ratio:.2f}x; "
          f"hit rate {hit_rate * 100:.1f}% "
          f"(gate >= {args.min_hit_rate * 100:.0f}%)")
    print(f"  lock-hold reduction (lock+plan vs lock): {hold_ratio:.2f}x "
          f"(gate >= 2x: planning really runs off the lock)")

    assert c_ser == 0 and c_cold == 0 and c_warm == 0, (
        f"corruption: {c_ser}/{c_cold}/{c_warm} results diverged"
    )
    if args.clients >= 8:
        assert speedup >= args.min_speedup, (
            f"concurrent+cache only {speedup:.2f}x the serialized "
            f"throughput (required >= {args.min_speedup}x)"
        )
    assert hit_rate >= args.min_hit_rate, (
        f"plan-cache hit rate {hit_rate * 100:.1f}% below the "
        f"{args.min_hit_rate * 100:.0f}% gate on a repeated-shape "
        f"workload: {warm['plan_cache']}"
    )
    assert hold_ratio >= 2.0, (
        f"lock+plan is only {hold_ratio:.2f}x the lock hold — planning "
        f"off the lock shaves less than half the on-lock design's hold"
    )
    assert warm.get("cached_plan_diagnostics", 0) == 0, (
        "cached plan recipes failed re-verification"
    )

    out = Path(args.cache_out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "section": "serve-plan-cache",
        "clients": args.clients,
        "requests": args.requests,
        "nprocs": args.nprocs,
        "block": args.block,
        "n": args.n,
        "latency_s": args.latency,
        "speedup_vs_serialized": speedup,
        "cache_throughput_ratio": cache_ratio,
        "hit_rate": hit_rate,
        "lock_hold_reduction": hold_ratio,
        "corruption": c_ser + c_cold + c_warm,
        "variants": {r["label"]: r for r in (ser, cold, warm)},
    }, indent=2))
    print(f"  wrote {out}")
    print("serve-plan-cache: OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8,
                    help="tenant threads (closed loop)")
    ap.add_argument("--requests", type=int, default=64,
                    help="total requests across all clients")
    ap.add_argument("--inflight", type=int, default=0,
                    help="max in-flight cones for the concurrent variant "
                         "(0 = min(clients, 16))")
    ap.add_argument("--nprocs", type=int, default=4)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--n", type=int, default=32,
                    help="per-tenant array side (n x n)")
    ap.add_argument("--latency", type=float, default=12e-3,
                    help="injected wire latency (s/message); must dominate "
                         "the per-request record+plan cost (~6 ms of Python "
                         "under the record lock) for concurrency to show")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="required concurrent/serialized throughput ratio "
                         "(enforced at >= 8 clients)")
    ap.add_argument("--p99-factor", type=float, default=8.0,
                    help="p99 budget as a multiple of the run's own mean")
    ap.add_argument("--suite", choices=("load", "plan-cache", "all"),
                    default="load",
                    help="load = serialized-vs-concurrent gate; "
                         "plan-cache = repeated-shape workload gating the "
                         "plan-shape cache + off-lock planning")
    ap.add_argument("--min-hit-rate", type=float, default=0.9,
                    help="required plan-cache hit rate on the "
                         "repeated-shape workload")
    ap.add_argument("--out", default="results/BENCH_serve_load.json")
    ap.add_argument("--cache-out",
                    default="results/BENCH_serve_plan_cache.json")
    args = ap.parse_args()

    if args.suite in ("plan-cache", "all"):
        run_plan_cache_suite(args)
        if args.suite == "plan-cache":
            return

    inflight = args.inflight or min(args.clients, 16)
    print(f"== serve load: {args.clients} clients, "
          f"~{args.requests} requests, {args.nprocs} procs, "
          f"alpha={args.latency * 1e3:.1f} ms ==")

    print("  barrier reference (bit-identity check, 1 tenant/flush)...")
    for idx in (0, args.clients - 1):
        host = tenant_host(1000 + idx, args.n)
        ref = barrier_reference(host, args.nprocs, args.block)
        assert np.array_equal(ref, stencil_expected(host)), (
            "barrier-flush reference diverged from the NumPy closed form — "
            "served results below are checked against the same expectation"
        )

    ser, corrupt_s = run_variant("serialized", args, max_inflight=1)
    con, corrupt_c = run_variant("concurrent", args, max_inflight=inflight)

    for r in (ser, con):
        print(f"  {r['label']:<11s} inflight<={r['max_inflight']:<3d} "
              f"{r['elapsed_s'] * 1e3:8.1f} ms  "
              f"{r['throughput_rps']:8.1f} req/s  "
              f"p50={r['latency_p50_s'] * 1e3:7.2f} ms  "
              f"p99={r['latency_p99_s'] * 1e3:7.2f} ms  "
              f"(peak inflight {r['peak_inflight']}, "
              f"queued {r['peak_queued']})")

    speedup = (con["throughput_rps"] / ser["throughput_rps"]
               if ser["throughput_rps"] > 0 else 0.0)
    budget = args.p99_factor * con["latency_mean_s"]
    print(f"  speedup: {speedup:.2f}x aggregate throughput "
          f"(gate >= {args.min_speedup}x at >= 8 clients)")
    print(f"  p99 budget: {con['latency_p99_s'] * 1e3:.2f} ms vs "
          f"{budget * 1e3:.2f} ms ({args.p99_factor:.0f}x mean)")

    assert corrupt_s == 0 and corrupt_c == 0, (
        f"cross-tenant corruption: {corrupt_s} serialized / "
        f"{corrupt_c} concurrent results differ from the tenant's own "
        f"closed form"
    )
    assert ser["n_rejected"] == 0 and con["n_rejected"] == 0, (
        "admission shed requests despite max_queue=clients — the gate "
        "would measure shedding, not throughput"
    )
    if args.clients >= 8:
        assert speedup >= args.min_speedup, (
            f"concurrent drains only {speedup:.2f}x the serialized "
            f"throughput (required >= {args.min_speedup}x)"
        )
    assert con["latency_p99_s"] <= budget, (
        f"concurrent p99 {con['latency_p99_s'] * 1e3:.2f} ms exceeds the "
        f"calibrated budget {budget * 1e3:.2f} ms "
        f"({args.p99_factor:.0f}x mean)"
    )

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "section": "serve-load",
        "clients": args.clients,
        "requests": args.requests,
        "nprocs": args.nprocs,
        "block": args.block,
        "n": args.n,
        "latency_s": args.latency,
        "speedup": speedup,
        "p99_budget_s": budget,
        "corruption": corrupt_s + corrupt_c,
        "variants": {r["label"]: r for r in (ser, con)},
    }, indent=2))
    print(f"  wrote {out}")
    print("serve-load: OK")


if __name__ == "__main__":
    main()
