"""The paper's eight benchmark applications (§6, figs. 11–18) on the
DistNumPy-style runtime — each measured with latency-hiding vs blocking
communication, reporting the paper's two metrics: waiting-time share and
speedup vs sequential.

Every app is written in the DistArray API exactly the way the paper's
NumPy code is written (fig. 9/10) — no manual parallelism.  Sizes are
scaled to run the *real* block computation on one CPU in seconds; the
communication/computation timeline is accounted by the α–β cluster model
calibrated to the paper's testbed (16 nodes, GbE — core/timeline.py).
"""
from __future__ import annotations

import numpy as np

from repro.api import ExecutionPolicy, RuntimeConfig
from repro.core import Runtime
from repro.core import darray as dnp
from repro.core.timeline import GIGE_2012

__all__ = ["APPS", "run_app", "run_all"]


# ---------------------------------------------------------------------------
# applications
# ---------------------------------------------------------------------------


def fractal(n=1024, iters=20):
    """Mandelbrot set (fig. 11) — embarrassingly parallel."""
    xs = np.linspace(-2.0, 0.5, n)
    ys = np.linspace(-1.25, 1.25, n)
    cr = dnp.array(np.repeat(xs[None, :], n, axis=0))
    ci = dnp.array(np.repeat(ys[:, None], n, axis=1))
    zr = dnp.zeros((n, n))
    zi = dnp.zeros((n, n))
    count = dnp.zeros((n, n))
    for _ in range(iters):
        zr2 = zr * zr
        zi2 = zi * zi
        inside = dnp.less(zr2 + zi2, 4.0)
        count += inside
        nzr = zr2 - zi2 + cr
        nzi = 2.0 * (zr * zi) + ci
        zr = dnp.where(inside, nzr, zr)
        zi = dnp.where(inside, nzi, zi)
    return count


def black_scholes(n=2_000_000, iters=8):
    """Black–Scholes pricing (figs. 9/12) — embarrassingly parallel."""
    rng = np.random.default_rng(0)
    S = dnp.array(rng.uniform(5, 65, n))
    X = dnp.array(rng.uniform(5, 65, n))
    r, v = 0.08, 0.3

    def cnd(d):  # logistic approximation (same comm pattern as A&S poly)
        e = dnp.exp(-1.702 * d)
        return 1.0 / (1.0 + e)

    total = dnp.zeros(1)
    for i in range(1, iters + 1):
        T = i / iters
        d1 = (dnp.log(S / X) + (r + v * v / 2.0) * T) / (v * np.sqrt(T))
        d2 = d1 - v * np.sqrt(T)
        call = S * cnd(d1) - X * np.exp(-r * T) * cnd(d2)
        total += call.sum(keepdims=True) / n
    return total


def nbody(n=2048, steps=4):
    """Naive O(n²) Newtonian N-body (fig. 13).

    The pairwise matrices are built with SUMMA outer products; the force
    reduction uses broadcast-multiply + axis-sum, which the runtime
    executes as partial-reduce-at-owner + tiny partial transfers — the
    communication-avoiding form of the matvec (paper §6.1.1: the N-body
    matmuls are 'specialized operations')."""
    rng = np.random.default_rng(1)
    G, eps, dt = 6.674e-11, 1e-2, 0.1
    m_np = rng.uniform(1e5, 1e6, (n, 1))
    m = dnp.array(m_np)
    m_row = dnp.array(m_np.reshape(1, n))  # the transposed masses
    px = dnp.array(rng.uniform(0, 1e3, (n, 1)))
    py = dnp.array(rng.uniform(0, 1e3, (n, 1)))
    vx = dnp.zeros((n, 1))
    vy = dnp.zeros((n, 1))
    ones = dnp.ones((n, 1))

    def pairwise(a):
        A = dnp.matmul(a, ones, trans_b=True)  # [i, j] = a[i]
        At = dnp.matmul(ones, a, trans_b=True)  # [i, j] = a[j]
        return At - A

    for _ in range(steps):
        dx = pairwise(px)
        dy = pairwise(py)
        r2 = dx * dx + dy * dy + eps
        inv_r3 = r2 ** -1.5
        fx = G * m * (dx * inv_r3 * m_row).sum(axis=1, keepdims=True)
        fy = G * m * (dy * inv_r3 * m_row).sum(axis=1, keepdims=True)
        vx += dt * fx / m
        vy += dt * fy / m
        px += dt * vx
        py += dt * vy
    return px


def knn(n=4096, d=64):
    """Naive nearest-neighbour search (fig. 14) — O(n²) distances."""
    rng = np.random.default_rng(2)
    X = dnp.array(rng.random((n, d)))
    ones = dnp.ones((n, 1))
    G = dnp.matmul(X, X, trans_b=True)  # [n, n]
    sq = (X * X).sum(axis=1, keepdims=True)  # [n, 1]
    SQ = dnp.matmul(sq, ones, trans_b=True)  # row broadcast
    SQT = dnp.matmul(ones, sq, trans_b=True)  # col broadcast
    D = SQ + SQT - 2.0 * G
    big = dnp.ones((n, n)) * 1e18
    eye_mask = dnp.array(np.eye(n))
    D = dnp.where(eye_mask, big, D)
    return D.min(axis=1)


_D2Q9 = [(0, 0), (1, 0), (0, 1), (-1, 0), (0, -1), (1, 1), (-1, 1), (-1, -1), (1, -1)]
_W2 = [4 / 9] + [1 / 9] * 4 + [1 / 36] * 4


def lbm2d(h=512, w=512, steps=6):
    """D2Q9 lattice-Boltzmann channel flow (fig. 15)."""
    omega = 1.0
    f = [dnp.ones((h, w)) * wgt for wgt in _W2]
    for _ in range(steps):
        # streaming: roll each population along its lattice vector
        f = [
            dnp.roll(dnp.roll(fi, cy, axis=0), cx, axis=1)
            for fi, (cx, cy) in zip(f, _D2Q9)
        ]
        rho = f[0]
        for fi in f[1:]:
            rho = rho + fi
        ux = dnp.zeros((h, w))
        uy = dnp.zeros((h, w))
        for fi, (cx, cy) in zip(f, _D2Q9):
            if cx:
                ux = ux + float(cx) * fi
            if cy:
                uy = uy + float(cy) * fi
        ux = ux / rho
        uy = uy / rho
        usq = 1.5 * (ux * ux + uy * uy)
        for i, (cx, cy) in enumerate(_D2Q9):
            cu = 3.0 * (cx * ux + cy * uy)
            feq = _W2[i] * rho * (1.0 + cu + 0.5 * cu * cu - usq)
            f[i] = f[i] + omega * (feq - f[i])
    return f[0]


_D3Q19 = (
    [(0, 0, 0)]
    + [(s * a, s * b, s * c)
       for (a, b, c) in [(1, 0, 0), (0, 1, 0), (0, 0, 1)] for s in (1, -1)]
    + [(s1 * a1 + 0, 0, 0) for s1, a1 in []]  # placeholder
)
# full D3Q19 velocity set
_D3Q19 = [(0, 0, 0),
          (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1),
          (1, 1, 0), (-1, -1, 0), (1, -1, 0), (-1, 1, 0),
          (1, 0, 1), (-1, 0, -1), (1, 0, -1), (-1, 0, 1),
          (0, 1, 1), (0, -1, -1), (0, 1, -1), (0, -1, 1)]
_W3 = [1 / 3] + [1 / 18] * 6 + [1 / 36] * 12


def lbm3d(d=64, h=64, w=64, steps=4):
    """D3Q19 lattice-Boltzmann fluid (fig. 16)."""
    omega = 1.0
    f = [dnp.ones((d, h, w)) * wgt for wgt in _W3]
    for _ in range(steps):
        f = [
            dnp.roll(dnp.roll(dnp.roll(fi, cz, 0), cy, 1), cx, 2)
            for fi, (cx, cy, cz) in zip(f, _D3Q19)
        ]
        rho = f[0]
        for fi in f[1:]:
            rho = rho + fi
        ux = dnp.zeros((d, h, w))
        uy = dnp.zeros((d, h, w))
        uz = dnp.zeros((d, h, w))
        for fi, (cx, cy, cz) in zip(f, _D3Q19):
            if cx:
                ux = ux + float(cx) * fi
            if cy:
                uy = uy + float(cy) * fi
            if cz:
                uz = uz + float(cz) * fi
        ux, uy, uz = ux / rho, uy / rho, uz / rho
        usq = 1.5 * (ux * ux + uy * uy + uz * uz)
        for i, (cx, cy, cz) in enumerate(_D3Q19):
            cu = 3.0 * (cx * ux + cy * uy + cz * uz)
            feq = _W3[i] * rho * (1.0 + cu + 0.5 * cu * cu - usq)
            f[i] = f[i] + omega * (feq - f[i])
    return f[0]


def jacobi(n=2048, nrhs=2048, iters=6):
    """Jacobi iteration on systemS of linear equations (fig. 17): one
    [n,n] matmul per sweep over the nrhs right-hand sides (SUMMA)."""
    rng = np.random.default_rng(3)
    A = rng.random((n, n)) + n * np.eye(n)
    R_np = A - np.diag(np.diag(A))
    inv_d = (1.0 / np.diag(A)).reshape(n, 1)
    R = dnp.array(R_np)
    b = dnp.array(rng.random((n, nrhs)))
    invd = dnp.array(inv_d)
    x = dnp.zeros((n, nrhs))
    for _ in range(iters):
        x = (b - dnp.matmul(R, x)) * invd
    return x


def jacobi_stencil(n=4096, iters=6):
    """Jacobi with stencil views (figs. 10/18) — the paper's flagship."""
    full = dnp.zeros((n + 2, n + 2))
    full[0, :] = 1.0
    full[:, 0] = 1.0
    for _ in range(iters):
        work = 0.2 * (
            full[1:-1, 1:-1]
            + full[0:-2, 1:-1]
            + full[2:, 1:-1]
            + full[1:-1, 0:-2]
            + full[1:-1, 2:]
        )
        full[1:-1, 1:-1] = work
    return full


# app -> (fn, default kwargs, distribution block size).  Block sizes follow
# the paper: the array is split so there are ~4-16× more blocks than the
# 16 processes (strong scaling, §6.1.2); problem sizes chosen so the
# per-block compute sits in the paper's regime (ms-scale blocks).
APPS = {
    "fractal": (fractal, {}, 128),
    "black_scholes": (black_scholes, {}, 65536),
    "nbody": (nbody, {}, 256),
    "knn": (knn, {}, 512),
    "lbm2d": (lbm2d, {}, 64),
    "lbm3d": (lbm3d, {}, 16),
    "jacobi": (jacobi, {}, 256),
    "jacobi_stencil": (jacobi_stencil, {}, 512),
}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


_UNSET = object()


def run_app(
    name: str,
    *,
    mode=_UNSET,
    nprocs=_UNSET,
    block_size=_UNSET,
    execute=_UNSET,
    fusion=_UNSET,
    cluster=_UNSET,
    flush_backend=_UNSET,
    exec_backend=_UNSET,
    exec_channel=_UNSET,
    exec_latency=_UNSET,
    passes=_UNSET,
    config: RuntimeConfig = None,
    policy: ExecutionPolicy = None,
    **kw,
):
    """Run one paper app and return ``(stats, result)``.

    Preferred invocation passes a :class:`RuntimeConfig` /
    :class:`ExecutionPolicy` pair; the individual keyword arguments
    remain as shorthand and are folded into the config objects when no
    explicit object is given.  Mixing an explicit object with its
    shorthand kwargs is refused (the kwarg would be silently ignored).
    """
    fn, defaults, default_bs = APPS[name]
    kwargs = {**defaults, **kw}
    cfg_kw = dict(nprocs=nprocs, block_size=block_size, execute=execute,
                  fusion=fusion)
    pol_kw = dict(mode=mode, cluster=cluster, flush_backend=flush_backend,
                  exec_backend=exec_backend, exec_channel=exec_channel,
                  exec_latency=exec_latency, passes=passes)
    if config is None:
        bs = cfg_kw["block_size"]
        config = RuntimeConfig(
            nprocs=16 if cfg_kw["nprocs"] is _UNSET else cfg_kw["nprocs"],
            block_size=default_bs if bs in (_UNSET, None) else bs,
            fusion=False if cfg_kw["fusion"] is _UNSET else cfg_kw["fusion"],
            execute=True if cfg_kw["execute"] is _UNSET else cfg_kw["execute"],
        )
    else:
        clash = [k for k, v in cfg_kw.items() if v is not _UNSET]
        if clash:
            raise TypeError(
                f"run_app: got both config= and shorthand kwarg(s) {clash} — "
                f"put them on the RuntimeConfig"
            )
    if policy is None:
        policy = ExecutionPolicy(
            scheduler="latency_hiding" if mode is _UNSET else mode,
            flush="sim" if flush_backend is _UNSET else flush_backend,
            backend="numpy" if exec_backend is _UNSET else exec_backend,
            channel=None if exec_channel is _UNSET else exec_channel,
            latency=0.0 if exec_latency is _UNSET else exec_latency,
            cluster=GIGE_2012 if cluster is _UNSET else cluster,
            passes="auto" if passes is _UNSET else passes,
        )
    else:
        clash = [k for k, v in pol_kw.items() if v is not _UNSET]
        if clash:
            raise TypeError(
                f"run_app: got both policy= and shorthand kwarg(s) {clash} — "
                f"use policy.replace(...) instead"
            )
    with Runtime.from_config(config, policy) as rt:
        out = fn(**kwargs)
        result = np.asarray(out) if config.execute else None
        stats = rt.stats()
    return stats, result


def run_all(nprocs: int = 16, execute: bool = True, block_size=None):
    rows = []
    for name in APPS:
        st_lh, res_lh = run_app(name, mode="latency_hiding", nprocs=nprocs,
                                execute=execute, block_size=block_size)
        st_bl, res_bl = run_app(name, mode="blocking", nprocs=nprocs,
                                execute=execute, block_size=block_size)
        if execute and res_lh is not None:
            assert np.allclose(res_lh, res_bl, equal_nan=True), f"{name}: mode changes result!"
        rows.append(
            dict(
                app=name,
                wait_lh=st_lh.wait_fraction,
                wait_blocking=st_bl.wait_fraction,
                speedup_lh=st_lh.speedup,
                speedup_blocking=st_bl.speedup,
                makespan_lh=st_lh.makespan,
                makespan_blocking=st_bl.makespan,
                comm_mb=st_lh.comm_bytes / 1e6,
            )
        )
    return rows
