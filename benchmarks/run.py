"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--skip-apps] [--skip-roofline]

Sections:
  1. Paper §6 (figs. 11-18): the 8 applications, latency-hiding vs
     blocking — waiting-time % and speedup (the paper's two metrics),
     plus the beyond-paper fusion mode on the stencil apps.
  2. §5.7.2 dependency-system overhead: heuristic vs full DAG.
  3. Kernel microbenches (CSV: name,us_per_call,derived).
  4. Roofline table from the dry-run artifacts (if present).
  5. Real overlap: the stencil app on the repro.exec async executor —
     measured wall-clock wait% (overlap on vs off) next to the
     simulated wait% columns at the same injected α.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def section(title):
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}", flush=True)


def run_paper_apps(fast: bool):
    from benchmarks.paper_apps import APPS, run_app

    section("1. Paper §6 — 8 applications, latency-hiding vs blocking (16 procs)")
    small = dict(
        fractal=dict(n=256, iters=8),
        black_scholes=dict(n=200_000, iters=4),
        nbody=dict(n=384, steps=2),
        knn=dict(n=1024, d=32),
        lbm2d=dict(h=256, w=256, steps=3),
        lbm3d=dict(d=32, h=32, w=32, steps=2),
        jacobi=dict(n=512, iters=6),
        jacobi_stencil=dict(n=512, iters=6),
    )
    print(f"{'app':16s} {'wait% LH':>9s} {'wait% BL':>9s} {'spdup LH':>9s} "
          f"{'spdup BL':>9s} {'comm MB':>9s}  paper(16c)")
    paper = {
        "fractal": "wait ~0/~0, 18.8x",
        "black_scholes": "wait ~0/~0, 15.4x",
        "nbody": "17.2x LH vs 17.8x BL",
        "knn": "12.5x/12.6x",
        "lbm2d": "wait 13%/19%",
        "lbm3d": "wait 9%/16%",
        "jacobi": "wait 2%/54%, 12.8x/5.9x",
        "jacobi_stencil": "wait 9%/62%, 18.4x/7.7x",
    }
    rows = []
    import numpy as np

    for name in APPS:
        kw = small[name] if fast else {}
        st_lh, r_lh = run_app(name, mode="latency_hiding", **kw)
        st_bl, r_bl = run_app(name, mode="blocking", **kw)
        assert r_lh is None or np.allclose(r_lh, r_bl, equal_nan=True)
        rows.append(dict(app=name,
                         wait_lh=st_lh.wait_fraction, wait_bl=st_bl.wait_fraction,
                         sp_lh=st_lh.speedup, sp_bl=st_bl.speedup,
                         makespan_lh=st_lh.makespan,
                         comm_mb=st_lh.comm_bytes / 1e6))
        print(f"{name:16s} {st_lh.wait_fraction*100:8.1f}% {st_bl.wait_fraction*100:8.1f}% "
              f"{st_lh.speedup:9.2f} {st_bl.speedup:9.2f} {st_lh.comm_bytes/1e6:9.2f}  {paper[name]}")

    # beyond-paper: §7 ufunc fusion on the stencil app.  The honest metric
    # is the MAKESPAN ratio — fusion shrinks the sequential work (fewer
    # memory passes), so "speedup vs its own sequential" understates it.
    name = "jacobi_stencil"
    kw = small[name] if fast else {}
    st_fu, r_fu = run_app(name, mode="latency_hiding", fusion=True, **kw)
    _, r_plain = run_app(name, mode="latency_hiding", **kw)
    assert np.allclose(r_fu, r_plain)
    mk_u = rows[-1]["makespan_lh"]
    print(f"\n  fusion(beyond-paper) {name}: makespan {st_fu.makespan*1e3:.1f}ms "
          f"vs {mk_u*1e3:.1f}ms unfused ({mk_u/st_fu.makespan:.2f}x wall-clock) "
          f"wait {st_fu.wait_fraction*100:.1f}% ops {st_fu.n_compute_ops}c/{st_fu.n_comm_ops}m")
    return rows


def run_depsys(fast: bool):
    from benchmarks.depsys_overhead import rows

    section("2. §5.7.2 dependency-system overhead — heuristic vs full DAG")
    print(f"{'n_ops':>8s} {'heur us/op':>11s} {'dag us/op':>11s} {'heur scans':>11s} "
          f"{'dag scans':>11s} {'speedup':>8s}")
    for r in rows((500, 1000, 2000) if fast else (500, 1000, 2000, 4000, 8000)):
        print(f"{r['n_ops']:8d} {r['heuristic_us_per_op']:11.2f} {r['dag_us_per_op']:11.2f} "
              f"{r['heuristic_scans']:11d} {r['dag_scans']:11d} {r['speedup']:8.1f}")


def run_kernels():
    from benchmarks.kernel_bench import rows

    section("3. Kernel microbenches (name,us_per_call,derived)")
    for r in rows():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


def run_roofline(results_dir="results/dryrun"):
    section("4. Roofline table (from dry-run artifacts; cost-probe records "
            "preferred — see EXPERIMENTS.md §Roofline for the while-loop "
            "FLOP-undercount correction)")
    d = Path(results_dir)
    base, cost = {}, {}
    for f in sorted(d.glob("*.json")) if d.exists() else []:
        r = json.loads(f.read_text())
        key = (r["arch"], r["shape"], r["mesh"])
        tag = r.get("tag") or ""
        if tag == "cost":
            cost[key] = r
        elif tag == "":
            base[key] = r
    recs = [cost.get(k, v) for k, v in base.items()]
    if not recs:
        print("  (no dry-run artifacts found — run `python -m repro.launch.dryrun --all` first)")
        return
    print(f"{'arch':22s} {'shape':12s} {'mesh':10s} {'t_comp(s)':>10s} {'t_mem(s)':>10s} "
          f"{'t_coll(s)':>10s} {'dominant':>10s} {'useful':>7s}")
    for r in recs:
        if r["status"] == "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
                  f"{r['t_compute']:10.4f} {r['t_memory']:10.4f} {r['t_collective']:10.4f} "
                  f"{r['dominant']:>10s} {100*(r.get('useful_ratio') or 0):6.1f}%")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} {r['status']:>10s}  {reason}")


def run_real_overlap(fast: bool, backend: str = "numpy", passes: str = "auto"):
    """§5 measured on the wall clock: drain the stencil schedule through
    repro.exec with the non-blocking progress-engine channel (overlap on)
    vs the synchronous channel (overlap off), injecting a scaled-up α
    (10 ms — see the regime note below) per message so there is real
    latency to hide.  The simulated rows run the cluster model at the
    same α; ``format_stats`` renders all four with identical columns,
    plus the dispatch-overhead counters (ops/s, handoffs/flush,
    msgs/flush) that the plan-stage passes improve.

    The execution stack is swept declaratively: one measured
    ``ExecutionPolicy`` and its ``.replace(channel=...)`` siblings, with
    the compute ``backend`` (numpy | jax | auto) and the plan-stage
    pass pipeline (``--passes``, comma-separated) resolved through the
    plugin registries.  The sweep includes a passes-off row and a
    record-time-fusion row, both asserted bit-identical to the planned
    run."""
    import dataclasses

    import numpy as np

    from benchmarks.paper_apps import run_app
    from repro.api import ExecutionPolicy, format_stats
    from repro.core.timeline import GIGE_2012

    section(f"5. Real overlap — stencil app, measured wall-clock wait% "
            f"(repro.exec async executor, 10 ms α injected, "
            f"backend={backend!r}, passes={passes!r})")
    # regime choice: per-message latency must dominate the ~0.1 ms/op
    # Python dispatch overhead for the overlap signal to be stable on a
    # shared machine, so α is scaled up to 10 ms (a WAN-class link) and
    # blocks are kept chunky.  The *ordering* claim (overlap lowers
    # measured wait) is latency-scale-invariant; only its magnitude grows.
    nprocs = 8
    latency = 10e-3
    kw = dict(n=256, iters=3, block_size=64) if fast else dict(
        n=512, iters=6, block_size=128)
    cl = dataclasses.replace(GIGE_2012, alpha=latency, name="gige-alpha-10ms")

    simulated = ExecutionPolicy(scheduler="latency_hiding", cluster=cl)
    measured = ExecutionPolicy(
        flush="async", backend=backend, channel="async", latency=latency,
        passes=passes,
    )

    st_sim_lh, _ = run_app("jacobi_stencil", nprocs=nprocs,
                           policy=simulated, **kw)
    st_sim_bl, _ = run_app("jacobi_stencil", nprocs=nprocs,
                           policy=simulated.replace(scheduler="blocking"), **kw)
    st_on, r_on = run_app("jacobi_stencil", nprocs=nprocs,
                          policy=measured, **kw)
    st_off, r_off = run_app("jacobi_stencil", nprocs=nprocs,
                            policy=measured.replace(channel="blocking"), **kw)
    assert np.array_equal(np.asarray(r_on), np.asarray(r_off)), \
        "channel discipline changed the numerical result!"
    # plan-stage sweep: passes off, and record-time Expr fusion on — the
    # stencil is pure elementwise work, so every variant must be
    # BIT-identical, not merely close
    st_np, r_np = run_app("jacobi_stencil", nprocs=nprocs,
                          policy=measured.replace(passes=()), **kw)
    assert np.array_equal(np.asarray(r_on), np.asarray(r_np)), \
        "plan passes changed the numerical result!"
    st_fu, r_fu = run_app("jacobi_stencil", nprocs=nprocs,
                          policy=measured, fusion=True, **kw)
    assert np.array_equal(np.asarray(r_on), np.asarray(r_fu)), \
        "record-time fusion changed the numerical result!"

    rows = [
        ("overlap ON  (async)", st_on),
        ("overlap OFF (blocking)", st_off),
        ("passes off", st_np),
        ("LH + fusion (§7)", st_fu),
        ("latency-hiding (model)", st_sim_lh),
        ("blocking (model)", st_sim_bl),
    ]
    # per_worker=True appends the per-rank compute/comm-wait/idle
    # breakdown under each measured row (simulated rows have no workers)
    print(format_stats(rows, per_worker=True))
    print(f"\n  wall-clock win from overlap: {st_off.makespan/st_on.makespan:.2f}x "
          f"(paper fig. 18, simulated: "
          f"{st_sim_bl.makespan/st_sim_lh.makespan:.2f}x)")
    if st_on.n_handoffs and st_np.n_handoffs:
        print(f"  plan-stage dispatch win: handoffs {st_np.n_handoffs} -> "
              f"{st_on.n_handoffs} "
              f"({st_np.n_handoffs/st_on.n_handoffs:.1f}x fewer), "
              f"messages {st_np.n_messages} -> {st_on.n_messages} "
              f"({st_np.n_messages/max(1, st_on.n_messages):.1f}x fewer)")

    # persist the section as a machine-readable artifact for
    # benchmarks.make_report; under REPRO_TRACE the measured config is
    # re-run traced, the Perfetto JSON exported next to it and the
    # wait-attribution top-K folded into the artifact
    def _stat_row(st):
        return dict(
            source="measured" if hasattr(st, "per_worker_table") else "simulated",
            makespan_s=st.makespan, wait_fraction=st.wait_fraction,
            speedup=st.speedup, comm_bytes=st.comm_bytes,
            n_compute_ops=st.n_compute_ops, n_comm_ops=st.n_comm_ops,
        )

    bench = dict(
        section="real_overlap", backend=backend, passes=passes,
        nprocs=nprocs, latency_s=latency,
        overlap_win=st_off.makespan / st_on.makespan,
        rows={label: _stat_row(st) for label, st in rows},
    )
    Path("results").mkdir(exist_ok=True)
    trace_env = os.environ.get("REPRO_TRACE", "")
    if trace_env not in ("", "0", "false", "False"):
        import repro
        from repro.obs import attribution, export_trace

        with repro.trace() as tr:
            st_tr, _ = run_app("jacobi_stencil", nprocs=nprocs,
                               policy=measured, **kw)
        export_trace(tr, "results/trace_real_overlap.json")
        rep = attribution(tr)
        print("\n" + rep.format(5))
        print("  trace -> results/trace_real_overlap.json "
              "(open in https://ui.perfetto.dev)")
        bench["attribution"] = dict(
            nworkers=rep.nworkers, elapsed_s=rep.elapsed,
            wait_fraction=rep.wait_fraction,
            measured_wait_fraction=st_tr.wait_fraction,
            barrier_wait_s=rep.barrier_wait, n_spans=rep.n_spans,
            top=rep.top(5),
        )
    Path("results/BENCH_real_overlap.json").write_text(
        json.dumps(bench, indent=1)
    )
    return dict(wait_on=st_on.wait_fraction, wait_off=st_off.wait_fraction)


def run_demand_overlap(fast: bool):
    """§6 demand-driven evaluation: the stencil app with a per-sweep
    probe readback, swept barrier vs demand sync on the measured
    executor.  Under ``sync="barrier"`` every probe drains the whole
    recorded graph; under ``sync="demand"`` it drains only the probe's
    dependency cone (the halo neighbourhood of the probed corner block,
    which the previous probe already mostly drained) — visible in the
    ``ops/flush`` dispatch column, the ops drained per readback.  Both
    modes must stay bit-identical: the cone partition changes WHEN
    operations execute, never what they compute."""
    import numpy as np

    import repro
    from repro.api import ExecutionPolicy, RuntimeConfig, format_stats

    section("6. Demand-driven overlap — stencil app + per-sweep probe, "
            "barrier vs demand sync (measured executor)")
    nprocs = 8
    n, iters, block = (128, 4, 32) if fast else (256, 6, 64)

    def stencil_probe(sync: str):
        cfg = RuntimeConfig(nprocs=nprocs, block_size=block)
        pol = ExecutionPolicy(flush="async", channel="async", sync=sync)
        with repro.runtime(cfg, pol) as rt:
            full = repro.zeros((n + 2, n + 2))
            full[0, :] = 1.0
            full[:, 0] = 1.0
            probes = []
            for _ in range(iters):
                full[1:-1, 1:-1] = 0.2 * (
                    full[1:-1, 1:-1]
                    + full[0:-2, 1:-1]
                    + full[2:, 1:-1]
                    + full[1:-1, 0:-2]
                    + full[1:-1, 2:]
                )
                # per-sweep convergence probe: one corner element
                probes.append(float(np.asarray(full[1:2, 1:2])[0, 0]))
            result = np.asarray(full)
            return rt.stats(), result, probes

    st_b, r_b, p_b = stencil_probe("barrier")
    st_d, r_d, p_d = stencil_probe("demand")
    assert np.array_equal(r_b, r_d), \
        "demand-driven sync changed the numerical result!"
    assert p_b == p_d, "demand-driven sync changed the probe values!"

    print(format_stats([
        ("barrier sync", st_b),
        ("demand sync", st_d),
    ]))
    ops_b = (st_b.n_compute_ops + st_b.n_comm_ops) / max(1, st_b.n_flushes)
    ops_d = (st_d.n_compute_ops + st_d.n_comm_ops) / max(1, st_d.n_flushes)
    print(f"\n  ops drained per readback: barrier={ops_b:,.0f} "
          f"demand={ops_d:,.0f} ({ops_b / max(1.0, ops_d):.1f}x fewer), "
          f"wait%: barrier={st_b.wait_fraction * 100:.1f} "
          f"demand={st_d.wait_fraction * 100:.1f}")
    return dict(ops_per_readback_barrier=ops_b, ops_per_readback_demand=ops_d)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced sizes (CI)")
    ap.add_argument("--skip-apps", action="store_true")
    ap.add_argument("--skip-depsys", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-real-overlap", action="store_true")
    ap.add_argument("--skip-demand-overlap", action="store_true")
    ap.add_argument("--exec-backend", default="numpy",
                    help="compute backend for the real-overlap section, "
                         "resolved through the plugin registry "
                         "(numpy | jax | auto | any registered name)")
    ap.add_argument("--passes", default="auto",
                    help="plan-stage pass pipeline for the real-overlap "
                         "section: 'auto', '' (none), or a comma-separated "
                         "list of registered pass names "
                         "(coalesce | fuse | batch | any registered name)")
    args = ap.parse_args()
    if not args.skip_apps:
        run_paper_apps(args.fast)
    if not args.skip_depsys:
        run_depsys(args.fast)
    if not args.skip_kernels:
        run_kernels()
    if not args.skip_roofline:
        run_roofline()
    if not args.skip_real_overlap:
        run_real_overlap(args.fast, backend=args.exec_backend,
                         passes=args.passes)
    if not args.skip_demand_overlap:
        run_demand_overlap(args.fast)


if __name__ == "__main__":
    main()
