"""Dispatch-overhead smoke: the plan-stage acceptance gate, runnable in CI.

    PYTHONPATH=src python -m benchmarks.dispatch_smoke [--ops 10000]

Two checks, both against the measured (``flush_backend="async"``)
executor:

1. **Batched handoffs** — a ~``--ops``-operation elementwise chain is
   drained with and without the ``batch`` plan pass.  The batched run
   must use *strictly fewer* worker handoffs (queue pushes), and at
   least ``--min-ratio``× fewer at the default size; results must be
   bit-identical.
2. **Coalesced messages** — the Jacobi stencil app is drained with and
   without the ``coalesce`` pass.  The coalesced run must post *fewer*
   channel messages; results must be bit-identical.

Exits non-zero (assertion) on any regression — wired into CI as the
``dispatch-overhead`` job.
"""
from __future__ import annotations

import argparse

import numpy as np


def chain_handoffs(ops: int, passes, nprocs: int = 4, nblocks: int = 32):
    """Drain an elementwise ``a += 1`` chain of ~``ops`` operations
    (``nblocks`` blocks × ``ops // nblocks`` steps, all ready work
    self-feeding per worker) and return (stats, result)."""
    import repro

    block = 64
    with repro.runtime(
        nprocs=nprocs, block_size=block, flush="async", passes=passes
    ) as rt:
        a = repro.ones((nblocks * block,))
        for _ in range(max(1, ops // nblocks)):
            a += 1.0
        result = np.asarray(a)
        return rt.stats(), result


def stencil_messages(passes, n: int = 128, iters: int = 2, nprocs: int = 4):
    from benchmarks.paper_apps import run_app
    from repro.api import ExecutionPolicy

    policy = ExecutionPolicy(flush="async", channel="async", passes=passes)
    st, r = run_app("jacobi_stencil", nprocs=nprocs, block_size=32,
                    policy=policy, n=n, iters=iters)
    return st, np.asarray(r)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=10_000,
                    help="approximate chain length for the handoff check")
    ap.add_argument("--min-ratio", type=float, default=5.0,
                    help="required handoff reduction at --ops >= 10000")
    args = ap.parse_args()

    print(f"== batched dispatch: ~{args.ops}-op elementwise chain ==")
    st_b, r_b = chain_handoffs(args.ops, passes=("batch",))
    st_u, r_u = chain_handoffs(args.ops, passes=())
    assert np.array_equal(r_b, r_u), "batching changed the numerical result!"
    ratio = st_u.n_handoffs / max(1, st_b.n_handoffs)
    wake_b = sum(p.n_wakeups for p in st_b.procs)
    wake_u = sum(p.n_wakeups for p in st_u.procs)
    print(f"  handoffs: unbatched={st_u.n_handoffs} "
          f"batched={st_b.n_handoffs} ({ratio:.1f}x fewer)")
    print(f"  wakeups:  unbatched={wake_u} batched={wake_b}")
    print(f"  ops/s:    unbatched={st_u.ops_per_sec:,.0f} "
          f"batched={st_b.ops_per_sec:,.0f}")
    assert st_b.n_handoffs < st_u.n_handoffs, (
        f"batched handoff count ({st_b.n_handoffs}) is not strictly lower "
        f"than unbatched ({st_u.n_handoffs})"
    )
    assert wake_b < wake_u, (
        f"batched worker wakeups ({wake_b}) are not strictly fewer "
        f"than unbatched ({wake_u})"
    )
    if args.ops >= 10_000:
        assert ratio >= args.min_ratio, (
            f"batched dispatch reduced handoffs only {ratio:.1f}x "
            f"(required >= {args.min_ratio}x)"
        )

    print("== coalesced transfers: jacobi stencil ==")
    st_c, r_c = stencil_messages(("coalesce", "batch"))
    st_n, r_n = stencil_messages(())
    assert np.array_equal(r_c, r_n), "coalescing changed the numerical result!"
    print(f"  messages: uncoalesced={st_n.n_messages} "
          f"coalesced={st_c.n_messages} "
          f"({st_n.n_messages / max(1, st_c.n_messages):.1f}x fewer)")
    assert st_c.n_messages < st_n.n_messages, (
        f"coalesced message count ({st_c.n_messages}) is not lower than "
        f"uncoalesced ({st_n.n_messages})"
    )
    print("dispatch-overhead smoke: OK")


if __name__ == "__main__":
    main()
