"""Dispatch-overhead smoke: the plan-stage acceptance gate, runnable in CI.

    PYTHONPATH=src python -m benchmarks.dispatch_smoke [--ops 10000]
    PYTHONPATH=src python -m benchmarks.dispatch_smoke --demand

Default mode runs two checks, both against the measured
(``flush_backend="async"``) executor:

1. **Batched handoffs** — a ~``--ops``-operation elementwise chain is
   drained with and without the ``batch`` plan pass.  The batched run
   must use *strictly fewer* worker handoffs (queue pushes), and at
   least ``--min-ratio``× fewer at the default size; results must be
   bit-identical.
2. **Coalesced messages** — the Jacobi stencil app is drained with and
   without the ``coalesce`` pass.  The coalesced run must post *fewer*
   channel messages; results must be bit-identical.

``--demand`` runs the demand-driven-overlap gate instead (CI job
``overlap-smoke``): a ~``--ops``-operation graph of independent
single-block chains is recorded, then ONE chain is read back.  Under
``sync="demand"`` that readback must drain **< 5 %** of the recorded
operations (its dependency cone — one chain), and forcing the remaining
arrays must produce results bit-identical to the same program under
``sync="barrier"``.

``--trace-overhead`` runs the tracing acceptance gates instead (CI job
``trace-smoke``): the same ~``--ops``-operation chain is timed with
tracing disabled and with a live collector.  Traced overhead must stay
below ``--max-overhead`` (default 5%), results must be bit-identical,
and the exported Chrome-trace JSON must validate.  (The <1% *disabled*
gate is implicit: the untraced leg here IS the disabled path, and the
tier-1 suite plus the default gates run it at full speed.)

``--verify-overhead`` runs the static-verification gate instead (CI job
``graph-lint``): the same chain under the full built-in pipeline is
timed with ``verify="off"`` and ``verify="full"``.  Verified overhead
must stay below ``--max-overhead`` on the ~``--ops``-op plan, the run
must produce zero diagnostics, and results must be bit-identical.

Exits non-zero (assertion) on any regression.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def chain_handoffs(ops: int, passes, nprocs: int = 4, nblocks: int = 32,
                   verify: str = "off"):
    """Drain an elementwise ``a += 1`` chain of ~``ops`` operations
    (``nblocks`` blocks × ``ops // nblocks`` steps, all ready work
    self-feeding per worker) and return (stats, result, verify_stats)."""
    import repro

    block = 64
    with repro.runtime(
        nprocs=nprocs, block_size=block, flush="async", passes=passes,
        verify=verify
    ) as rt:
        a = repro.ones((nblocks * block,))
        for _ in range(max(1, ops // nblocks)):
            a += 1.0
        result = np.asarray(a)
        return rt.stats(), result, rt.verify_stats


def stencil_messages(passes, n: int = 128, iters: int = 2, nprocs: int = 4):
    from benchmarks.paper_apps import run_app
    from repro.api import ExecutionPolicy

    policy = ExecutionPolicy(flush="async", channel="async", passes=passes)
    st, r = run_app("jacobi_stencil", nprocs=nprocs, block_size=32,
                    policy=policy, n=n, iters=iters)
    return st, np.asarray(r)


def demand_readback(ops: int, sync: str, nprocs: int = 4, nchains: int = 32):
    """Record ``nchains`` independent single-block ``a += 1`` chains
    (~``ops`` operations total), read back ONE of them, then the rest.
    Returns (recorded ops, ops drained by the first readback, results)."""
    import repro

    block = 64
    per = max(1, ops // nchains)
    with repro.runtime(
        nprocs=nprocs, block_size=block, flush="async", sync=sync
    ) as rt:
        arrs = [repro.ones((block,)) for _ in range(nchains)]
        for _ in range(per):
            for a in arrs:
                a += 1.0
        recorded = rt.deps.n_pending
        first = np.asarray(arrs[0])
        st = rt.stats()
        drained = st.n_compute_ops + st.n_comm_ops
        rest = [np.asarray(a) for a in arrs[1:]]
        return recorded, drained, [first] + rest


def run_demand_gate(ops: int) -> None:
    print(f"== demand-driven overlap: 1-block cone out of a ~{ops}-op graph ==")
    rec_d, drained_d, res_d = demand_readback(ops, sync="demand")
    frac = drained_d / max(1, rec_d)
    print(f"  recorded={rec_d} ops; first readback drained {drained_d} "
          f"({frac * 100:.2f}% of the graph)")
    assert frac < 0.05, (
        f"demand readback drained {frac * 100:.2f}% of the recorded graph "
        f"(required < 5%): the dependency cone leaked"
    )
    rec_b, drained_b, res_b = demand_readback(ops, sync="barrier")
    print(f"  barrier reference: first readback drained {drained_b} "
          f"of {rec_b} ops")
    assert drained_b == rec_b, "barrier sync no longer drains everything?"
    for i, (d, b) in enumerate(zip(res_d, res_b)):
        assert np.array_equal(d, b), (
            f"demand forcing changed the numerical result (array {i})!"
        )
    print("  results bit-identical to sync='barrier'")
    print("overlap smoke: OK")


def run_trace_overhead_gate(ops: int, max_overhead: float) -> None:
    """Tracing overhead gate: best-of-3 wall-clock of the ~``ops``-op
    chain, traced (live ring-buffer collector) vs untraced, must differ
    by < ``max_overhead``; traced results stay bit-identical and the
    export validates."""
    from repro.obs import attribution, export_trace, trace, validate_trace

    print(f"== tracing overhead: ~{ops}-op elementwise chain ==")

    def timed(fn):
        t0 = time.perf_counter()
        result = fn()
        return time.perf_counter() - t0, result

    def traced_run():
        with trace() as tr:
            st, r, _ = chain_handoffs(ops, passes=("batch",))
        return st, r, tr

    # warm-up (thread pools, import costs) outside the timed region
    chain_handoffs(max(100, ops // 100), passes=("batch",))

    # alternate the two legs so machine drift hits both equally, then
    # compare best against best (the least-noise estimate of true cost)
    offs, ons = [], []
    for _ in range(3):
        t, (st_off, r_off, _) = timed(
            lambda: chain_handoffs(ops, passes=("batch",))
        )
        offs.append(t)
        t, (st_on, r_on, tr) = timed(traced_run)
        ons.append(t)

    t_off, t_on = min(offs), min(ons)
    overhead = t_on / t_off - 1.0
    print(f"  untraced: {t_off * 1e3:8.1f} ms  ({st_off.ops_per_sec:,.0f} ops/s)")
    print(f"  traced:   {t_on * 1e3:8.1f} ms  ({st_on.ops_per_sec:,.0f} ops/s, "
          f"{tr.n_emitted} events, {tr.dropped} dropped)")
    print(f"  overhead: {overhead * 100:+.2f}% (gate < {max_overhead * 100:.0f}%)")
    assert np.array_equal(r_off, r_on), "tracing changed the numerical result!"
    assert tr.n_emitted > ops, (
        f"traced run emitted only {tr.n_emitted} events for ~{ops} ops"
    )
    doc = export_trace(tr)
    info = validate_trace(doc)
    print(f"  export: {info['n_events']} trace events validate "
          f"(pids {info['pids']})")
    rep = attribution(tr)
    print("  " + rep.format(3).replace("\n", "\n  "))
    assert overhead < max_overhead, (
        f"tracing overhead {overhead * 100:.2f}% exceeds the "
        f"{max_overhead * 100:.0f}% gate"
    )
    print("trace-overhead smoke: OK")


def run_verify_overhead_gate(ops: int, max_overhead: float) -> None:
    """Static-verification overhead gate: best-of-3 wall-clock of the
    ~``ops``-op chain under the full built-in pipeline, with
    ``verify="full"`` vs ``verify="off"``, must differ by less than
    ``max_overhead``; the verified run must be diagnostic-free and
    bit-identical.

    The overhead is measured in-process: the engine times its own
    verification work (``VerifyStats.verify_seconds`` — footprint
    snapshot, plan check, race oracle) inside the verified run, and the
    gate compares that against the remainder of the same run.  A
    wall-clock A/B of two whole legs cannot resolve a ~2% effect
    against a 5% gate on a shared box (leg-to-leg noise is ±10–20%);
    sharing one run's clock between numerator and denominator cancels
    the machine noise."""
    import repro

    pipeline = ("coalesce", "fuse", "batch")
    print(f"== verification overhead: ~{ops}-op chain, "
          f"passes={pipeline} ==")

    def sim_chain(n, verify, nblocks=32, block=64):
        with repro.runtime(nprocs=4, block_size=block, flush="sim",
                           passes=pipeline, verify=verify) as rt:
            a = repro.ones((nblocks * block,))
            for _ in range(max(1, n // nblocks)):
                a += 1.0
            result = np.asarray(a)
            return rt.stats(), result, rt.verify_stats

    # warm-up (imports, allocator; lazy repro.analysis)
    sim_chain(max(200, ops // 50), "full")

    # bit-identity reference leg (untimed)
    _, r_off, _ = sim_chain(ops, "off")

    # best-of-3 verified runs; per run, overhead = time spent inside
    # the verifier / time spent doing everything else
    overheads = []
    for _ in range(3):
        t0 = time.perf_counter()
        st_on, r_on, vs = sim_chain(ops, "full")
        t_total = time.perf_counter() - t0
        overheads.append(vs.verify_seconds / (t_total - vs.verify_seconds))
    overhead = min(overheads)
    print(f"  verified run: {t_total * 1e3:8.1f} ms total, "
          f"{vs.verify_seconds * 1e3:.1f} ms in the verifier "
          f"({vs.n_flushes_verified} flushes verified, "
          f"{st_on.n_compute_ops} compute ops drained)")
    print(f"  overhead: {overhead * 100:+.2f}% "
          f"(gate < {max_overhead * 100:.0f}%)")
    if vs.precision is not None:
        print(f"  race-oracle precision on key-level conflicts: "
              f"{vs.precision * 100:.1f}% "
              f"({vs.n_region_false_positives} region-level false "
              f"positives out of {vs.n_key_conflicts} key conflicts)")
    else:
        print("  race-oracle precision: n/a "
              "(no concurrent key-level conflicts on this workload)")
    assert np.array_equal(r_off, r_on), (
        "verification changed the numerical result!"
    )
    assert vs.n_flushes_verified >= 1, "verify='full' never ran a check"
    assert vs.n_diagnostics == 0, (
        f"built-in pipeline produced {vs.n_diagnostics} diagnostics "
        f"on a clean program"
    )
    assert overhead < max_overhead, (
        f"verification overhead {overhead * 100:.2f}% exceeds the "
        f"{max_overhead * 100:.0f}% gate"
    )
    print("verify-overhead smoke: OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=10_000,
                    help="approximate chain length for the handoff check")
    ap.add_argument("--min-ratio", type=float, default=5.0,
                    help="required handoff reduction at --ops >= 10000")
    ap.add_argument("--demand", action="store_true",
                    help="run the demand-driven overlap gate instead "
                         "(CI job overlap-smoke)")
    ap.add_argument("--trace-overhead", action="store_true",
                    help="run the tracing overhead gate instead "
                         "(CI job trace-smoke)")
    ap.add_argument("--verify-overhead", action="store_true",
                    help="run the static-verification overhead gate "
                         "instead (CI job graph-lint)")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="allowed traced/untraced slowdown (fraction)")
    args = ap.parse_args()

    if args.demand:
        run_demand_gate(args.ops)
        return
    if args.trace_overhead:
        run_trace_overhead_gate(args.ops, args.max_overhead)
        return
    if args.verify_overhead:
        run_verify_overhead_gate(args.ops, args.max_overhead)
        return

    print(f"== batched dispatch: ~{args.ops}-op elementwise chain ==")
    st_b, r_b, _ = chain_handoffs(args.ops, passes=("batch",))
    st_u, r_u, _ = chain_handoffs(args.ops, passes=())
    assert np.array_equal(r_b, r_u), "batching changed the numerical result!"
    ratio = st_u.n_handoffs / max(1, st_b.n_handoffs)
    wake_b = sum(p.n_wakeups for p in st_b.procs)
    wake_u = sum(p.n_wakeups for p in st_u.procs)
    print(f"  handoffs: unbatched={st_u.n_handoffs} "
          f"batched={st_b.n_handoffs} ({ratio:.1f}x fewer)")
    print(f"  wakeups:  unbatched={wake_u} batched={wake_b}")
    print(f"  ops/s:    unbatched={st_u.ops_per_sec:,.0f} "
          f"batched={st_b.ops_per_sec:,.0f}")
    assert st_b.n_handoffs < st_u.n_handoffs, (
        f"batched handoff count ({st_b.n_handoffs}) is not strictly lower "
        f"than unbatched ({st_u.n_handoffs})"
    )
    assert wake_b < wake_u, (
        f"batched worker wakeups ({wake_b}) are not strictly fewer "
        f"than unbatched ({wake_u})"
    )
    if args.ops >= 10_000:
        assert ratio >= args.min_ratio, (
            f"batched dispatch reduced handoffs only {ratio:.1f}x "
            f"(required >= {args.min_ratio}x)"
        )

    print("== coalesced transfers: jacobi stencil ==")
    st_c, r_c = stencil_messages(("coalesce", "batch"))
    st_n, r_n = stencil_messages(())
    assert np.array_equal(r_c, r_n), "coalescing changed the numerical result!"
    print(f"  messages: uncoalesced={st_n.n_messages} "
          f"coalesced={st_c.n_messages} "
          f"({st_n.n_messages / max(1, st_c.n_messages):.1f}x fewer)")
    assert st_c.n_messages < st_n.n_messages, (
        f"coalesced message count ({st_c.n_messages}) is not lower than "
        f"uncoalesced ({st_n.n_messages})"
    )
    print("dispatch-overhead smoke: OK")


if __name__ == "__main__":
    main()
