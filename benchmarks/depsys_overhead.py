"""Dependency-system overhead: the paper's §5.7.2 motivation.

Measures insertion cost of N operations into (a) the full DAG (O(n)
compare-against-everything insert) and (b) the per-block dependency-list
heuristic, on the access pattern the heuristic is built for: a vectorized
operation spread evenly over the blocks of a few arrays (each block's
list stays short while the DAG scans every live node).
"""
from __future__ import annotations

import time

from repro.core import COMPUTE, AccessNode, DependencySystem, FullDAG, OperationNode

__all__ = ["measure", "rows"]


def _make_ops(n_ops: int, n_blocks: int, reads_per_op: int = 2):
    """Synthetic stencil-ish stream: op i writes block i%B of array 0 and
    reads neighbouring blocks of array 1."""
    ops = []
    for i in range(n_ops):
        op = OperationNode(COMPUTE, None, procs=(i % 4,), cost=1.0)
        blk = i % n_blocks
        op.add_access(AccessNode(("a0", blk), ((0, 64),), write=True))
        for r in range(reads_per_op):
            op.add_access(AccessNode(("a1", (blk + r) % n_blocks), ((0, 64),), write=False))
        ops.append(op)
    return ops


def _drain(sys_, ops):
    for op in ops:
        sys_.insert(op)
    done = 0
    while True:
        op = sys_.pop_ready()
        if op is None:
            break
        sys_.complete(op)
        done += 1
    assert done == len(ops), (done, len(ops))


def measure(n_ops: int, n_blocks: int = 256):
    out = {}
    for name, cls in (("heuristic", DependencySystem), ("full_dag", FullDAG)):
        ops = _make_ops(n_ops, n_blocks)
        sys_ = cls()
        t0 = time.perf_counter()
        _drain(sys_, ops)
        dt = time.perf_counter() - t0
        out[name] = {"seconds": dt, "scan_steps": sys_.scan_steps,
                     "us_per_op": dt / n_ops * 1e6}
    return out


def rows(sizes=(500, 1000, 2000, 4000, 8000)):
    out = []
    for n in sizes:
        m = measure(n)
        out.append(
            dict(
                n_ops=n,
                heuristic_us_per_op=m["heuristic"]["us_per_op"],
                dag_us_per_op=m["full_dag"]["us_per_op"],
                heuristic_scans=m["heuristic"]["scan_steps"],
                dag_scans=m["full_dag"]["scan_steps"],
                speedup=m["full_dag"]["seconds"] / max(m["heuristic"]["seconds"], 1e-12),
            )
        )
    return out
